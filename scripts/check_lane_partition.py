"""CI lane-partition check: the three test-lane marker expressions must
exactly partition the suite.

CI splits tier-1 tests across three jobs by marker expression::

    fast   -m "not slow and not faults"
    slow   -m "slow and not faults"
    faults -m "faults"

A test that matches none of these (or two of them) silently escapes (or
double-runs in) the matrix. This script collects each lane with
``pytest --collect-only -q`` and asserts

    |fast| + |slow| + |faults| == |total|

where total is an unfiltered collection. Exit 1 with the per-lane counts
on any mismatch.

Usage (repo root): ``PYTHONPATH=src python scripts/check_lane_partition.py``
"""
from __future__ import annotations

import subprocess
import sys

LANES = {
    "fast": "not slow and not faults",
    "slow": "slow and not faults",
    "faults": "faults",
}


def collect_count(markers: str | None = None) -> int:
    cmd = [sys.executable, "-m", "pytest", "--collect-only", "-q"]
    if markers is not None:
        cmd += ["-m", markers]
    proc = subprocess.run(cmd, capture_output=True, text=True)
    # exit 5 = "no tests collected", a legal count of 0 for a lane
    if proc.returncode not in (0, 5):
        print(proc.stdout, file=sys.stderr)
        print(proc.stderr, file=sys.stderr)
        raise SystemExit(
            f"pytest --collect-only failed (exit {proc.returncode})")
    # each collected test prints one "path::test_id" line
    return sum("::" in line for line in proc.stdout.splitlines())


def main() -> None:
    total = collect_count()
    counts = {lane: collect_count(expr) for lane, expr in LANES.items()}
    covered = sum(counts.values())
    summary = " + ".join(f"{lane}={n}" for lane, n in counts.items())
    print(f"[lane-partition] {summary} -> {covered} (total {total})")
    if covered != total:
        print(
            f"LANE PARTITION BROKEN: the lane marker expressions cover "
            f"{covered} of {total} collected tests. Some test matches "
            f"zero or multiple of the CI lane expressions "
            f"{list(LANES.values())} - fix its markers.",
            file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
