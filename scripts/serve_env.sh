# Host-side tuning for serving launches. Source before any python -m
# repro.launch.* entry point:
#
#   source scripts/serve_env.sh
#   PYTHONPATH=src python -m repro.launch.serve --retrieval ...
#
# Two independent knobs (see API.md "Serving host environment" for the
# measured effect on this repo's quick benchmarks):
#
# 1. tcmalloc. CPython + XLA host callbacks allocate hot; tcmalloc's
#    thread-cached freelists cut malloc contention under the engine's
#    executor threads. Guarded on existence — containers without
#    gperftools keep glibc malloc and everything still works.
TCMALLOC_SO=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
if [ -e "$TCMALLOC_SO" ]; then
    export LD_PRELOAD="$TCMALLOC_SO${LD_PRELOAD:+:$LD_PRELOAD}"
    # silence per-allocation reports for the big arena/datastore buffers
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
fi

# 2. XLA host-platform tuning. One host device: the engine parallelises
#    across executor *threads* over a shared arena, so asking XLA to
#    split the host into virtual devices only fragments its thread pool.
export XLA_FLAGS="--xla_force_host_platform_device_count=1${XLA_FLAGS:+ $XLA_FLAGS}"

# keep serving logs readable: drop libtpu/absl INFO+WARNING chatter
export TF_CPP_MIN_LOG_LEVEL=${TF_CPP_MIN_LOG_LEVEL:-4}
