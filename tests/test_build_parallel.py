"""Parallel index construction (repro.build): the process-pool fan-out
must be bit-identical to the sequential build (the store-manifest
determinism gate from the acceptance criteria), and a crashed worker
must be retried without changing the result."""
import concurrent.futures
from concurrent.futures.process import BrokenProcessPool

import numpy as np
import pytest

from repro.build import (BuildError, build_pyramid_index_parallel,
                         build_subgraphs, plan_build)
from repro.common.config import PyramidConfig
from repro.core.distributed import search_single_host
from repro.data.synthetic import clustered_vectors, query_set
from repro.store import IndexStore, content_checksum, graph_to_arrays

CFG = PyramidConfig(metric="l2", num_shards=4, meta_size=32,
                    sample_size=500, branching_factor=2, max_degree=10,
                    max_degree_upper=5, ef_construction=30, ef_search=40,
                    kmeans_iters=4)


@pytest.fixture(scope="module")
def data():
    return clustered_vectors(900, 12, 8, seed=0)


@pytest.fixture(scope="module")
def seq_index(data):
    return build_pyramid_index_parallel(data, CFG, workers=0)


def _checksums(index):
    return [content_checksum(graph_to_arrays(g)) for g in index.subs]


def test_parallel_build_is_bit_identical(data, seq_index, tmp_path):
    """Acceptance gate: a pool of 4 workers produces the same index as
    the sequential loop — same published manifest checksums."""
    par = build_pyramid_index_parallel(data, CFG, workers=4)
    assert par.build_stats["build_mode"] == "parallel"
    assert _checksums(seq_index) == _checksums(par)
    v_seq = IndexStore(str(tmp_path / "seq")).publish(seq_index)
    v_par = IndexStore(str(tmp_path / "par")).publish(par)
    m_seq = IndexStore(str(tmp_path / "seq")).reader(v_seq).manifest
    m_par = IndexStore(str(tmp_path / "par")).reader(v_par).manifest
    assert ([s["checksum"] for s in m_seq["shards"]]
            == [s["checksum"] for s in m_par["shards"]])
    assert m_seq["meta"]["checksum"] == m_par["meta"]["checksum"]
    # and the search results agree exactly
    q = query_set(data, 16, seed=3)
    ids_a, sc_a, _ = search_single_host(seq_index, q, k=5)
    ids_b, sc_b, _ = search_single_host(par, q, k=5)
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_array_equal(sc_a, sc_b)


def test_build_stats_record_fanout(data):
    par = build_pyramid_index_parallel(data, CFG, workers=2)
    st = par.build_stats
    assert st["build_workers"] == 2
    assert len(st["shard_build_s"]) == CFG.num_shards
    assert all(t > 0 for t in st["shard_build_s"])
    assert st["subgraphs_wall_s"] > 0
    assert st["sub_sizes"] == [g.n for g in par.subs]


class _FlakyPool:
    """Injectable pool whose first ``fail_times`` submissions fail.

    Later submissions run the work inline, so the retry path is
    exercised deterministically without real process churn."""

    def __init__(self, fail_times: int, exc_factory=RuntimeError):
        self.fail_times = fail_times
        self.exc_factory = exc_factory
        self.calls = 0

    def submit(self, fn, *args):
        fut: concurrent.futures.Future = concurrent.futures.Future()
        self.calls += 1
        if self.calls <= self.fail_times:
            fut.set_exception(self.exc_factory("injected worker crash"))
        else:
            fut.set_result(fn(*args))
        return fut

    def shutdown(self, wait=True, cancel_futures=False):
        pass


def test_worker_crash_is_retried(data, seq_index):
    plan = plan_build(data, CFG)
    subs, stats = build_subgraphs(
        plan, workers=2, pool_factory=lambda: _FlakyPool(1))
    assert stats["build_retries"] == 1
    assert [e["event"] for e in stats["build_timeline"]] == ["retry"]
    assert stats["build_timeline"][0]["via"] == "pool"
    for a, b in zip(seq_index.subs, subs):   # retry changed nothing
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.data, b.data)


def test_broken_pool_falls_back_inline(data, seq_index):
    plan = plan_build(data, CFG)
    subs, stats = build_subgraphs(
        plan, workers=2,
        pool_factory=lambda: _FlakyPool(1, exc_factory=BrokenProcessPool))
    assert stats["build_retries"] == 1
    assert stats["build_timeline"][0]["via"] == "inline"
    for a, b in zip(seq_index.subs, subs):
        np.testing.assert_array_equal(a.ids, b.ids)


class _BreaksOnResubmit:
    """The initial fan-out lands (first future fails with an ordinary
    error, the rest succeed); the *resubmit* then raises
    BrokenProcessPool from ``submit()`` itself (another worker died in
    between) — the fall-through-to-inline path."""

    def __init__(self, n_initial: int):
        self.n_initial = n_initial
        self.calls = 0

    def submit(self, fn, *args):
        self.calls += 1
        if self.calls > self.n_initial:
            raise BrokenProcessPool("pool broke before resubmit")
        fut: concurrent.futures.Future = concurrent.futures.Future()
        if self.calls == 1:
            fut.set_exception(RuntimeError("injected worker crash"))
        else:
            fut.set_result(fn(*args))
        return fut

    def shutdown(self, wait=True, cancel_futures=False):
        pass


def test_pool_breaking_during_resubmit_falls_back_inline(data, seq_index):
    plan = plan_build(data, CFG)
    subs, stats = build_subgraphs(
        plan, workers=2,
        pool_factory=lambda: _BreaksOnResubmit(CFG.num_shards))
    assert stats["build_retries"] >= 1
    assert stats["build_timeline"][0]["via"] == "inline"
    for a, b in zip(seq_index.subs, subs):
        np.testing.assert_array_equal(a.ids, b.ids)


def test_retry_budget_exhaustion_raises(data):
    plan = plan_build(data, CFG)
    with pytest.raises(BuildError, match="retries"):
        build_subgraphs(plan, workers=2, max_retries=1,
                        pool_factory=lambda: _FlakyPool(100))


def test_workers_default_caps_at_shards(data):
    # workers=None must pick something sane and still build correctly
    idx = build_pyramid_index_parallel(data, CFG, workers=None)
    assert idx.num_shards == CFG.num_shards
    assert idx.build_stats["build_workers"] <= CFG.num_shards
