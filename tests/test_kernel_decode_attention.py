"""Shape/dtype sweep of the flash-decode Pallas kernel vs the jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import flash_decode_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def _case(b, s, h, kvh, hd, pos_mode, dtype=jnp.float32, block_s=64,
          seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, hd)).astype(np.float32), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, hd)).astype(np.float32),
                    dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, hd)).astype(np.float32),
                    dtype)
    if pos_mode == "full":
        pos = jnp.full((b,), s - 1, jnp.int32)
    elif pos_mode == "start":
        pos = jnp.zeros((b,), jnp.int32)
    else:
        pos = jnp.asarray(rng.integers(0, s, size=(b,)), jnp.int32)
    ref = decode_attention_ref(q, k, v, pos)
    ker = flash_decode_pallas(q, k, v, pos, block_s=block_s, interpret=True)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [
    (2, 128, 8, 4, 32), (1, 300, 16, 8, 64), (3, 64, 4, 4, 16),
    (2, 96, 6, 2, 32),
])
@pytest.mark.parametrize("pos_mode", ["full", "start", "random"])
def test_flash_decode_matches_ref(shape, pos_mode):
    b, s, h, kvh, hd = shape
    _case(b, s, h, kvh, hd, pos_mode, seed=hash((shape, pos_mode)) % 1000)


def test_flash_decode_bf16():
    _case(2, 200, 8, 4, 32, "random", dtype=jnp.bfloat16, seed=5)


def test_flash_decode_unaligned_blocks():
    # S not a multiple of block_s: padded rows must be fully masked
    _case(2, 130, 8, 4, 32, "full", block_s=64, seed=6)
    _case(1, 70, 4, 2, 16, "random", block_s=64, seed=7)


def test_flash_decode_matches_model_decode_path():
    """The kernel must agree with the model's grouped-KV decode einsums."""
    import jax
    from repro.models.attention import decode_attention_block, AttnSpec
    from repro.common.registry import get_arch
    from repro.models.transformer import init_params

    cfg = get_arch("qwen3-1.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    p = jax.tree.map(lambda a: a[0], params["blocks"]["attention"])
    rng = np.random.default_rng(8)
    b, s = 2, 32
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    x = jnp.asarray(rng.normal(size=(b, 1, cfg.d_model)).astype(np.float32))
    k_cache = jnp.asarray(
        rng.normal(size=(b, s, kvh, hd)).astype(np.float32))
    v_cache = jnp.asarray(
        rng.normal(size=(b, s, kvh, hd)).astype(np.float32))
    pos = jnp.asarray([5, 17], jnp.int32)
    y, k2, v2 = decode_attention_block(
        p, cfg, x, pos, k_cache, v_cache, AttnSpec(False, 0))
    # reproduce with the kernel on the UPDATED cache
    from repro.models.attention import _project_qkv
    q, _, _ = _project_qkv(p, cfg, x, pos[:, None])
    out = flash_decode_pallas(q[:, 0], k2, v2, pos, block_s=16,
                              interpret=True)
    w_o = np.asarray(p["w_o"]).reshape(cfg.num_heads, hd, cfg.d_model)
    y_kernel = np.einsum("bhq,hqd->bd", np.asarray(out), w_o)
    np.testing.assert_allclose(y_kernel, np.asarray(y[:, 0], np.float32),
                               rtol=2e-2, atol=2e-2)
