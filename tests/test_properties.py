"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import metrics as M
from repro.core.partition import balance_stats, partition_graph
from repro.kernels.topk_distance.kernel import topk_similarity_pallas
from repro.kernels.topk_distance.ref import topk_similarity_ref
from repro.models.rope import apply_rope
from repro.models.ssm import _segsum
from repro.common.config import RoPEKind

SETTINGS = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# kernel: pallas == oracle for arbitrary shapes/metrics
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 12),
    n=st.integers(8, 200),
    d=st.integers(2, 48),
    k=st.integers(1, 8),
    metric=st.sampled_from(["l2", "ip", "angular"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_oracle(b, n, d, k, metric, seed):
    k = min(k, n)
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    s_ref, _ = topk_similarity_ref(q, x, k=k, metric=metric)
    s_ker, ids = topk_similarity_pallas(q, x, k=k, metric=metric,
                                        block_q=8, block_n=64,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(s_ker), np.asarray(s_ref),
                               rtol=3e-4, atol=3e-4)
    ids = np.asarray(ids)
    assert (ids >= 0).all() and (ids < n).all()


# ---------------------------------------------------------------------------
# partitioning: always a balanced cover
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(16, 150),
    m=st.integers(2, 6),
    w=st.integers(2, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_partition_invariants(n, m, w, seed):
    rng = np.random.default_rng(seed)
    adj = rng.integers(-1, n, size=(n, m)).astype(np.int32)
    weights = rng.uniform(0.5, 2.0, size=n)
    labels = partition_graph(adj, weights, w, seed=seed % 100)
    assert labels.shape == (n,)
    assert labels.min() >= 0 and labels.max() < w
    bal, pw = balance_stats(weights, labels, w)
    # every part non-empty unless w ~ n
    assert (pw > 0).sum() >= min(w, n)
    # weight balance within the epsilon + integrality slack
    assert bal <= 1.5


# ---------------------------------------------------------------------------
# similarity metrics: invariances
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(2, 40),
    d=st.integers(2, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_l2_self_similarity_is_max(n, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    sims = M.similarity_matrix_np(x, x, "l2")
    # an item is always (one of) its own nearest neighbours
    assert np.allclose(np.diag(sims), sims.max(axis=1), atol=1e-4)


@settings(**SETTINGS)
@given(
    n=st.integers(2, 30),
    d=st.integers(2, 16),
    scale=st.floats(0.1, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_angular_scale_invariance(n, d, scale, seed):
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(4, d)).astype(np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    s1 = M.similarity_matrix_np(q, x, "angular")
    s2 = M.similarity_matrix_np(q * scale, x * np.float32(scale), "angular")
    np.testing.assert_allclose(s1, s2, atol=1e-3)


# ---------------------------------------------------------------------------
# model substrate invariants
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    s=st.integers(1, 16),
    hd=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rope_preserves_norm(s, hd, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, s, 3, hd)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (2, s))
    y = apply_rope(x, pos, kind=RoPEKind.STANDARD)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    q=st.integers(2, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_segsum_matches_bruteforce(q, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(q,)).astype(np.float32))
    out = np.asarray(_segsum(a))
    for i in range(q):
        for j in range(q):
            if i >= j:
                expect = float(np.sum(np.asarray(a)[j + 1: i + 1]))
                np.testing.assert_allclose(out[i, j], expect, atol=1e-5)
            else:
                assert out[i, j] == -np.inf
