"""Deterministic tests for the signal-driven autoscaler.

Every test drives :meth:`Autoscaler.tick` directly and injects the
engine's own signals (``engine.tracker.observe`` for p99, the routing
counters for access rate) — no background thread, no sleeps, no
wall-clock dependence anywhere.
"""
import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.core.meta_index import build_pyramid_index
from repro.data.synthetic import clustered_vectors
from repro.obs import MetricsRegistry, Tracer
from repro.serving.autoscaler import Autoscaler, AutoscalerConfig
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def index():
    x = clustered_vectors(1000, 8, 8, seed=0)
    cfg = PyramidConfig(metric="l2", num_shards=2, meta_size=16,
                        sample_size=500, branching_factor=2,
                        max_degree=8, max_degree_upper=4,
                        ef_construction=30, ef_search=30, kmeans_iters=4)
    return build_pyramid_index(x, cfg)


@pytest.fixture()
def engine(index):
    eng = ServingEngine(index, replicas=1, hedge=False,
                        registry=MetricsRegistry(), tracer=Tracer())
    yield eng
    eng.shutdown()


CFG = dict(min_replicas=1, max_replicas=3, p99_high_s=0.5,
           p99_low_s=0.1, access_high=None, scale_down_after=2,
           cooldown_ticks=1)


def _observe(eng, shard, value, n=32):
    for _ in range(n):
        eng.tracker.observe(shard, value)


def test_scale_up_on_p99_inflation(engine):
    sc = Autoscaler(engine, AutoscalerConfig(**CFG))
    assert sc.tick() == []              # no samples yet -> no action
    _observe(engine, 0, 1.0)            # p99 = 1.0s > 0.5s threshold
    actions = sc.tick()
    assert [(a[0], a[1], a[2]) for a in actions] == [(0, "up", 2)]
    assert engine.replica_count(0) == 2
    assert engine.replica_count(1) == 1     # quiet shard untouched
    prom = engine.obs.render_prometheus()
    assert 'pyramid_autoscaler_scale_ups_total{shard="0"} 1' in prom
    ups = [s for s in engine.tracer.snapshot()
           if s.name == "autoscaler.scale_up"]
    assert len(ups) == 1 and ups[0].attrs["shard"] == 0


def test_cooldown_blocks_immediate_reaction(engine):
    sc = Autoscaler(engine, AutoscalerConfig(**CFG))
    _observe(engine, 0, 1.0)
    assert sc.tick()                    # up, starts cooldown
    assert sc.tick() == []              # cooldown tick: shard sits out
    assert sc.tick() != []              # still hot -> scales up again
    assert engine.replica_count(0) == 3


def test_hysteresis_scale_down_needs_consecutive_quiet_ticks(engine):
    sc = Autoscaler(engine, AutoscalerConfig(**CFG))
    _observe(engine, 0, 1.0)
    assert sc.tick() == [(0, "up", 2,
                          "p99=1.0000s>0.5s")]
    sc.tick()                           # burn the cooldown tick
    # flush the window with quiet samples: p99 drops below p99_low_s
    _observe(engine, 0, 0.01, n=256)
    assert sc.tick() == []              # quiet tick 1: streak, no action
    actions = sc.tick()                 # quiet tick 2: scale down
    assert [(a[0], a[1], a[2]) for a in actions] == [(0, "down", 1)]
    assert engine.replica_count(0) == 1
    prom = engine.obs.render_prometheus()
    assert 'pyramid_autoscaler_scale_downs_total{shard="0"} 1' in prom
    downs = [s for s in engine.tracer.snapshot()
             if s.name == "autoscaler.scale_down"]
    assert len(downs) == 1


def test_hysteresis_band_resets_the_streak(engine):
    sc = Autoscaler(engine, AutoscalerConfig(**CFG))
    _observe(engine, 0, 1.0)
    sc.tick()                           # up to 2 replicas
    sc.tick()                           # cooldown
    _observe(engine, 0, 0.01, n=256)
    assert sc.tick() == []              # quiet tick: streak = 1
    _observe(engine, 0, 0.3, n=256)     # mid-band: 0.1 < p99 < 0.5
    assert sc.tick() == []              # band tick RESETS the streak
    _observe(engine, 0, 0.01, n=256)
    assert sc.tick() == []              # streak restarts at 1
    assert engine.replica_count(0) == 2     # still scaled up
    assert sc.tick() != []              # second consecutive quiet tick
    assert engine.replica_count(0) == 1


def test_never_scales_below_min_or_above_max(engine):
    sc = Autoscaler(engine, AutoscalerConfig(**{
        **CFG, "max_replicas": 2, "cooldown_ticks": 0}))
    _observe(engine, 0, 1.0)
    assert sc.tick()                    # 1 -> 2
    assert sc.tick() == []              # at max: hot but capped
    _observe(engine, 1, 0.01, n=256)
    for _ in range(4):
        assert sc.tick() == []          # shard 1 at min_replicas: never
    assert engine.replica_count(1) == 1


def test_access_rate_triggers_scale_up_before_latency(engine):
    sc = Autoscaler(engine, AutoscalerConfig(**{
        **CFG, "access_high": 0.8}))
    # inject the routing counters directly: 90% of routes hit shard 0,
    # no latency samples at all (the hot-shard signal fires first)
    with engine._lock:
        engine._routed_queries = 100
        engine._routed_per_shard = np.array([90, 30], np.int64)
    actions = sc.tick()
    assert [(a[0], a[1], a[2]) for a in actions] == [(0, "up", 2)]
    assert "access=0.900" in actions[0][3]


def test_min_replicas_zero_rejected(engine):
    with pytest.raises(ValueError):
        Autoscaler(engine, AutoscalerConfig(min_replicas=0))


def test_stats_and_defaults_wire_to_engine_obs(engine):
    sc = Autoscaler(engine, AutoscalerConfig(**CFG))
    assert sc.obs is engine.obs
    assert sc.tracer is engine.tracer
    sc.tick()
    st = sc.stats()
    assert st["ticks"] == 1
    assert st["actions"] == []
    assert st["config"]["max_replicas"] == 3
