"""HNSW build + search correctness (recall vs brute force)."""
import numpy as np
import pytest

from repro.core import hnsw as H
from repro.core import metrics as M


def _recall(found_ids, true_ids):
    hits = 0
    for f, t in zip(found_ids, true_ids):
        hits += len(set(f.tolist()) & set(t.tolist()))
    return hits / true_ids.size


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2000, 16)).astype(np.float32)
    q = rng.normal(size=(50, 16)).astype(np.float32)
    return x, q


@pytest.mark.parametrize("metric", ["l2", "ip", "angular"])
def test_build_and_numpy_search_recall(dataset, metric):
    x, q = dataset
    x = M.preprocess_dataset(x, metric)
    q = M.preprocess_queries(q, metric)
    g = H.build_hnsw(x, metric=metric, max_degree=16, max_degree_upper=8,
                     ef_construction=60, seed=1)
    ids, _ = H.search_numpy(g, q, k=10, ef=80)
    true_ids, _ = M.brute_force_topk(q, x, 10, metric)
    assert _recall(ids, true_ids) > 0.85


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_jax_search_matches_numpy_quality(dataset, metric):
    x, q = dataset
    g = H.build_hnsw(x, metric=metric, max_degree=16, max_degree_upper=8,
                     ef_construction=60, seed=1)
    arrs = g.device_arrays()
    ids, scores = H.hnsw_search(arrs, q, metric=metric, k=10, ef=80)
    ids = np.asarray(ids)
    true_ids, true_scores = M.brute_force_topk(q, x, 10, metric)
    rec = _recall(ids, true_ids)
    assert rec > 0.85, f"jax search recall too low: {rec}"
    # scores must be self-consistent with the data
    sims = M.similarity_matrix_np(q, x, metric)
    picked = np.take_along_axis(sims, np.clip(ids, 0, None), axis=1)
    np.testing.assert_allclose(np.asarray(scores), picked, rtol=1e-4, atol=1e-4)


def test_jax_search_sorted_and_valid(dataset):
    x, q = dataset
    g = H.build_hnsw(x[:500], metric="l2", max_degree=12, max_degree_upper=6,
                     ef_construction=40, seed=2)
    ids, scores = H.hnsw_search(g.device_arrays(), q, metric="l2", k=8, ef=40)
    ids, scores = np.asarray(ids), np.asarray(scores)
    assert ids.shape == (q.shape[0], 8)
    assert (ids >= 0).all() and (ids < 500).all()
    assert (np.diff(scores, axis=1) <= 1e-5).all(), "scores must be descending"
    for row in ids:
        assert len(set(row.tolist())) == len(row), "duplicate results"
