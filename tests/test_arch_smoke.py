"""Per-architecture smoke tests: reduced variant, one forward + one decode
step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.registry import get_arch, list_archs
from repro.models.transformer import forward, init_params, make_cache

ARCHS = [
    "h2o-danube-1.8b", "zamba2-7b", "qwen3-1.7b", "phi3.5-moe-42b-a6.6b",
    "internvl2-2b", "grok-1-314b", "gemma3-12b", "mamba2-780m",
    "musicgen-medium", "chatglm3-6b",
]


def test_all_archs_registered():
    assert set(ARCHS) <= set(list_archs())


def _inputs(cfg, b, s, rng):
    if cfg.frontend:
        return jnp.asarray(
            rng.normal(size=(b, s, cfg.frontend_dim)).astype(np.float32))
    return jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, s)),
                       jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    x = _inputs(cfg, 2, 32, rng)
    logits, aux, _ = forward(params, cfg, x)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_smoke(arch):
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(1)
    params = init_params(cfg, jax.random.PRNGKey(1))
    cache = make_cache(cfg, batch=2, max_seq=16)
    pos = jnp.zeros((2,), jnp.int32)
    x = _inputs(cfg, 2, 1, rng)
    logits, _, new_cache = forward(params, cfg, x, cache=cache,
                                   decode_pos=pos)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache must be updated, not returned unchanged
    changed = jax.tree.map(
        lambda a, b: bool(np.any(np.asarray(a) != np.asarray(b))),
        cache, new_cache)
    assert any(jax.tree.leaves(changed))


def test_ring_cache_decode_matches_full_swa():
    """Sliding-window ring cache (window < seq, wraps several times) must
    reproduce full-forward SWA logits token by token."""
    import dataclasses
    cfg = dataclasses.replace(get_arch("h2o-danube-1.8b").reduced(),
                              sliding_window=8)
    rng = np.random.default_rng(9)
    params = init_params(cfg, jax.random.PRNGKey(9))
    s = 24  # 3x window: the ring wraps twice
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, s)),
                       jnp.int32)
    logits_full, _, _ = forward(params, cfg, toks, remat=False)

    cache = make_cache(cfg, batch=1, max_seq=s)
    assert cache["attention@swa"]["k"].shape[2] == 8  # ring, not max_seq
    outs = []
    for t in range(s):
        step_logits, _, cache = forward(
            params, cfg, toks[:, t: t + 1], cache=cache,
            decode_pos=jnp.full((1,), t, jnp.int32))
        outs.append(np.asarray(step_logits[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        dec, np.asarray(logits_full, np.float32), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-780m", "zamba2-7b"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode must reproduce train-mode logits (last token)."""
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(2)
    params = init_params(cfg, jax.random.PRNGKey(2))
    s = 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, s)),
                       jnp.int32)
    logits_full, _, _ = forward(params, cfg, toks, remat=False)

    cache = make_cache(cfg, batch=1, max_seq=s)
    outs = []
    for t in range(s):
        step_logits, _, cache = forward(
            params, cfg, toks[:, t: t + 1], cache=cache,
            decode_pos=jnp.full((1,), t, jnp.int32))
        outs.append(np.asarray(step_logits[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        dec, np.asarray(logits_full, np.float32), rtol=2e-2, atol=2e-2)
