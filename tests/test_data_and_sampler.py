"""Vector IO roundtrips and sampler behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.vectors import (load_dataset, read_fvecs, worker_slice,
                                write_fvecs)
from repro.serving.sampler import SamplerConfig, sample


def test_fvecs_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(37, 12)).astype(np.float32)
    path = str(tmp_path / "x.fvecs")
    write_fvecs(path, x)
    np.testing.assert_array_equal(read_fvecs(path), x)
    np.testing.assert_array_equal(read_fvecs(path, start=5, count=10),
                                  x[5:15])
    np.testing.assert_array_equal(load_dataset(path), x)


def test_worker_slices_cover_exactly():
    total = 103
    seen = []
    for w in range(8):
        s, c = worker_slice(total, w, 8)
        seen += list(range(s, s + c))
    assert seen == list(range(total))


def test_sampler_greedy_and_temperature():
    logits = jnp.asarray([[0.0, 5.0, 1.0], [3.0, 0.0, -1.0]])
    out = sample(logits, jax.random.PRNGKey(0), SamplerConfig(greedy=True))
    np.testing.assert_array_equal(np.asarray(out), [1, 0])
    # near-zero temperature ~ greedy
    out = sample(logits, jax.random.PRNGKey(0),
                 SamplerConfig(temperature=1e-4))
    np.testing.assert_array_equal(np.asarray(out), [1, 0])


def test_sampler_top_k_restricts_support():
    logits = jnp.asarray([[0.0, 10.0, 9.0, -5.0]])
    cfg = SamplerConfig(temperature=1.0, top_k=2)
    draws = {int(sample(logits, jax.random.PRNGKey(i), cfg)[0])
             for i in range(50)}
    assert draws <= {1, 2}


def test_sampler_top_p_keeps_best():
    logits = jnp.asarray([[0.0, 10.0, 1.0, 0.5]])
    cfg = SamplerConfig(top_p=0.1)  # only the argmax survives
    draws = {int(sample(logits, jax.random.PRNGKey(i), cfg)[0])
             for i in range(20)}
    assert draws == {1}
