"""Futures-based PyramidClient surface: per-query result delivery,
concurrent-session isolation (the old shared ``_done`` queue race),
``as_completed`` streaming, timeout semantics, elastic ``scale()``."""
import threading

import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.core.client import (EngineShutdownError, PyramidClient,
                               SearchFuture, as_completed)
from repro.core.meta_index import build_pyramid_index
from repro.data.synthetic import clustered_vectors, query_set
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def engine_index():
    x = clustered_vectors(1500, 12, 12, seed=0)
    cfg = PyramidConfig(metric="l2", num_shards=4, meta_size=48,
                        sample_size=800, branching_factor=2, max_degree=12,
                        max_degree_upper=6, ef_construction=40,
                        ef_search=50, kmeans_iters=6)
    return x, build_pyramid_index(x, cfg)


# ---------------------------------------------------------------------------
# SearchFuture semantics (no engine needed)
# ---------------------------------------------------------------------------


def test_future_timeout_raises_builtin_timeouterror():
    fut = SearchFuture(7)
    with pytest.raises(TimeoutError, match="query 7"):
        fut.result(timeout=0.05)
    assert not fut.done()


def test_future_result_and_callbacks():
    fut = SearchFuture(1)
    seen = []
    fut.add_done_callback(lambda f: seen.append(("early", f.query_id)))
    fut.set_result("payload")
    assert fut.done()
    assert fut.result(timeout=0) == "payload"
    assert fut.exception() is None
    # late registration fires immediately
    fut.add_done_callback(lambda f: seen.append(("late", f.query_id)))
    assert seen == [("early", 1), ("late", 1)]


def test_future_exception_propagates():
    fut = SearchFuture(2)
    fut.set_exception(EngineShutdownError("engine gone"))
    with pytest.raises(EngineShutdownError):
        fut.result(timeout=0)
    assert isinstance(fut.exception(), EngineShutdownError)


def test_as_completed_yields_in_completion_order():
    futs = [SearchFuture(i) for i in range(3)]
    futs[2].set_result("c")
    futs[0].set_result("a")

    def finish_last():
        futs[1].set_result("b")

    t = threading.Timer(0.05, finish_last)
    t.start()
    got = [f.query_id for f in as_completed(futs, timeout=5)]
    t.join()
    # already-done futures drain first; the straggler arrives last
    assert set(got[:2]) == {0, 2}
    assert got[2] == 1
    for f in as_completed(futs, timeout=1):
        assert f.done()


def test_as_completed_timeout():
    futs = [SearchFuture(0), SearchFuture(1)]
    futs[0].set_result("a")
    with pytest.raises(TimeoutError, match="1 of 2"):
        list(as_completed(futs, timeout=0.1))


# ---------------------------------------------------------------------------
# client sessions over a live engine
# ---------------------------------------------------------------------------


def test_concurrent_clients_get_only_their_own_results(engine_index):
    """Regression for the shared ``_done``-queue race: two sessions
    hammering one engine concurrently must each observe exactly their
    own queries' results. Under the old API both callers drained one
    queue, so caller A could steal (and mis-merge) caller B's batch."""
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=1)
    try:
        # each client queries exact dataset points -> its own point must
        # come back as the top-1 neighbour (distance 0)
        own = {"a": np.arange(0, 40), "b": np.arange(700, 740)}
        clients = {name: PyramidClient(eng, name=name) for name in own}
        outcome = {}
        barrier = threading.Barrier(len(own))

        def run(name):
            barrier.wait()   # maximize interleaving on the engine
            futs = clients[name].search_batch(x[own[name]], k=3)
            outcome[name] = [f.result(timeout=60) for f in futs]

        threads = [threading.Thread(target=run, args=(n,)) for n in own]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        for name, rows in outcome.items():
            assert len(rows) == len(own[name])
            top1 = np.asarray([r.ids[0] for r in rows])
            # every result belongs to this client's own queries
            assert (top1 == own[name]).mean() > 0.9
            # query ids are exactly the ones this session submitted
            assert len({r.query_id for r in rows}) == len(rows)
        a_ids = {r.query_id for r in outcome["a"]}
        b_ids = {r.query_id for r in outcome["b"]}
        assert not (a_ids & b_ids)
    finally:
        eng.shutdown()


def test_search_single_and_streaming_batch(engine_index):
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=1)
    try:
        client = PyramidClient(eng)
        res = client.search(x[5], k=4).result(timeout=60)
        assert res.ids.shape[0] == 4
        assert res.ids[0] == 5

        q = query_set(x, 16, seed=1)
        futs = client.search_batch(q, k=5)
        done = [f.result(0) for f in as_completed(futs, timeout=60)]
        assert len(done) == 16
        assert {r.query_id for r in done} == {f.query_id for f in futs}
    finally:
        eng.shutdown()


def test_scale_up_down_under_load(engine_index):
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=1)
    try:
        client = PyramidClient(eng)
        assert client.stats()["replicas"] == {0: 1, 1: 1, 2: 1, 3: 1}

        names = client.scale(0, 3)
        assert len(names) == 3
        assert eng.replica_count(0) == 3

        futs = client.search_batch(query_set(x, 32, seed=2), k=5)
        client.scale(0, 1)           # shrink while queries are in flight
        results = [f.result(timeout=60) for f in futs]
        assert len(results) == 32    # at-least-once requeue: none lost
        assert eng.replica_count(0) == 1
        stats = client.stats()
        assert stats["replicas"][0] == 1
        assert stats["submitted_queries"] >= 32
    finally:
        eng.shutdown()


def test_scale_retired_replicas_stay_down(engine_index):
    """Scale-down must deregister before killing so the monitor treats
    it as intentional (unlike failure injection, which restarts)."""
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=2, auto_restart=True)
    try:
        assert eng.replica_count(2) == 2
        eng.scale(2, 1)
        import time
        time.sleep(0.5)              # give the monitor a few periods
        assert eng.replica_count(2) == 1
    finally:
        eng.shutdown()


def test_shutdown_fails_inflight_futures(engine_index):
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=1)
    client = PyramidClient(eng)
    futs = client.search_batch(query_set(x, 8, seed=3), k=5)
    eng.shutdown()
    for f in futs:
        try:
            f.result(timeout=5)      # completed before shutdown: fine
        except EngineShutdownError:
            pass                     # failed loudly: also fine
    with pytest.raises(EngineShutdownError):
        client.search(x[0], k=3)
