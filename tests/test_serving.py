"""Serving substrate: prefill->decode consistency, engine robustness
(straggler + failure, paper Sec. IV-B), kNN-LM retrieval."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.common.registry import get_arch
from repro.core import metrics as M
from repro.core.meta_index import build_pyramid_index
from repro.data.synthetic import clustered_vectors, query_set
from repro.models.transformer import forward, grow_cache, init_params
from repro.serving.decode import decode_step, prefill_step
from repro.serving.engine import ServingEngine
from repro.serving.retrieval import (build_datastore, hidden_states,
                                     interpolate, knn_probs)


# ---------------------------------------------------------------------------
# prefill -> decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "zamba2-7b"])
def test_prefill_then_decode_matches_full(arch):
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    s = 10
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, s + 1)),
                       jnp.int32)
    # full forward over s+1 tokens = ground truth for logits at position s
    full_logits, _, _ = forward(params, cfg, toks, remat=False)

    # prefill s tokens, then decode token s
    pre_logits, cache = prefill_step(params, toks[:, :s], cfg=cfg)
    cache = grow_cache(cache, max_seq=s + 4)
    nxt, step_logits, _ = decode_step(
        params, cache, toks[:, s: s + 1],
        jnp.full((1,), s, jnp.int32), cfg=cfg)
    np.testing.assert_allclose(
        np.asarray(step_logits[0]),
        np.asarray(full_logits[0, s], np.float32), rtol=2e-2, atol=2e-2)
    # prefill logits must equal full logits at earlier positions too
    np.testing.assert_allclose(
        np.asarray(pre_logits[0, :s], np.float32),
        np.asarray(full_logits[0, :s], np.float32), rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_index():
    x = clustered_vectors(1500, 12, 12, seed=0)
    cfg = PyramidConfig(metric="l2", num_shards=4, meta_size=48,
                        sample_size=800, branching_factor=2, max_degree=12,
                        max_degree_upper=6, ef_construction=40,
                        ef_search=50, kmeans_iters=6)
    return x, build_pyramid_index(x, cfg)


def test_engine_end_to_end(engine_index):
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=1)
    try:
        q = query_set(x, 24, seed=3)
        futures = eng.submit(q, k=10)
        results = [f.result(timeout=30) for f in futures]
        assert len(results) == 24
        true_ids, _ = M.brute_force_topk(q, x, 10, "l2")
        hits = sum(
            len(set(r.ids.tolist()) & set(true_ids[i].tolist()))
            for i, r in enumerate(results))
        assert hits / true_ids.size > 0.6
        assert all(r.latency_s < 10 for r in results)
    finally:
        eng.shutdown()


def test_engine_straggler_mitigation(engine_index):
    """Replicated topics keep serving when one executor is throttled
    (paper Fig. 12 mechanism: queue rebalancing offloads the slow one)."""
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=2)
    try:
        eng.set_cpu_share("exec-s0-r0", 0.1)  # heavy straggler
        q = query_set(x, 64, seed=4)
        futures = eng.submit(q, k=5)
        results = [f.result(timeout=300) for f in futures]
        assert len(results) == len(futures)
        # the healthy replica of shard 0 must have absorbed most work
        healthy = eng.executors["exec-s0-r1"].processed
        slow = eng.executors["exec-s0-r0"].processed
        assert healthy >= slow
    finally:
        eng.shutdown()


def test_engine_failure_recovery(engine_index):
    """Kill an executor mid-stream: replica plus monitor restart keep all
    queries answered (paper Fig. 13)."""
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=2, auto_restart=True)
    try:
        q = query_set(x, 80, seed=5)
        futures = eng.submit(q[:40], k=5)
        eng.kill_executor("exec-s1-r0")
        futures += eng.submit(q[40:], k=5)
        results = [f.result(timeout=30) for f in futures]
        assert len(results) == len(futures)  # no query lost
        # monitor restarted the killed executor
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline and eng.monitor.restarts == 0:
            time.sleep(0.1)
        assert eng.monitor.restarts >= 1
    finally:
        eng.shutdown()


def test_engine_mixed_k_batches(engine_index):
    """Executors drain a topic without grouping by k: a mixed batch must
    search at max(k) and trim per request, never at batch[0].k."""
    from repro.serving.engine import QueryRequest
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=1)
    try:
        q = query_set(x, 8, seed=7)
        # deterministic unit check on the drain-batch search itself
        ex = next(iter(eng.executors.values()))
        reqs = [QueryRequest(0, q[0], 3, 1), QueryRequest(1, q[1], 9, 1),
                QueryRequest(2, q[2], 1, 1)]
        outs = ex._search(reqs)
        assert [len(ids) for ids, _ in outs] == [3, 9, 1]
        assert all(len(ids) == len(scores) for ids, scores in outs)
        # end-to-end: interleaved submits with different k
        futs_small = eng.submit(q[:4], k=2)
        futs_large = eng.submit(q[4:], k=12)
        small = [f.result(timeout=30) for f in futs_small]
        large = [f.result(timeout=30) for f in futs_large]
        assert all(len(r.ids) == 2 for r in small)
        assert all(len(r.ids) == 12 for r in large), \
            [len(r.ids) for r in large]
        for r in small + large:   # dedup + sorted per result
            assert len(set(r.ids.tolist())) == len(r.ids)
            assert (np.diff(r.scores) <= 1e-5).all()
    finally:
        eng.shutdown()


def test_engine_pending_queries_expire(engine_index):
    """A query whose shard lost every live replica must not leak in
    ``_pending`` forever: it fails with QueryExpiredError after the
    configured deadline."""
    from repro.core.client import QueryExpiredError
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=1, auto_restart=False,
                        pending_deadline_s=1.0)
    try:
        for name in list(eng.executors):   # all replica groups die
            eng.kill_executor(name)
        time.sleep(0.3)                    # let executors drain out
        futs = eng.submit(query_set(x, 4, seed=8), k=5)
        for f in futs:
            with pytest.raises(QueryExpiredError):
                f.result(timeout=10)
        assert eng.stats()["expired_queries"] == len(futs)
        assert eng.stats()["pending_queries"] == 0
    finally:
        eng.shutdown()


def test_engine_healthy_queries_unaffected_by_deadline(engine_index):
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=1, pending_deadline_s=30.0)
    try:
        futs = eng.submit(query_set(x, 8, seed=9), k=5)
        res = [f.result(timeout=30) for f in futs]
        assert len(res) == 8
        assert eng.stats()["expired_queries"] == 0
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# kNN-LM retrieval
# ---------------------------------------------------------------------------


def test_knn_lm_interpolation_improves_memorized_continuations():
    cfg = get_arch("qwen3-1.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(6)
    toks = rng.integers(0, cfg.vocab_size, size=(8, 24))
    pyr = PyramidConfig(metric="l2", num_shards=2, meta_size=16,
                        sample_size=100, branching_factor=2, max_degree=8,
                        max_degree_upper=4, ef_construction=30,
                        ef_search=40, kmeans_iters=4)
    ds = build_datastore(params, cfg, [toks], pyr)
    assert ds.values.shape[0] == 8 * 23

    # query with hidden states the datastore has seen: kNN mass must land
    # on the memorized next tokens
    hid = np.asarray(hidden_states(params, cfg, jnp.asarray(toks)),
                     np.float32)
    queries = hid[:, :-1].reshape(-1, cfg.d_model)[:16]
    gold = toks[:, 1:].reshape(-1)[:16]
    kp = knn_probs(ds, queries, k=4, vocab_size=cfg.vocab_size)
    top1 = kp.argmax(-1)
    assert (top1 == gold).mean() > 0.8

    # interpolation: log-probs well-formed
    lm_logits = rng.normal(size=(16, cfg.vocab_size)).astype(np.float32)
    lp = interpolate(lm_logits, kp, lam=0.5)
    np.testing.assert_allclose(np.exp(lp).sum(-1), 1.0, atol=1e-3)
