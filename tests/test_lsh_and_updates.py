"""LSH baseline quality + incremental index updates."""
import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.distributed import search_single_host
from repro.core.lsh import build_lsh, search_lsh
from repro.core.meta_index import build_pyramid_index
from repro.core.updates import add_items, remove_items
from repro.data.synthetic import clustered_vectors, query_set


# ---------------------------------------------------------------------------
# LSH baseline
# ---------------------------------------------------------------------------


def test_lsh_finds_near_neighbours():
    x = clustered_vectors(4000, 16, 24, seed=0)
    q = query_set(x, 40, seed=1)
    idx = build_lsh(x, metric="l2", num_shards=4, num_tables=12,
                    num_bits=8, width=3.0)
    ids, scores = search_lsh(idx, q, k=10)
    true_ids, _ = M.brute_force_topk(q, x, 10, "l2")
    hits = sum(len(set(a[a >= 0].tolist()) & set(b.tolist()))
               for a, b in zip(ids, true_ids))
    recall = hits / true_ids.size
    assert recall > 0.5, recall  # LSH is the weaker baseline, by design
    # scores must be sorted descending among valid entries
    for r_ids, r_s in zip(ids, scores):
        v = r_s[r_ids >= 0]
        assert (np.diff(v) <= 1e-5).all()


def test_lsh_recall_grows_with_tables():
    x = clustered_vectors(3000, 16, 24, seed=2)
    q = query_set(x, 30, seed=3)
    true_ids, _ = M.brute_force_topk(q, x, 10, "l2")

    def rec(num_tables):
        idx = build_lsh(x, metric="l2", num_shards=4,
                        num_tables=num_tables, num_bits=8, width=3.0)
        ids, _ = search_lsh(idx, q, k=10)
        return sum(len(set(a[a >= 0].tolist()) & set(b.tolist()))
                   for a, b in zip(ids, true_ids)) / true_ids.size

    assert rec(12) > rec(2)


# ---------------------------------------------------------------------------
# incremental updates
# ---------------------------------------------------------------------------


@pytest.fixture()
def small_index():
    x = clustered_vectors(2000, 16, 16, seed=4)
    cfg = PyramidConfig(metric="l2", num_shards=4, meta_size=48,
                        sample_size=1000, branching_factor=2,
                        max_degree=12, max_degree_upper=6,
                        ef_construction=40, ef_search=60, kmeans_iters=6)
    return x, build_pyramid_index(x, cfg)


def test_add_items_searchable(small_index):
    x, idx = small_index
    rng = np.random.default_rng(5)
    new = (x[rng.choice(2000, 50)] +
           0.02 * rng.normal(size=(50, 16))).astype(np.float32)
    before = idx.build_stats["total_stored"]
    add_items(idx, new)
    assert idx.build_stats["total_stored"] == before + 50
    # querying exactly at the new points must surface their new ids
    ids, _, _ = search_single_host(idx, new[:20], k=3)
    new_id_set = set(range(2000, 2050))
    found = sum(1 for row in ids if set(row.tolist()) & new_id_set)
    assert found >= 16, found


def test_remove_items_gone(small_index):
    x, idx = small_index
    victims = np.arange(100, 120)
    remove_items(idx, victims)
    stored = np.concatenate([g.ids for g in idx.subs])
    assert not (set(victims.tolist()) & set(stored.tolist()))
    # searches no longer return the removed ids
    ids, _, _ = search_single_host(idx, x[victims][:10], k=5)
    assert not (set(ids.reshape(-1).tolist()) & set(victims.tolist()))


def test_add_items_with_empty_shard(small_index):
    """Regression: ``add_items`` used to crash computing the next free
    id when any sub-HNSW was empty (``g.ids.max()`` on a zero-item
    shard) — skewed partitions can legitimately produce one."""
    from repro.core import hnsw as H
    x, idx = small_index
    d = x.shape[1]
    m0 = idx.subs[0].neighbors[0].shape[1]
    idx.subs[1] = H.HNSWGraph(
        data=np.zeros((0, d), np.float32),
        ids=np.zeros((0,), np.int64),
        neighbors=[np.full((0, m0), -1, np.int32)],
        levels=np.zeros((0,), np.int32), entry=-1, metric="l2")
    idx.invalidate_device_cache()
    # the max over the NON-empty shards (the emptied shard may have
    # held the global max id — those ids are gone and may be reused)
    start = max(int(g.ids.max()) for g in idx.subs if g.ids.size) + 1
    new = clustered_vectors(30, 16, 4, seed=9)
    add_items(idx, new)   # must not raise
    stored = np.concatenate([g.ids for g in idx.subs])
    assert set(range(start, start + 30)) <= set(stored.tolist())


def test_add_items_all_shards_empty_starts_at_zero():
    from repro.core import hnsw as H
    from repro.common.config import PyramidConfig as PC
    x = clustered_vectors(400, 8, 4, seed=11)
    cfg = PC(metric="l2", num_shards=2, meta_size=16, sample_size=200,
             branching_factor=1, max_degree=8, max_degree_upper=4,
             ef_construction=20, ef_search=30, kmeans_iters=3)
    idx = build_pyramid_index(x, cfg)
    m0 = idx.subs[0].neighbors[0].shape[1]
    for s in range(idx.num_shards):
        idx.subs[s] = H.HNSWGraph(
            data=np.zeros((0, 8), np.float32),
            ids=np.zeros((0,), np.int64),
            neighbors=[np.full((0, m0), -1, np.int32)],
            levels=np.zeros((0,), np.int32), entry=-1, metric="l2")
    idx.invalidate_device_cache()
    add_items(idx, x[:10])
    stored = np.concatenate([g.ids for g in idx.subs])
    assert set(stored.tolist()) == set(range(10))


def test_add_after_remove_does_not_reuse_freed_ids(small_index):
    """Regression: ids freed by remove_items must not be handed to new
    vectors — store delta replay applies the journal onto the
    *published* state, where a reused id would transiently alias two
    different vectors between the insert and tombstone records."""
    x, idx = small_index
    remove_items(idx, np.arange(1990, 2000))
    add_items(idx, clustered_vectors(5, 16, 2, seed=12))
    stored = np.concatenate([g.ids for g in idx.subs])
    new_ids = set(stored.tolist()) - set(range(2000))
    assert new_ids == set(range(2000, 2005))


def test_remove_whole_shard_never_resurfaces(small_index):
    """Regression for the ``keep[0] = True`` degenerate guard: deleting
    every item of a shard used to silently retain one. The shard must
    come out truly empty and none of the three search paths — the fused
    arena pipeline, the per-shard python loop, and the serving engine —
    may ever return a removed id."""
    from repro.core.client import gather_arrays
    from repro.core.distributed import search_single_host_python
    from repro.serving.engine import ServingEngine

    x, idx = small_index
    sizes = [g.n for g in idx.subs]
    victim_shard = int(np.argmin(sizes))
    victims = idx.subs[victim_shard].ids.copy()
    assert victims.size > 0
    remove_items(idx, victims)
    assert idx.subs[victim_shard].n == 0    # truly empty, no survivor
    gone = set(victims.tolist())
    # query AT the deleted points: the strongest bait for resurfacing
    q = x[victims[:16]]
    ids_fused, _, _ = search_single_host(idx, q, k=10)
    assert not (set(np.asarray(ids_fused).reshape(-1).tolist()) & gone)
    ids_py, _, _ = search_single_host_python(idx, q, k=10)
    assert not (set(np.asarray(ids_py).reshape(-1).tolist()) & gone)
    eng = ServingEngine(idx, replicas=1)
    try:
        ids_eng, _ = gather_arrays(eng.submit(q, k=10), 10, timeout=60)
    finally:
        eng.shutdown()
    assert not (set(np.asarray(ids_eng).reshape(-1).tolist()) & gone)


def test_update_then_quality_holds(small_index):
    x, idx = small_index
    rng = np.random.default_rng(6)
    new = clustered_vectors(200, 16, 16, seed=7)
    add_items(idx, new)
    full = np.concatenate([x, new])
    q = query_set(full, 40, seed=8)
    ids, _, _ = search_single_host(idx, q, k=10)
    true_ids, _ = M.brute_force_topk(q, full, 10, "l2")
    hits = sum(len(set(a.tolist()) & set(b.tolist()))
               for a, b in zip(ids, true_ids))
    assert hits / true_ids.size > 0.7
