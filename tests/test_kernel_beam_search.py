"""beam_search kernel: fused walk (Pallas, interpret mode) vs jnp oracle
vs numpy twin, adversarial visited-mask cases, and integration parity of
the paths that ride it (hnsw_search impl="fused"/"loop", the arena
shard_axis strategies, search_single_host vs the python oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.core import hnsw as H
from repro.core import metrics as M
from repro.core.arena import arena_search
from repro.core.distributed import (search_single_host,
                                    search_single_host_python)
from repro.core.meta_index import build_pyramid_index
from repro.core.quant import QuantParams
from repro.kernels.beam_search import (beam_impl, beam_search,
                                       beam_search_np, beam_search_pallas,
                                       beam_search_ref, beam_search_stats)

METRICS = ("l2", "ip", "angular")


def _random_case(s, n, d, c, m0, seed, quantized=False):
    """Arbitrary -1-padded adjacency over integer-grid vectors (exact in
    f32, so score comparisons tie-break identically in every impl)."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-8, 9, size=(s, n, d)).astype(np.float32)
    bottom = rng.integers(-1, n, size=(s, n, m0)).astype(np.int32)
    queries = rng.integers(-8, 9, size=(s, c, d)).astype(np.float32)
    entries = rng.integers(0, n, size=(s, c)).astype(np.int32)
    scale = zero = None
    if quantized:
        params = QuantParams.from_data(x.reshape(s * n, d))
        x = np.stack([params.quantize(x[i]) for i in range(s)])
        scale, zero = params.scale, params.zero
    return x, bottom, queries, entries, scale, zero


def _built_case(n, d, c, seed, metric, quantized=False):
    """A real HNSW graph (S=1 stack) with descend-produced entries."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    g = H.build_hnsw(x, metric=metric, max_degree=8, max_degree_upper=4,
                     ef_construction=40, seed=seed).device_arrays()
    queries = rng.normal(size=(c, d)).astype(np.float32)
    queries = np.asarray(M.preprocess_queries(queries, metric))
    entries = np.asarray(jax.vmap(
        lambda qv: H._greedy_descend(g, qv, metric, max_steps=64))(
            jnp.asarray(queries)))
    data = np.asarray(g.data)
    scale = zero = None
    if quantized:
        params = QuantParams.from_data(data)
        data = params.quantize(data)
        scale, zero = params.scale, params.zero
    return (data[None], np.asarray(g.bottom)[None], queries[None],
            entries[None], scale, zero)


def _three_way(x, bottom, queries, entries, scale, zero, *, metric, ef,
               max_iters=400, **kernel_kw):
    kw = dict(metric=metric, ef=ef, max_iters=max_iters)
    sz = {} if scale is None else dict(scale=jnp.asarray(scale),
                                       zero=jnp.asarray(zero))
    s_k, n_k = beam_search_pallas(
        jnp.asarray(x), jnp.asarray(bottom), jnp.asarray(queries),
        jnp.asarray(entries), interpret=True, **kw, **sz, **kernel_kw)
    s_k = jnp.where(n_k >= 0, s_k, -jnp.inf)  # ops-layer normalization
    s_r, n_r = beam_search_ref(
        jnp.asarray(x), jnp.asarray(bottom), jnp.asarray(queries),
        jnp.asarray(entries), **kw, **sz)
    s_n, n_n = beam_search_np(x, bottom, queries, entries, **kw,
                              scale=scale, zero=zero)
    np.testing.assert_array_equal(np.asarray(n_k), np.asarray(n_r))
    np.testing.assert_array_equal(np.asarray(n_r), n_n)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_r), s_n, rtol=1e-5,
                               atol=1e-5)
    return s_n, n_n


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("quantized", (False, True))
def test_built_graph_three_way_parity(metric, quantized):
    case = _built_case(220, 12, 9, seed=3, metric=metric,
                       quantized=quantized)
    _three_way(*case, metric=metric, ef=24)


@pytest.mark.parametrize("metric", METRICS)
def test_random_stack_three_way_parity(metric):
    case = _random_case(3, 40, 6, 5, 4, seed=17)
    _three_way(*case, metric=metric, ef=8)


def test_revisit_cycle_blocked_by_visited_mask():
    """A ring: every expansion reaches back into already-visited nodes,
    so the visited mask is what keeps the beam duplicate-free."""
    n, m0 = 6, 3
    bottom = np.full((1, n, m0), -1, np.int32)
    for i in range(n):
        bottom[0, i] = [(i + 1) % n, (i + 2) % n, -1]
    x = np.arange(n, dtype=np.float32)[None, :, None] * np.ones(
        (1, n, 3), np.float32)
    queries = np.full((1, 2, 3), 2.0, np.float32)
    entries = np.array([[0, 3]], np.int32)
    s_n, n_n = _three_way(x, bottom, queries, entries, None, None,
                          metric="l2", ef=4)
    # the walk saturates the ring: no node may appear twice in a beam
    for row in n_n.reshape(-1, 4):
        real = row[row >= 0]
        assert len(set(real.tolist())) == len(real)


def test_duplicate_neighbour_slots_stay_in_parity():
    """Duplicate slots inside ONE adjacency row both pass the visited
    test (the test precedes the mark — same as the per-query walk), so
    each impl must admit them identically, and the kernel's bitwise-OR
    visited update must not corrupt neighbouring bits."""
    n, m0 = 6, 4
    bottom = np.full((1, n, m0), -1, np.int32)
    for i in range(n):
        bottom[0, i] = [(i + 1) % n, (i + 1) % n, (i + 2) % n, -1]
    x = np.arange(n, dtype=np.float32)[None, :, None] * np.ones(
        (1, n, 3), np.float32)
    queries = np.full((1, 2, 3), 2.0, np.float32)
    entries = np.array([[0, 3]], np.int32)
    _three_way(x, bottom, queries, entries, None, None, metric="l2",
               ef=4)


def test_isolated_entry_all_padding():
    # adjacency all -1: the beam is exactly the entry node
    x = np.ones((1, 5, 2), np.float32)
    bottom = np.full((1, 5, 3), -1, np.int32)
    queries = np.zeros((1, 3, 2), np.float32)
    entries = np.array([[4, 0, 2]], np.int32)
    s_n, n_n = _three_way(x, bottom, queries, entries, None, None,
                          metric="ip", ef=4)
    np.testing.assert_array_equal(n_n[0, :, 0], entries[0])
    assert (n_n[0, :, 1:] == -1).all()
    assert np.isneginf(s_n[0, :, 1:]).all()


def test_beam_ties_break_identically():
    # duplicate vectors => exactly equal scores; every impl must place
    # tied candidates in the same beam order (stable, lowest slot first)
    n = 8
    x = np.ones((1, n, 4), np.float32)          # all rows identical
    rng = np.random.default_rng(5)
    bottom = rng.integers(-1, n, size=(1, n, 3)).astype(np.int32)
    queries = np.ones((1, 4, 4), np.float32)
    entries = np.array([[0, 3, 5, 7]], np.int32)
    _three_way(x, bottom, queries, entries, None, None, metric="l2",
               ef=5)


def test_max_iters_bound_semantics():
    # the iteration bound truncates the walk identically everywhere,
    # including max_iters=0 (beam == entry only)
    case = _random_case(2, 30, 5, 4, 4, seed=23)
    for mi in (0, 1, 3):
        _three_way(*case, metric="l2", ef=6, max_iters=mi)


def test_ef_clamped_to_graph_size():
    case = _random_case(1, 10, 4, 3, 3, seed=9)
    s_n, n_n = _three_way(*case, metric="ip", ef=64)
    assert s_n.shape == (1, 3, 10)


def test_non_dividing_block_shapes():
    # C=7 with block_q=4 pads the query axis; padded lanes must be
    # computed-and-trimmed without touching real outputs
    x, bottom, queries, entries, _, _ = _random_case(2, 25, 6, 7, 4,
                                                     seed=31)
    kw = dict(metric="l2", ef=8, max_iters=400)
    s_a, n_a = beam_search_pallas(
        jnp.asarray(x), jnp.asarray(bottom), jnp.asarray(queries),
        jnp.asarray(entries), interpret=True, block_q=4, **kw)
    s_b, n_b = beam_search_pallas(
        jnp.asarray(x), jnp.asarray(bottom), jnp.asarray(queries),
        jnp.asarray(entries), interpret=True, block_q=7, **kw)
    np.testing.assert_array_equal(np.asarray(n_a), np.asarray(n_b))
    np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_b),
                               rtol=1e-6, atol=1e-6)


def test_ops_dispatch_runs_off_tpu():
    # off-TPU the public op must route to the oracle (CPU CI) and
    # report so
    assert beam_impl() in ("pallas-kernel", "xla-oracle")
    if jax.default_backend() != "tpu":
        assert beam_impl() == "xla-oracle"
    x, bottom, queries, entries, _, _ = _random_case(1, 20, 4, 3, 3,
                                                     seed=2)
    kw = dict(metric="l2", ef=6, max_iters=400)
    s_o, n_o = beam_search(jnp.asarray(x), jnp.asarray(bottom),
                           jnp.asarray(queries), jnp.asarray(entries),
                           **kw)
    s_r, n_r = beam_search_ref(jnp.asarray(x), jnp.asarray(bottom),
                               jnp.asarray(queries),
                               jnp.asarray(entries), **kw)
    np.testing.assert_array_equal(np.asarray(n_o), np.asarray(n_r))
    np.testing.assert_array_equal(np.asarray(s_o), np.asarray(s_r))


def test_stats_counts_expansions():
    x, bottom, queries, entries, _, _ = _random_case(1, 30, 4, 4, 3,
                                                     seed=13)
    _, _, iters = beam_search_stats(x, bottom, queries, entries,
                                    metric="l2", ef=6, max_iters=400)
    assert iters.shape == (1, 4)
    assert (np.asarray(iters) >= 1).all()
    _, _, iters1 = beam_search_stats(x, bottom, queries, entries,
                                     metric="l2", ef=6, max_iters=1)
    assert (np.asarray(iters1) == 1).all()


@pytest.mark.parametrize("metric", METRICS)
def test_hnsw_search_fused_matches_loop(metric):
    rng = np.random.default_rng(41)
    x = rng.normal(size=(300, 16)).astype(np.float32)
    g = H.build_hnsw(x, metric=metric, max_degree=8, max_degree_upper=4,
                     ef_construction=40, seed=1).device_arrays()
    q = jnp.asarray(M.preprocess_queries(
        rng.normal(size=(13, 16)).astype(np.float32), metric))
    ids_l, sc_l = H.hnsw_search(g, q, metric=metric, k=10, ef=32,
                                impl="loop")
    ids_f, sc_f = H.hnsw_search(g, q, metric=metric, k=10, ef=32,
                                impl="fused")
    np.testing.assert_array_equal(np.asarray(ids_l), np.asarray(ids_f))
    np.testing.assert_array_equal(np.asarray(sc_l), np.asarray(sc_f))


def _small_index(seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(12, 16))
    asg = rng.integers(0, 12, size=1500)
    x = (centers[asg] + 0.15 * rng.normal(size=(1500, 16))).astype(
        np.float32)
    cfg = PyramidConfig(metric="l2", num_shards=4, meta_size=48,
                        sample_size=500, branching_factor=2,
                        max_degree=8, max_degree_upper=4,
                        ef_construction=40, ef_search=48, kmeans_iters=4,
                        seed=0)
    return build_pyramid_index(x, cfg), x


@pytest.mark.parametrize("dtype", ("float32", "int8"))
def test_arena_kernel_strategy_matches_vmap_and_map(dtype):
    index, x = _small_index()
    arena = index.arena(dtype)
    meta = index.meta_arrays()
    poc = jnp.asarray(index.part_of_center)
    rng = np.random.default_rng(3)
    q = jnp.asarray(M.preprocess_queries(
        rng.normal(size=(24, 16)).astype(np.float32), "l2"))
    outs = {}
    for ax in ("kernel", "vmap", "map"):
        ids, sc, _ = arena_search(arena, meta, poc, q, metric="l2",
                                  k=10, ef=48, branching_factor=2,
                                  shard_axis=ax)
        outs[ax] = (np.asarray(ids), np.asarray(sc))
    for ax in ("vmap", "map"):
        np.testing.assert_array_equal(outs["kernel"][0], outs[ax][0])
        np.testing.assert_array_equal(outs["kernel"][1], outs[ax][1])


def test_single_host_matches_python_oracle_end_to_end():
    # recall@10 through the fused default must be bit-identical to the
    # pre-kernel per-shard python oracle at the default ef
    index, x = _small_index(seed=7)
    rng = np.random.default_rng(11)
    q = rng.normal(size=(16, 16)).astype(np.float32)
    ids_f, sc_f, _ = search_single_host(index, q, k=10)
    out_py = search_single_host_python(index, q, k=10)
    np.testing.assert_array_equal(np.asarray(ids_f),
                                  np.asarray(out_py[0]))
    np.testing.assert_allclose(np.asarray(sc_f), np.asarray(out_py[1]),
                               rtol=1e-5, atol=1e-5)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:       # container without hypothesis: the
    given = None          # deterministic cases above still run

if given is not None:

    @st.composite
    def walk_case(draw):
        s = draw(st.integers(1, 2))
        n = draw(st.integers(2, 24))
        d = draw(st.integers(1, 6))
        c = draw(st.integers(1, 4))
        m0 = draw(st.integers(1, 5))
        ef = draw(st.integers(1, 8))
        seed = draw(st.integers(0, 2 ** 31 - 1))
        metric = draw(st.sampled_from(("l2", "ip")))
        return s, n, d, c, m0, ef, seed, metric

    @settings(max_examples=25, deadline=None)
    @given(walk_case())
    def test_property_three_way_parity(case):
        s, n, d, c, m0, ef, seed, metric = case
        x, bottom, queries, entries, _, _ = _random_case(
            s, n, d, c, m0, seed)
        _three_way(x, bottom, queries, entries, None, None,
                   metric=metric, ef=ef)
