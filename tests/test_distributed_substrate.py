"""Distributed substrate pieces not covered elsewhere: distributed kmeans
vs single-host, multi-shard-per-device SPMD search, sharding helpers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import sharding as S
from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.distributed import make_pyramid_search_fn, stack_shards
from repro.core.kmeans import kmeans, kmeans_distributed
from repro.core.meta_index import build_pyramid_index
from repro.data.synthetic import clustered_vectors, query_set


def test_kmeans_distributed_matches_single_host():
    x = clustered_vectors(1024, 8, 10, seed=0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    c_dist, n_dist = kmeans_distributed(
        jnp.asarray(x), 8, mesh, iters=6, seed=3)
    c_single, n_single = kmeans(x, 8, iters=6, seed=3)
    np.testing.assert_allclose(np.asarray(c_dist), c_single,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(n_dist), n_single)


def test_kmeans_more_centers_than_rows():
    """Regression: ``m > n`` used to raise inside ``jax.random.choice(
    replace=False)``; tiny samples must still yield m centers."""
    x = clustered_vectors(5, 8, 2, seed=4)
    centers, counts = kmeans(x, 8, iters=3, seed=0)
    assert centers.shape == (8, 8)
    assert np.isfinite(centers).all()
    assert int(counts.sum()) == 5
    # every row is represented among the centers (distinct-first fill)
    d2 = ((x[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
    assert (d2.min(axis=1) < 1e-8).all()


def test_kmeanspp_init_flag():
    """True k-means++ seeding behind ``init="kmeans++"``: correct shape,
    distinct centers, and no worse quantisation than uniform seeding on
    well-separated clusters."""
    x = clustered_vectors(1500, 8, 12, seed=5)

    def inertia(centers):
        d2 = ((x[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        return float(d2.min(axis=1).mean())

    c_pp, n_pp = kmeans(x, 12, iters=8, seed=1, init="kmeans++")
    assert c_pp.shape == (12, 8)
    assert len(np.unique(c_pp, axis=0)) == 12
    assert int(n_pp.sum()) == 1500
    c_uni, _ = kmeans(x, 12, iters=8, seed=1, init="uniform")
    assert inertia(c_pp) <= inertia(c_uni) * 1.5

    with pytest.raises(ValueError, match="unknown init"):
        kmeans(x, 4, iters=2, seed=0, init="bogus")


def test_spmd_search_multiple_shards_per_device():
    """w=8 shards on a 1-device model axis: the per-device shard loop."""
    x = clustered_vectors(3000, 16, 24, seed=1)
    cfg = PyramidConfig(metric="l2", num_shards=8, meta_size=64,
                        sample_size=1500, branching_factor=2,
                        max_degree=12, max_degree_upper=6,
                        ef_construction=40, ef_search=60, kmeans_iters=6)
    idx = build_pyramid_index(x, cfg)
    mesh = jax.make_mesh((1,), ("model",))
    fn = make_pyramid_search_fn(mesh, cfg, k=10, batch=32, ef=60)
    q = query_set(x, 32, seed=2)
    ids, scores = fn(stack_shards(idx), idx.meta_arrays(),
                     jnp.asarray(idx.part_of_center), jnp.asarray(q))
    true_ids, _ = M.brute_force_topk(q, x, 10, "l2")
    rec = sum(len(set(np.asarray(a).tolist()) & set(b.tolist()))
              for a, b in zip(np.asarray(ids), true_ids)) / true_ids.size
    assert rec > 0.7, rec


def test_logical_to_sharding_shaped_divisibility():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # non-divisible dim falls back to replicated without error
    sh = S.logical_to_sharding_shaped(mesh, ("model", None), (7, 4))
    assert sh.spec == jax.sharding.PartitionSpec(None, None) or \
        sh.spec == jax.sharding.PartitionSpec("model", None)  # 7 % 1 == 0
    mesh16 = jax.make_mesh((1,), ("model",))
    del mesh16


def test_moe_ff_fallback_rule():
    """grok-style: expert dim smaller than model axis moves TP to d_ff."""
    from repro.common.registry import get_arch
    from repro.train.train_step import abstract_params, param_shardings
    cfg = get_arch("grok-1-314b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ps = param_shardings(mesh, cfg, abstract_params(cfg))
    spec = ps["blocks"]["attention"]["e_gate"].spec
    # on a 1x1 mesh everything divides; the rule itself is exercised in
    # the dry-run — here we assert the spec tree builds without error
    assert len(spec) <= 4
