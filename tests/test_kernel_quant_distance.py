"""quant_distance kernel: asymmetric int8 scan vs jnp oracle vs numpy
twin, and exactness against dequantize-then-similarity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics as M
from repro.core.quant import QuantParams
from repro.kernels.quant_distance import (quant_scores, quant_scores_np,
                                          quant_scores_ref)
from repro.kernels.quant_distance.kernel import quant_distance_pallas

METRICS = ("l2", "ip", "angular")


def _case(b, n, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32) * \
        rng.uniform(0.5, 3.0, size=(1, d)).astype(np.float32)
    q = rng.normal(size=(b, d)).astype(np.float32)
    params = QuantParams.from_data(x)
    codes = params.quantize(x)
    return q, codes, params


def _three_way(q, codes, params, metric, **kernel_kw):
    s_k = quant_distance_pallas(
        jnp.asarray(q), jnp.asarray(codes), jnp.asarray(params.scale),
        jnp.asarray(params.zero), metric=metric, interpret=True,
        **kernel_kw)
    s_r = quant_scores_ref(jnp.asarray(q), jnp.asarray(codes),
                           jnp.asarray(params.scale),
                           jnp.asarray(params.zero), metric=metric)
    s_n = quant_scores_np(q, codes, params.scale, params.zero,
                          metric=metric)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_r), s_n, rtol=1e-5,
                               atol=1e-5)
    return s_n


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("b,n,d", [(5, 24, 8), (130, 70, 16), (1, 8, 4)])
def test_kernel_matches_oracle_and_numpy(metric, b, n, d):
    q, codes, params = _case(b, n, d, seed=b * n + d)
    _three_way(q, codes, params, metric)


@pytest.mark.parametrize("metric", METRICS)
def test_blocked_launch_matches_unblocked(metric):
    # shapes that do NOT divide the blocks: padding rows/cols must be
    # computed-and-trimmed without touching real outputs
    q, codes, params = _case(37, 53, 8, seed=7)
    s_small = _three_way(q, codes, params, metric, block_q=16, block_n=16)
    s_one = _three_way(q, codes, params, metric, block_q=128,
                       block_n=512)
    np.testing.assert_allclose(s_small, s_one, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("metric", METRICS)
def test_scan_equals_similarity_of_dequantized(metric):
    """The whole family must compute EXACTLY similarity(q, dequant(c))
    with the metrics module's own formulas — the contract that keeps the
    quantized walk's semantics anchored to the float path's."""
    q, codes, params = _case(9, 31, 6, seed=3)
    want = M.similarity_matrix_np(q, params.dequantize(codes), metric)
    got = quant_scores_np(q, codes, params.scale, params.zero,
                          metric=metric)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_ops_dispatch_runs_off_tpu():
    # off-TPU the public op must route to the compiled oracle (CPU CI)
    q, codes, params = _case(4, 12, 5, seed=11)
    out = quant_scores(jnp.asarray(q), jnp.asarray(codes),
                       jnp.asarray(params.scale),
                       jnp.asarray(params.zero), metric="l2")
    want = quant_scores_np(q, codes, params.scale, params.zero,
                           metric="l2")
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5,
                               atol=1e-5)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:       # container without hypothesis: the
    given = None          # deterministic cases above still run

if given is not None:

    @st.composite
    def scan_case(draw):
        b = draw(st.integers(1, 6))
        n = draw(st.integers(1, 40))
        d = draw(st.integers(1, 12))
        seed = draw(st.integers(0, 2 ** 31 - 1))
        metric = draw(st.sampled_from(METRICS))
        return b, n, d, seed, metric

    @settings(max_examples=25, deadline=None)
    @given(scan_case())
    def test_property_three_way_parity(case):
        b, n, d, seed, metric = case
        q, codes, params = _case(b, n, d, seed)
        _three_way(q, codes, params, metric)
