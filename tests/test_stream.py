"""Streaming retrieval-decode engine (`repro.serving.stream`): exact
kNN-LM semantics under continuous batching, overlap == serialized token
equality, slot recycling, backpressure, stats — and (faults lane) the
exactly-once contract under a fault storm.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.common.registry import get_arch
from repro.models.transformer import forward, init_params, make_cache
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.faults import FaultEvent, FaultSchedule
from repro.serving.retrieval import build_datastore
from repro.serving.stream import BackpressureError, StreamEngine


@pytest.fixture(scope="module")
def model():
    cfg = get_arch("qwen3-1.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def datastore(model):
    cfg, params = model
    rng = np.random.default_rng(7)
    corpus = rng.integers(0, cfg.vocab_size, size=(8, 24)).astype(np.int32)
    pyr = PyramidConfig(metric="l2", num_shards=2, meta_size=16,
                        sample_size=100, branching_factor=2, max_degree=8,
                        max_degree_upper=4, ef_construction=20, ef_search=30)
    return build_datastore(params, cfg, [corpus], pyr)


def _prompts(cfg, n, seed=0, lo=4, hi=12):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size,
                         size=int(rng.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


def _sequential_greedy(params, cfg, prompt, n_new, max_seq):
    """Reference: single-sequence greedy decode (full LM head path)."""
    cache = make_cache(cfg, 1, max_seq)
    for t in range(len(prompt)):
        logits, _, cache = forward(
            params, cfg, jnp.asarray([[int(prompt[t])]], jnp.int32),
            cache=cache, decode_pos=jnp.asarray([t], jnp.int32))
    out = [int(jnp.argmax(logits[0, 0]))]
    pos = len(prompt)
    while len(out) < n_new:
        logits, _, cache = forward(
            params, cfg, jnp.asarray([[out[-1]]], jnp.int32), cache=cache,
            decode_pos=jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return out


def _run(eng, prompts, n_new=5):
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=n_new))
    done = eng.run_until_drained()
    return {c.request_id: c for c in done}


def test_stream_no_retrieval_matches_sequential(model):
    """The engine's explicit-head decode path (skip_head hidden state
    @ lm_head) must argmax-match the in-forward head."""
    cfg, params = model
    prompts = _prompts(cfg, 4)
    with StreamEngine(params, cfg, num_slots=4, max_seq=32) as eng:
        by_id = _run(eng, prompts, n_new=5)
    assert len(by_id) == len(prompts)
    for i, p in enumerate(prompts):
        ref = _sequential_greedy(params, cfg, p, 5, 32)
        assert by_id[i].tokens == ref, (i, by_id[i].tokens, ref)


def test_stream_matches_continuous_batcher(model):
    """StreamEngine generalises ContinuousBatcher: LM-only greedy decode
    produces identical per-request tokens."""
    cfg, params = model
    prompts = _prompts(cfg, 6, seed=3)
    b = ContinuousBatcher(params, cfg, num_slots=4, max_seq=32)
    for i, p in enumerate(prompts):
        b.submit(Request(i, p, max_new_tokens=5))
    ref = {c.request_id: c.tokens for c in b.run_until_drained()}
    with StreamEngine(params, cfg, num_slots=4, max_seq=32) as eng:
        by_id = _run(eng, prompts, n_new=5)
    assert {i: c.tokens for i, c in by_id.items()} == ref


def test_stream_overlap_equals_serialized(model, datastore):
    """Double-buffered retrieval hides latency but must not change
    semantics: per-session timelines are identical either way."""
    cfg, params = model
    prompts = _prompts(cfg, 5, seed=1)
    out = {}
    for overlap in (True, False):
        with StreamEngine(params, cfg, num_slots=4, max_seq=32,
                          datastore=datastore, knn_k=4, lam=0.3,
                          overlap=overlap) as eng:
            by_id = _run(eng, prompts, n_new=6)
            assert len(by_id) == len(prompts)
        out[overlap] = {i: c.tokens for i, c in by_id.items()}
    assert out[True] == out[False]


def test_stream_retrieval_steers_decode(model, datastore):
    """kNN interpolation with a strong lam must actually change tokens
    vs the LM-only run (the datastore is real signal, not a no-op),
    and every sampled token should be covered by retrieved memories on
    this memorised corpus (knn_hit_rate == recall-equivalent)."""
    cfg, params = model
    prompts = _prompts(cfg, 3, seed=2)
    with StreamEngine(params, cfg, num_slots=2, max_seq=32) as eng:
        lm_only = {i: c.tokens for i, c in _run(eng, prompts).items()}
    with StreamEngine(params, cfg, num_slots=2, max_seq=32,
                      datastore=datastore, knn_k=8, lam=0.9) as eng:
        mixed = {i: c.tokens for i, c in _run(eng, prompts).items()}
        st = eng.stats()
    assert mixed != lm_only
    assert st["retrieval"]["lookups"] > 0
    assert st["retrieval"]["knn_hit_rate"] > 0.5


def test_stream_slot_recycling_exactly_once(model):
    """More sessions than slots, mixed prompt/output lengths: every
    session completes exactly once through recycled slots."""
    cfg, params = model
    rng = np.random.default_rng(5)
    prompts = _prompts(cfg, 9, seed=5)
    lens = [int(rng.integers(2, 7)) for _ in prompts]
    with StreamEngine(params, cfg, num_slots=2, max_seq=32) as eng:
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new_tokens=lens[i]))
        done = eng.run_until_drained()
        st = eng.stats()
    ids = [c.request_id for c in done]
    assert sorted(ids) == list(range(len(prompts)))
    assert len(set(ids)) == len(ids)
    for c in done:
        assert len(c.tokens) == lens[c.request_id]
    assert st["sessions"]["completed"] == len(prompts)
    assert st["sessions"]["active"] == 0 and st["sessions"]["queued"] == 0


def test_stream_backpressure(model):
    cfg, params = model
    prompts = _prompts(cfg, 3, seed=6)
    with StreamEngine(params, cfg, num_slots=2, max_seq=32,
                      max_queue=2) as eng:
        eng.submit(Request(0, prompts[0], max_new_tokens=2))
        eng.submit(Request(1, prompts[1], max_new_tokens=2))
        with pytest.raises(BackpressureError):
            eng.submit(Request(2, prompts[2], max_new_tokens=2))
        # draining frees queue capacity; the retried insert succeeds
        eng.generate_step()
        eng.submit(Request(2, prompts[2], max_new_tokens=2))
        done = eng.run_until_drained()
        assert eng.stats()["sessions"]["rejected"] == 1
    assert sorted(c.request_id for c in done) == [0, 1, 2]


def test_stream_rejects_bad_inputs(model):
    cfg, params = model
    with StreamEngine(params, cfg, num_slots=2, max_seq=8) as eng:
        with pytest.raises(ValueError, match="max_seq"):
            eng.prefill(Request(0, np.zeros(8, np.int32),
                                max_new_tokens=2))
        sess = eng.submit(Request(1, np.zeros(3, np.int32),
                                  max_new_tokens=2))
        with pytest.raises(ValueError, match="queued"):
            eng.insert(sess)     # double-insert
        eng.run_until_drained()
    with pytest.raises(ValueError, match="datastore"):
        StreamEngine(params, cfg, client=object())  # client sans datastore


def test_stream_stats_surface(model, datastore):
    cfg, params = model
    prompts = _prompts(cfg, 4, seed=8)
    with StreamEngine(params, cfg, num_slots=4, max_seq=32,
                      datastore=datastore, knn_k=4) as eng:
        _run(eng, prompts, n_new=4)
        st = eng.stats()
    assert st["tokens_emitted"] == 4 * len(prompts)
    assert st["tokens_per_s"] > 0
    r = st["retrieval"]
    assert r["enabled"] and r["lookups"] == st["tokens_emitted"]
    for key in ("latency_p50_s", "latency_p99_s",
                "wait_p50_s", "wait_p99_s"):
        assert np.isfinite(r[key]) and r[key] >= 0
    assert r["latency_p99_s"] >= r["latency_p50_s"]


# ---------------------------------------------------------------------------
# faults lane: streaming decode under a fault storm
# ---------------------------------------------------------------------------


@pytest.mark.faults
def test_stream_decode_under_fault_storm(model, datastore):
    """Kill one replica mid-batch and throttle another to 0.1 CPU share
    while streaming decode runs. Hedged dispatch + at-least-once requeue
    must keep the exactly-once contract: every session completes exactly
    once with per-token ids identical to the fault-free run."""
    cfg, params = model
    prompts = _prompts(cfg, 5, seed=9)
    engine_kw = dict(replicas=2, hedge=True, hedge_deadline_s=0.25,
                     auto_restart=False, executor_batch=4)

    def run(schedule):
        with StreamEngine(params, cfg, num_slots=4, max_seq=32,
                          datastore=datastore, knn_k=4, lam=0.3,
                          fault_schedule=schedule, **engine_kw) as eng:
            by_id = _run(eng, prompts, n_new=6)
            st = eng.stats()
        return {i: c.tokens for i, c in by_id.items()}, st

    clean, _ = run(None)

    storm = FaultSchedule([
        # victim dies mid-batch, drained queries in hand (requeued)
        FaultEvent(step=2, action="kill", target="exec-s0-r0",
                   when_actor="exec-s0-r0"),
        FaultEvent(step=3, action="cpu_share", target="exec-s1-r1",
                   value=0.1),
    ])
    stormy, st = run(storm)

    assert len(storm.fired) == len(storm.events)
    assert sorted(stormy) == sorted(clean)       # exactly-once, all done
    assert stormy == clean                       # per-token id parity
    assert st["sessions"]["completed"] == len(prompts)
