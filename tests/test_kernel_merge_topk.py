"""merge_topk kernel: dedup-top-k merge vs jnp oracle vs numpy twin."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.merge_topk import merge_topk, merge_topk_np, merge_topk_ref
from repro.kernels.merge_topk.kernel import merge_topk_pallas


def _random_partials(b, m, seed, n_ids=16, invalid_frac=0.2):
    rng = np.random.default_rng(seed)
    scores = rng.normal(size=(b, m)).astype(np.float32)
    ids = rng.integers(0, n_ids, size=(b, m)).astype(np.int32)
    inv = rng.random(size=(b, m)) < invalid_frac
    ids[inv] = -1
    scores[inv] = -np.inf
    return scores, ids


@pytest.mark.parametrize("b,m,k", [(5, 24, 5), (130, 40, 10), (1, 8, 8)])
def test_kernel_matches_oracle_and_numpy(b, m, k):
    scores, ids = _random_partials(b, m, seed=b * m + k)
    s_k, i_k = merge_topk_pallas(jnp.asarray(scores), jnp.asarray(ids),
                                 k=k, interpret=True)
    s_r, i_r = merge_topk_ref(jnp.asarray(scores), jnp.asarray(ids), k=k)
    s_n, i_n = merge_topk_np(scores, ids, k=k)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
    np.testing.assert_array_equal(np.asarray(i_r), i_n)
    # kernel encodes empties as a finite NEG_INF; compare on valid slots
    valid = i_n >= 0
    np.testing.assert_allclose(np.asarray(s_k)[valid],
                               np.asarray(s_r)[valid], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_r), s_n)


def test_duplicates_keep_best_occurrence():
    # id 7 appears three times; only its best score must survive
    scores = np.array([[1.0, 5.0, 3.0, 5.0, 2.0]], np.float32)
    ids = np.array([[7, 7, 4, 7, 9]], np.int32)
    s, i = merge_topk(jnp.asarray(scores), jnp.asarray(ids), k=4)
    assert np.asarray(i)[0].tolist() == [7, 4, 9, -1]
    np.testing.assert_allclose(np.asarray(s)[0][:3], [5.0, 3.0, 2.0])
    # equal-score duplicate group: deterministic (lowest position wins),
    # and identical across all three implementations
    s_n, i_n = merge_topk_np(scores, ids, k=4)
    assert i_n[0].tolist() == [7, 4, 9, -1]


def test_all_invalid_rows_and_k_wider_than_m():
    scores = np.full((3, 4), -np.inf, np.float32)
    ids = np.full((3, 4), -1, np.int32)
    s, i = merge_topk(jnp.asarray(scores), jnp.asarray(ids), k=6)
    assert (np.asarray(i) == -1).all()
    assert np.isneginf(np.asarray(s)).all()
    s_n, i_n = merge_topk_np(scores, ids, k=6)
    assert (i_n == -1).all() and np.isneginf(s_n).all()


def test_output_sorted_and_deduped():
    scores, ids = _random_partials(64, 32, seed=0, n_ids=12)
    s, i = merge_topk(jnp.asarray(scores), jnp.asarray(ids), k=10)
    s, i = np.asarray(s), np.asarray(i)
    for row_s, row_i in zip(s, i):
        valid = row_i >= 0
        assert len(set(row_i[valid].tolist())) == valid.sum()
        assert (np.diff(row_s[valid]) <= 1e-6).all()
        # -1 padding is a suffix
        assert not np.any(np.diff(valid.astype(int)) > 0)
