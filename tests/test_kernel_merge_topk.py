"""merge_topk kernel: dedup-top-k merge vs jnp oracle vs numpy twin."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.merge_topk import merge_topk, merge_topk_np, merge_topk_ref
from repro.kernels.merge_topk.kernel import merge_topk_pallas


def _random_partials(b, m, seed, n_ids=16, invalid_frac=0.2):
    rng = np.random.default_rng(seed)
    scores = rng.normal(size=(b, m)).astype(np.float32)
    ids = rng.integers(0, n_ids, size=(b, m)).astype(np.int32)
    inv = rng.random(size=(b, m)) < invalid_frac
    ids[inv] = -1
    scores[inv] = -np.inf
    return scores, ids


@pytest.mark.parametrize("b,m,k", [(5, 24, 5), (130, 40, 10), (1, 8, 8)])
def test_kernel_matches_oracle_and_numpy(b, m, k):
    scores, ids = _random_partials(b, m, seed=b * m + k)
    s_k, i_k = merge_topk_pallas(jnp.asarray(scores), jnp.asarray(ids),
                                 k=k, interpret=True)
    s_r, i_r = merge_topk_ref(jnp.asarray(scores), jnp.asarray(ids), k=k)
    s_n, i_n = merge_topk_np(scores, ids, k=k)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
    np.testing.assert_array_equal(np.asarray(i_r), i_n)
    # kernel encodes empties as a finite NEG_INF; compare on valid slots
    valid = i_n >= 0
    np.testing.assert_allclose(np.asarray(s_k)[valid],
                               np.asarray(s_r)[valid], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_r), s_n)


def test_duplicates_keep_best_occurrence():
    # id 7 appears three times; only its best score must survive
    scores = np.array([[1.0, 5.0, 3.0, 5.0, 2.0]], np.float32)
    ids = np.array([[7, 7, 4, 7, 9]], np.int32)
    s, i = merge_topk(jnp.asarray(scores), jnp.asarray(ids), k=4)
    assert np.asarray(i)[0].tolist() == [7, 4, 9, -1]
    np.testing.assert_allclose(np.asarray(s)[0][:3], [5.0, 3.0, 2.0])
    # equal-score duplicate group: deterministic (lowest position wins),
    # and identical across all three implementations
    s_n, i_n = merge_topk_np(scores, ids, k=4)
    assert i_n[0].tolist() == [7, 4, 9, -1]


def test_all_invalid_rows_and_k_wider_than_m():
    scores = np.full((3, 4), -np.inf, np.float32)
    ids = np.full((3, 4), -1, np.int32)
    s, i = merge_topk(jnp.asarray(scores), jnp.asarray(ids), k=6)
    assert (np.asarray(i) == -1).all()
    assert np.isneginf(np.asarray(s)).all()
    s_n, i_n = merge_topk_np(scores, ids, k=6)
    assert (i_n == -1).all() and np.isneginf(s_n).all()


def test_output_sorted_and_deduped():
    scores, ids = _random_partials(64, 32, seed=0, n_ids=12)
    s, i = merge_topk(jnp.asarray(scores), jnp.asarray(ids), k=10)
    s, i = np.asarray(s), np.asarray(i)
    for row_s, row_i in zip(s, i):
        valid = row_i >= 0
        assert len(set(row_i[valid].tolist())) == valid.sum()
        assert (np.diff(row_s[valid]) <= 1e-6).all()
        # -1 padding is a suffix
        assert not np.any(np.diff(valid.astype(int)) > 0)


# ---------------------------------------------------------------------------
# adversarial id-collision / tie-distance cases (deterministic)
# ---------------------------------------------------------------------------


def _three_way(scores, ids, k):
    """Run all three implementations, assert exact parity, return one.

    For k > m the inputs are padded with (-inf, -1) exactly as
    ``ops.merge_topk`` does before dispatching (the kernel and the jnp
    oracle both require k <= m)."""
    if k > scores.shape[1]:
        pad = k - scores.shape[1]
        scores = np.pad(scores, ((0, 0), (0, pad)),
                        constant_values=-np.inf)
        ids = np.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
    s_k, i_k = merge_topk_pallas(jnp.asarray(scores), jnp.asarray(ids),
                                 k=k, interpret=True)
    s_r, i_r = merge_topk_ref(jnp.asarray(scores), jnp.asarray(ids), k=k)
    s_n, i_n = merge_topk_np(scores, ids, k=k)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
    np.testing.assert_array_equal(np.asarray(i_r), i_n)
    valid = i_n >= 0
    np.testing.assert_allclose(np.asarray(s_k)[valid],
                               np.asarray(s_r)[valid], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_r), s_n)
    return s_n, i_n


def test_single_id_row_all_tied():
    # every slot is the same id at the same score: exactly one survives,
    # and the tie breaks to position 0 in all three implementations
    scores = np.full((1, 8), 2.5, np.float32)
    ids = np.full((1, 8), 3, np.int32)
    s, i = _three_way(scores, ids, k=4)
    assert i[0].tolist() == [3, -1, -1, -1]
    assert s[0][0] == 2.5


def test_hedged_duplicate_partials_change_nothing():
    """First-result-wins hedging can hand the coordinator the same
    shard partial twice (identical ids AND scores). Merging with the
    duplicate block appended must equal merging the original alone."""
    scores, ids = _random_partials(6, 20, seed=3, n_ids=8)
    dup_s = np.concatenate([scores, scores], axis=1)
    dup_i = np.concatenate([ids, ids], axis=1)
    s0, i0 = _three_way(scores, ids, k=7)
    s1, i1 = _three_way(dup_s, dup_i, k=7)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_allclose(s0, s1)


# ---------------------------------------------------------------------------
# property-based: hypothesis-generated adversarial partials
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:       # container without hypothesis: the
    given = None          # deterministic cases above still run

if given is not None:

    @st.composite
    def partials(draw):
        """Adversarial [b, m] partial lists: tiny id pool (forced
        collisions), scores from a small integer lattice (forced ties),
        and a sprinkle of invalid (-1, -inf) slots."""
        b = draw(st.integers(1, 5))
        m = draw(st.integers(1, 24))
        n_ids = draw(st.integers(1, 6))
        rows_ids = draw(st.lists(
            st.lists(st.integers(0, n_ids - 1), min_size=m, max_size=m),
            min_size=b, max_size=b))
        rows_scores = draw(st.lists(
            st.lists(st.integers(-4, 4), min_size=m, max_size=m),
            min_size=b, max_size=b))
        ids = np.asarray(rows_ids, np.int32)
        scores = np.asarray(rows_scores, np.float32)
        inv = np.asarray(draw(st.lists(
            st.lists(st.booleans(), min_size=m, max_size=m),
            min_size=b, max_size=b)))
        ids[inv] = -1
        scores[inv] = -np.inf
        k = draw(st.integers(1, m + 3))   # k > m exercises padding
        return scores, ids, k

    @settings(max_examples=25, deadline=None)
    @given(partials())
    def test_property_three_way_parity(case):
        scores, ids, k = case
        s, i = _three_way(scores, ids, k)
        for row_s, row_i in zip(s, i):
            valid = row_i >= 0
            # deduped, descending, -1/-inf padded as a suffix
            assert len(set(row_i[valid].tolist())) == int(valid.sum())
            assert (np.diff(row_s[valid]) <= 0).all()
            assert not np.any(np.diff(valid.astype(int)) > 0)
            assert np.isneginf(row_s[~valid]).all()

    @settings(max_examples=25, deadline=None)
    @given(partials(), st.integers(0, 2 ** 32 - 1))
    def test_property_duplicate_partials_are_idempotent(case, seed):
        """Appending a shuffled copy of the same partial block (the
        hedged duplicate-delivery case) never changes the merge: the
        best occurrence of every id wins regardless of arrival layout."""
        scores, ids, k = case
        perm = np.random.default_rng(seed).permutation(scores.shape[1])
        dup_s = np.concatenate([scores, scores[:, perm]], axis=1)
        dup_i = np.concatenate([ids, ids[:, perm]], axis=1)
        s0, i0 = merge_topk_np(scores, ids, k=k)
        s1, i1 = merge_topk_np(dup_s, dup_i, k=k)
        np.testing.assert_array_equal(i0, i1)
        np.testing.assert_allclose(s0, s1)
        # and the duplicated layout still holds exact 3-way parity
        _three_way(dup_s, dup_i, k)
