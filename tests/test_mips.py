"""MIPS-specific behaviour (Alg. 5): spherical partitioning, norm
replication, balanced sub-datasets, recall at K=1 (paper Fig. 10)."""
import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.distributed import search_single_host
from repro.core.meta_index import build_pyramid_index


@pytest.fixture(scope="module")
def mips_data():
    """Norm-spread data like Tiny10M: direction clusters x lognormal norms."""
    rng = np.random.default_rng(0)
    dirs = rng.normal(size=(16, 12))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    asg = rng.integers(0, 16, size=2500)
    x = dirs[asg] + 0.2 * rng.normal(size=(2500, 12))
    norms = rng.lognormal(mean=0.0, sigma=0.8, size=(2500, 1))
    x = (x * norms).astype(np.float32)
    q = rng.normal(size=(40, 12)).astype(np.float32)
    return x, q


def _build(x, r):
    cfg = PyramidConfig(metric="ip", num_shards=4, meta_size=48,
                        sample_size=1500, branching_factor=1,
                        max_degree=12, max_degree_upper=6,
                        ef_construction=40, ef_search=80,
                        replication_r=r, kmeans_iters=8)
    return build_pyramid_index(x, cfg)


def test_mips_partitions_balanced(mips_data):
    """Alg. 5 avoids the 'large norm partition attracts everything' failure."""
    x, _ = mips_data
    idx = _build(x, r=0)
    sizes = np.asarray(idx.build_stats["sub_sizes"], dtype=float)
    assert sizes.max() / sizes.mean() < 2.0, sizes


def test_mips_replication_overhead_small_but_present(mips_data):
    x, _ = mips_data
    idx = _build(x, r=30)
    total = idx.build_stats["total_stored"]
    assert total > 2500  # replication happened
    assert total < 2500 * 1.8  # memory overhead bounded (paper: ~0.6%)


def test_mips_recall_improves_with_replication(mips_data):
    x, q = mips_data
    true_ids, _ = M.brute_force_topk(q, x, 10, "ip")

    def rec(idx):
        ids, _, mask = search_single_host(idx, q, k=10)
        r = sum(len(set(a.tolist()) & set(b.tolist()))
                for a, b in zip(ids, true_ids)) / true_ids.size
        return r, mask.mean()

    r0, a0 = rec(_build(x, r=0))
    r1, a1 = rec(_build(x, r=60))
    # replication pulls large-norm items into every cone -> higher recall
    # at the same access rate (paper Fig. 10 mechanism)
    assert r1 > r0 + 0.05, (r0, r1)
    assert r1 > 0.6, r1
    assert a1 <= 0.5  # K=1 of 4 shards (+: no access-rate explosion)


def test_angular_metric_end_to_end(mips_data):
    x, q = mips_data
    cfg = PyramidConfig(metric="angular", num_shards=4, meta_size=48,
                        sample_size=1500, branching_factor=2,
                        max_degree=12, max_degree_upper=6,
                        ef_construction=40, ef_search=60, kmeans_iters=8)
    idx = build_pyramid_index(x, cfg)
    ids, _, _ = search_single_host(idx, q, k=10)
    xn = x / np.linalg.norm(x, axis=1, keepdims=True)
    qn = q / np.linalg.norm(q, axis=1, keepdims=True)
    true_ids, _ = M.brute_force_topk(qn, xn, 10, "ip")
    r = sum(len(set(a.tolist()) & set(b.tolist()))
            for a, b in zip(ids, true_ids)) / true_ids.size
    assert r > 0.6, r
