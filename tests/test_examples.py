"""Slow-lane smoke tests: the examples must actually run end-to-end.

`examples/retrieval_decode.py` is the full kNN-LM serving flow —
datastore build, context-managed client, streaming engine — so running
it is the cheapest whole-system integration check we have.
"""
import pathlib
import runpy

import pytest

pytestmark = pytest.mark.slow

EXAMPLES = pathlib.Path(__file__).resolve().parent.parent / "examples"


def test_retrieval_decode_example_runs(capsys):
    runpy.run_path(str(EXAMPLES / "retrieval_decode.py"),
                   run_name="__main__")
    out = capsys.readouterr().out
    assert "streaming decode" in out
    assert "sessions" in out


def test_continuous_batching_example_runs(capsys):
    runpy.run_path(str(EXAMPLES / "continuous_batching.py"),
                   run_name="__main__")
    out = capsys.readouterr().out
    assert "10 requests" in out
