"""Shape/dtype sweep of the topk_distance Pallas kernel vs the jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.topk_distance.kernel import topk_similarity_pallas
from repro.kernels.topk_distance.ref import topk_similarity_ref


def _check(q, x, k, metric, block_q=32, block_n=128):
    s_ref, i_ref = topk_similarity_ref(q, x, k=k, metric=metric)
    s_ker, i_ker = topk_similarity_pallas(
        q, x, k=k, metric=metric, block_q=block_q, block_n=block_n,
        interpret=True)
    # scores must match exactly at f32 tolerances; ids may differ on ties so
    # compare score-sets, then spot-check id validity by re-scoring.
    np.testing.assert_allclose(
        np.asarray(s_ker), np.asarray(s_ref), rtol=2e-4, atol=2e-4)
    sims = np.asarray(topk_similarity_ref(q, x, k=x.shape[0], metric=metric)[0])
    ids = np.asarray(i_ker)
    assert (ids >= 0).all() and (ids < x.shape[0]).all()
    rescore = np.take_along_axis(
        np.asarray(jnp.asarray(sims)), np.argsort(-sims, axis=1)[:, :1], 1)
    del rescore  # ids validity asserted above; scores checked against ref


@pytest.mark.parametrize("metric", ["l2", "ip", "angular"])
@pytest.mark.parametrize("shape", [(5, 40, 8), (17, 200, 32), (33, 513, 64)])
def test_kernel_matches_ref(metric, shape):
    b, n, d = shape
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    _check(q, x, k=min(10, n), metric=metric)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(9, 24)).astype(np.float32)).astype(dtype)
    x = jnp.asarray(rng.normal(size=(150, 24)).astype(np.float32)).astype(dtype)
    s_ref, _ = topk_similarity_ref(q, x, k=5, metric="ip")
    s_ker, _ = topk_similarity_pallas(q, x, k=5, metric="ip",
                                      block_q=8, block_n=64, interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(s_ker), np.asarray(s_ref),
                               rtol=tol, atol=tol)


def test_kernel_k_equals_one_and_blocks_bigger_than_n():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(4, 12)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(50, 12)).astype(np.float32))
    s_ref, i_ref = topk_similarity_ref(q, x, k=1, metric="l2")
    s_ker, i_ker = topk_similarity_pallas(q, x, k=1, metric="l2",
                                          block_q=8, block_n=256,
                                          interpret=True)
    np.testing.assert_allclose(np.asarray(s_ker), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_ker), np.asarray(i_ref))


def test_padding_never_returned():
    """Padded database rows (id >= n) must never appear in results."""
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(6, 16)).astype(np.float32))
    # n chosen so heavy padding exists (block_n=128 -> 78 pad rows)
    x = jnp.asarray(rng.normal(size=(50, 16)).astype(np.float32)) * 0.001
    _, ids = topk_similarity_pallas(q, x, k=20, metric="ip",
                                    block_q=8, block_n=128, interpret=True)
    ids = np.asarray(ids)
    assert (ids < 50).all() and (ids >= 0).all()
