"""Training substrate: optimizer math, loss decreases, checkpoint roundtrip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.registry import get_arch
from repro.data.synthetic import SyntheticLM
from repro.models.transformer import init_params
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optimizer import (AdamWConfig, adamw_update,
                                   init_opt_state, schedule)
from repro.train.train_step import make_train_step, init_sharded

# multi-arch training loops: slow CI lane, not the fast PR lane
pytestmark = pytest.mark.slow


def test_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9
    assert abs(lrs[10] - 1e-3) < 1e-4
    assert lrs[-1] < lrs[50] < lrs[11]
    assert lrs[-1] >= 1e-4 - 1e-6  # min_lr_frac floor


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, min_lr_frac=1.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    target = jnp.asarray([1.0, 1.0])
    state = init_opt_state(params)
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(cfg, params, g, state)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                               atol=0.05)


def test_grad_clip_applies():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0,
                      warmup_steps=0, min_lr_frac=1.0)
    params = {"w": jnp.zeros(4)}
    g = {"w": jnp.full((4,), 100.0)}
    state = init_opt_state(params)
    new, _, stats = adamw_update(cfg, params, g, state)
    assert float(stats["grad_norm"]) == pytest.approx(200.0)
    assert np.abs(np.asarray(new["w"])).max() <= 1.5  # bounded step


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-780m",
                                  "phi3.5-moe-42b-a6.6b"])
def test_train_loss_decreases(arch):
    cfg = get_arch(arch).reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt_cfg = AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=120,
                          weight_decay=0.0)
    step_fn, _ = make_train_step(mesh, cfg, opt_cfg)
    params, opt_state = init_sharded(mesh, cfg, seed=0)
    data = iter(SyntheticLM(cfg, batch=8, seq_len=32, seed=0))
    losses = []
    for i in range(60):
        b = next(data)
        batch = {"inputs": jnp.asarray(b.inputs),
                 "targets": jnp.asarray(b.targets),
                 "mask": jnp.asarray(b.mask)}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_arch("qwen3-1.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_opt_state(params)
    save_checkpoint(str(tmp_path / "ck"), params, state, step=7)
    p2, s2, step = load_checkpoint(str(tmp_path / "ck"), params, state)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, p2)
    assert int(s2.step) == 7
