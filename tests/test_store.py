"""Versioned index store (repro.store): publish/load parity across all
three metrics, checksum rejection, concurrent-publish atomicity, the
pickle-migration shim, delta-log replay, GC — and store-backed engine
crash recovery driven by a deterministic FaultSchedule."""
import os
import pickle
import threading
import time

import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.distributed import search_single_host
from repro.core.meta_index import build_pyramid_index
from repro.core.updates import add_items
from repro.data.synthetic import (clustered_vectors, norm_spread_vectors,
                                  query_set)
from repro.store import IndexStore, StoreCorruptionError, StoreError


def _cfg(metric):
    return PyramidConfig(
        metric=metric, num_shards=4, meta_size=32, sample_size=400,
        branching_factor=2, max_degree=10, max_degree_upper=5,
        ef_construction=30, ef_search=40, kmeans_iters=4,
        replication_r=30 if metric == "ip" else 0)


@pytest.fixture(scope="module")
def built():
    """(x, queries, index) per metric — built once for the module."""
    out = {}
    for metric in ("l2", "angular", "ip"):
        if metric == "ip":
            x = norm_spread_vectors(700, 12, 8, seed=2)
            q = np.random.default_rng(3).normal(
                size=(12, 12)).astype(np.float32)
        else:
            x = clustered_vectors(700, 12, 8, seed=0)
            q = query_set(x, 12, seed=1)
        out[metric] = (x, q, build_pyramid_index(x, _cfg(metric)))
    return out


# ---------------------------------------------------------------------------
# round-trip parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["l2", "angular", "ip"])
def test_publish_load_search_parity(built, metric, tmp_path):
    """Loaded index answers bit-identically to the in-memory one."""
    x, q, index = built[metric]
    store = IndexStore(str(tmp_path))
    vid = store.publish(index)
    assert store.latest() == vid
    loaded = store.load()
    assert loaded.config == index.config
    np.testing.assert_array_equal(loaded.part_of_center,
                                  index.part_of_center)
    ids_a, sc_a, _ = search_single_host(index, q, k=5)
    ids_b, sc_b, _ = search_single_host(loaded, q, k=5)
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_array_equal(sc_a, sc_b)


def test_reader_lazy_shard_parity(built, tmp_path):
    """An executor can fetch ONLY its shard — and gets the same graph."""
    _, _, index = built["l2"]
    store = IndexStore(str(tmp_path))
    store.publish(index)
    reader = store.reader()
    assert reader.num_shards == index.num_shards
    g = reader.load_shard(2)
    np.testing.assert_array_equal(g.ids, index.subs[2].ids)
    np.testing.assert_array_equal(g.data, index.subs[2].data)
    assert g.entry == index.subs[2].entry
    assert len(g.neighbors) == len(index.subs[2].neighbors)


def test_empty_store_raises(tmp_path):
    with pytest.raises(StoreError, match="no published"):
        IndexStore(str(tmp_path)).load()


# ---------------------------------------------------------------------------
# corruption & atomicity
# ---------------------------------------------------------------------------


def test_corrupted_segment_is_rejected(built, tmp_path):
    _, _, index = built["l2"]
    store = IndexStore(str(tmp_path))
    vid = store.publish(index)
    seg = os.path.join(store.version_dir(vid), "shard-0001.npz")
    blob = bytearray(open(seg, "rb").read())
    mid = len(blob) // 2
    blob[mid:mid + 64] = bytes(b ^ 0xFF for b in blob[mid:mid + 64])
    with open(seg, "wb") as f:
        f.write(blob)
    with pytest.raises(StoreCorruptionError):
        store.load()
    # other shards still load lazily; only the stomped one rejects
    reader = store.reader()
    reader.load_shard(0)
    with pytest.raises(StoreCorruptionError):
        reader.load_shard(1)


def test_concurrent_publish_atomicity(built, tmp_path):
    """Two racing publishers both land complete, distinct versions."""
    _, q, index = built["l2"]
    store = IndexStore(str(tmp_path))
    barrier = threading.Barrier(2)
    got, errs = [], []

    def publisher():
        try:
            barrier.wait(timeout=30)
            got.append(IndexStore(str(tmp_path)).publish(index))
        except Exception as e:   # pragma: no cover - failure detail
            errs.append(e)

    ts = [threading.Thread(target=publisher) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert not errs
    assert len(set(got)) == 2            # distinct version ids claimed
    assert sorted(store.versions()) == sorted(got)
    assert store.latest() in got         # CURRENT points at a winner
    loaded = store.load()                # and it is complete
    ids_a, _, _ = search_single_host(index, q, k=5)
    ids_b, _, _ = search_single_host(loaded, q, k=5)
    np.testing.assert_array_equal(ids_a, ids_b)
    # no half-written tmpdirs left behind
    assert not [n for n in os.listdir(str(tmp_path))
                if n.startswith(".tmp-")]


def test_pickle_migration_shim(built, tmp_path):
    """Seed-era ``index.pkl`` dirs still load (with a deprecation
    warning), and ``save_index`` now publishes store versions."""
    from repro.launch.build_index import load_index, save_index
    x, q, index = built["l2"]
    legacy = tmp_path / "legacy"
    legacy.mkdir()
    with open(legacy / "index.pkl", "wb") as f:
        pickle.dump(index, f)
    with pytest.warns(DeprecationWarning, match="legacy pickle"):
        loaded = load_index(str(legacy))
    ids_a, _, _ = search_single_host(index, q, k=5)
    ids_b, _, _ = search_single_host(loaded, q, k=5)
    np.testing.assert_array_equal(ids_a, ids_b)
    # the deprecated writer produces the NEW format
    with pytest.warns(DeprecationWarning, match="save_index"):
        save_index(index, str(tmp_path / "migrated"))
    assert IndexStore(str(tmp_path / "migrated")).versions()
    ids_c, _, _ = search_single_host(
        load_index(str(tmp_path / "migrated")), q, k=5)
    np.testing.assert_array_equal(ids_a, ids_c)
    # save/load round-trip ON the legacy dir must return the fresh
    # publish, never the stale pickle (which is moved aside)
    fresh = build_pyramid_index(x + 25.0, _cfg("l2"))
    with pytest.warns(DeprecationWarning, match="save_index"):
        save_index(fresh, str(legacy))
    assert not (legacy / "index.pkl").exists()
    reloaded = load_index(str(legacy))
    np.testing.assert_array_equal(
        reloaded.subs[0].data, fresh.subs[0].data)


# ---------------------------------------------------------------------------
# delta log
# ---------------------------------------------------------------------------


def test_delta_log_replay_parity(built, tmp_path):
    """Post-publish inserts are journaled and replayed on load — the
    reloaded index is bit-identical to the in-memory one."""
    x, q, index = built["l2"]
    store = IndexStore(str(tmp_path))
    store.publish(index)
    assert index.delta_log() is not None
    extra = clustered_vectors(40, 12, 4, seed=9)
    add_items(index, extra)
    extra2 = clustered_vectors(16, 12, 2, seed=10)
    add_items(index, extra2)
    assert len(index.delta_log()) == 2
    loaded = store.load()
    ids_a, sc_a, _ = search_single_host(index, q, k=5)
    ids_b, sc_b, _ = search_single_host(loaded, q, k=5)
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_array_equal(sc_a, sc_b)
    # the inserted ids are really in the reloaded index
    all_ids = np.concatenate([g.ids for g in loaded.subs])
    assert int(all_ids.max()) >= len(x) + len(extra) + len(extra2) - 1
    # replay does not re-journal: the log is still 2 records long
    assert len(loaded.delta_log()) == 2


def test_uncommitted_delta_record_is_ignored(built, tmp_path):
    """A crash between record write and LOG append leaves an orphan
    file; replay must skip it (the LOG line is the commit point)."""
    _, q, index = built["l2"]
    store = IndexStore(str(tmp_path))
    vid = store.publish(index)
    delta_dir = os.path.join(store.version_dir(vid), "delta")
    os.makedirs(delta_dir, exist_ok=True)
    np.savez(os.path.join(delta_dir, "d000001.npz"),
             vectors=np.zeros((3, 12), np.float32),
             ids=np.arange(3, dtype=np.int64))   # never committed
    loaded = store.load()
    ids_a, _, _ = search_single_host(index, q, k=5)
    ids_b, _, _ = search_single_host(loaded, q, k=5)
    np.testing.assert_array_equal(ids_a, ids_b)
    # the next committed append must not collide with the orphan name
    add_items(index, clustered_vectors(8, 12, 2, seed=12))
    assert len(index.delta_log()) == 1
    store.load()   # replays cleanly


def test_torn_log_tail_is_healed_on_next_append(built, tmp_path):
    """A crash can tear the LOG's final line; the next append must not
    glue its record onto the fragment (which would silently drop a
    committed insert from every future replay)."""
    _, q, index = built["l2"]
    store = IndexStore(str(tmp_path))
    vid = store.publish(index)
    add_items(index, clustered_vectors(10, 12, 2, seed=13))
    log_path = os.path.join(store.version_dir(vid), "delta", "LOG")
    with open(log_path, "a") as f:
        f.write('{"file": "d9')   # torn fragment, no trailing newline
    index.delta_log()._count = None   # fresh process: no cached count
    add_items(index, clustered_vectors(6, 12, 2, seed=14))
    assert len(index.delta_log()) == 2   # both records committed
    loaded = store.load()
    ids_a, _, _ = search_single_host(index, q, k=5)
    ids_b, _, _ = search_single_host(loaded, q, k=5)
    np.testing.assert_array_equal(ids_a, ids_b)


def test_delta_replay_parity_float64_angular(tmp_path):
    """Regression: float64 input on an angular index must replay
    bit-identically (the journal stores float32 — the apply path has to
    cast before normalising, not after)."""
    x = clustered_vectors(500, 12, 6, seed=21)
    index = build_pyramid_index(x, _cfg("angular"))
    store = IndexStore(str(tmp_path))
    store.publish(index)
    extra = np.random.default_rng(5).normal(size=(20, 12))   # float64
    add_items(index, extra)
    loaded = store.load()
    q = query_set(x, 10, seed=22)
    ids_a, sc_a, _ = search_single_host(index, q, k=5)
    ids_b, sc_b, _ = search_single_host(loaded, q, k=5)
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_array_equal(sc_a, sc_b)


def test_newlineless_tail_is_uncommitted_everywhere(built, tmp_path):
    """The trailing newline is THE commit point: a crash that persists
    a parseable line without its newline must be treated as uncommitted
    by replay AND by the healer — never replayed once then erased."""
    _, _, index = built["l2"]
    store = IndexStore(str(tmp_path))
    vid = store.publish(index)
    add_items(index, clustered_vectors(8, 12, 2, seed=30))
    log_path = os.path.join(store.version_dir(vid), "delta", "LOG")
    with open(log_path, "rb") as f:
        body = f.read()
    with open(log_path, "wb") as f:
        f.write(body.rstrip(b"\n"))   # the crash ate the newline
    assert len(store.reader().delta_log()) == 0   # not committed
    idx2 = store.load()               # replays nothing — consistent
    add_items(idx2, clustered_vectors(4, 12, 2, seed=31))
    assert len(idx2.delta_log()) == 1   # healed tail + one new record
    again = store.load()
    ids_a, _, _ = search_single_host(idx2, query_set(
        np.asarray(idx2.subs[0].data), 6, seed=32), k=5)
    ids_b, _, _ = search_single_host(again, query_set(
        np.asarray(idx2.subs[0].data), 6, seed=32), k=5)
    np.testing.assert_array_equal(ids_a, ids_b)


def test_append_to_gcd_version_fails_loudly(built, tmp_path):
    """An index attached to a version that GC deleted must not journal
    ghost records into a recreated directory nothing can replay."""
    _, _, index = built["l2"]
    store = IndexStore(str(tmp_path))
    store.publish(index)              # index attached to v1's log
    idx2 = store.load()
    store.publish(idx2)               # v2 published
    store.gc(keep=1)                  # v1 deleted
    with pytest.raises(StoreError, match="gone"):
        add_items(index, clustered_vectors(5, 12, 2, seed=33))
    assert len(store.versions()) == 1   # no ghost v1 dir resurrected


# ---------------------------------------------------------------------------
# versioning & GC
# ---------------------------------------------------------------------------


def test_gc_keeps_current_and_newest(built, tmp_path):
    _, q, index = built["l2"]
    store = IndexStore(str(tmp_path))
    vids = [store.publish(index) for _ in range(3)]
    assert store.versions() == vids
    removed = store.gc(keep=1)
    assert removed == vids[:2]
    assert store.versions() == [vids[-1]]
    assert store.latest() == vids[-1]
    store.load()
    with pytest.raises(ValueError):
        store.gc(keep=0)


def test_publish_keep_runs_gc(built, tmp_path):
    _, _, index = built["l2"]
    store = IndexStore(str(tmp_path))
    for _ in range(3):
        store.publish(index, keep=2)
    assert len(store.versions()) == 2


def test_gc_spares_fresh_tmpdirs(built, tmp_path):
    """A fresh ``.tmp-`` dir may be a concurrent publish still writing;
    gc must only sweep STALE orphans."""
    _, _, index = built["l2"]
    store = IndexStore(str(tmp_path))
    store.publish(index)
    fresh = tmp_path / ".tmp-inflight"
    fresh.mkdir()
    stale = tmp_path / ".tmp-crashed"
    stale.mkdir()
    old = time.time() - 2 * IndexStore.ORPHAN_GRACE_S
    os.utime(stale, (old, old))
    store.gc(keep=1)
    assert fresh.exists(), "gc deleted a possibly-live publish tmpdir"
    assert not stale.exists(), "gc left a stale crash orphan"


def test_current_flip_is_newest_wins(built, tmp_path):
    """A publisher descheduled between claiming its version and flipping
    CURRENT must not roll CURRENT back over a newer publish."""
    _, _, index = built["l2"]
    store = IndexStore(str(tmp_path))
    v1 = store.publish(index)
    v2 = store.publish(index)
    assert store.latest() == v2
    store._set_current(v1)   # the late, stale flip
    assert store.latest() == v2


def test_latest_falls_back_without_current(built, tmp_path):
    """Crash between the version rename and the CURRENT flip: the
    publish must still be discoverable."""
    _, _, index = built["l2"]
    store = IndexStore(str(tmp_path))
    vid = store.publish(index)
    os.remove(os.path.join(str(tmp_path), "CURRENT"))
    assert store.latest() == vid
    store.load()


# ---------------------------------------------------------------------------
# engine crash recovery (deterministic FaultSchedule, ROADMAP testing guide)
# ---------------------------------------------------------------------------


def _recall(results, queries, corpus, k=10):
    true_ids, _ = M.brute_force_topk(queries, corpus, k, "l2")
    hits = sum(len(set(r.ids.tolist()) & set(true_ids[i].tolist()))
               for i, r in enumerate(results))
    return hits / true_ids.size


@pytest.mark.faults
def test_engine_crash_recovers_from_store(tmp_path):
    """The acceptance scenario: publish -> serve (through a scripted
    mid-batch kill storm) -> hard crash -> ``ServingEngine.from_store``
    reopens the published version, replays the post-publish delta log,
    and answers within 2% recall of the pre-crash engine."""
    from repro.serving.engine import ServingEngine
    from repro.serving.faults import FaultEvent, FaultSchedule

    x = clustered_vectors(1200, 12, 10, seed=0)
    index = build_pyramid_index(x, _cfg("l2"))
    store = IndexStore(str(tmp_path / "store"))
    store.publish(index)

    # post-publish inserts ride the delta log, not a new version
    extra = clustered_vectors(60, 12, 4, seed=7)
    add_items(index, extra)
    corpus = np.concatenate([x, extra])
    q = query_set(corpus, 32, seed=11)

    storm = FaultSchedule([
        FaultEvent(step=2, action="kill", target="exec-s*-r0"),
    ])
    eng = ServingEngine(index, replicas=2, executor_batch=4,
                        fault_schedule=storm,
                        monitor_opts={"backoff_base_s": 0.02,
                                      "period_s": 0.05})
    try:
        futs = eng.submit(q, k=10)
        pre = [f.result(timeout=60) for f in futs]
        assert [r.query_id for r in pre] == [f.query_id for f in futs]
        assert storm.done()
    finally:
        eng.shutdown()   # the crash: host gone, in-memory index lost
    recall_pre = _recall(pre, q, corpus)

    eng2 = ServingEngine.from_store(str(tmp_path / "store"), replicas=1)
    try:
        post = [f.result(timeout=60) for f in eng2.submit(q, k=10)]
    finally:
        eng2.shutdown()
    recall_post = _recall(post, q, corpus)
    assert abs(recall_post - recall_pre) <= 0.02, \
        f"recovered recall {recall_post:.3f} vs pre-crash {recall_pre:.3f}"
    # the delta-logged inserts survived the crash
    recovered_ids = set()
    for r in post:
        recovered_ids.update(int(i) for i in r.ids)
    assert any(i >= len(x) for i in recovered_ids), \
        "no post-publish insert came back after recovery"
