"""Unit tests for the observability layer (``repro.obs``): metrics
registry rendering/snapshot semantics, tracer causality + Chrome
export, the stats HTTP server, the LatencyTracker edge cases the
hedging machinery depends on, and compactor counter parity.
"""
import json
import threading
import urllib.request

import numpy as np
import pytest

from repro.common.utils import nearest_rank
from repro.obs import (LATENCY_BUCKETS, MetricsRegistry, NULL_TRACER,
                       StatsServer, Tracer, validate_chrome_trace)
from repro.serving.engine import LatencyTracker


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------


def test_counter_inc_and_render():
    reg = MetricsRegistry()
    c = reg.counter("widgets_total", "widgets made")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    text = reg.render_prometheus()
    assert "# TYPE widgets_total counter" in text
    assert "# HELP widgets_total widgets made" in text
    assert "widgets_total 3.5" in text


def test_labeled_counter_children_are_cached():
    reg = MetricsRegistry()
    c = reg.counter("hits_total", "hits", labelnames=("shard",))
    a = c.labels(shard="0")
    b = c.labels(shard="0")
    assert a is b                      # hot path: no per-call allocation
    a.inc(3)
    c.labels(shard="1").inc()
    text = reg.render_prometheus()
    assert 'hits_total{shard="0"} 3' in text
    assert 'hits_total{shard="1"} 1' in text


def test_gauge_set_and_lazy_fn():
    reg = MetricsRegistry()
    g = reg.gauge("depth", "queue depth")
    g.set(7)
    assert "depth 7" in reg.render_prometheus()
    reg.gauge("lazy_depth", "scraped lazily", fn=lambda: 42)
    reg.gauge("lazy_by", "labeled lazy", labelnames=("shard",),
              fn=lambda: {("0",): 1.5, ("1",): 2.5})
    text = reg.render_prometheus()
    assert "lazy_depth 42" in text
    assert 'lazy_by{shard="0"} 1.5' in text
    assert 'lazy_by{shard="1"} 2.5' in text


def test_histogram_cumulative_buckets_and_sum():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    text = reg.render_prometheus()
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 3' in text       # cumulative
    assert 'lat_seconds_bucket{le="+Inf"} 4' in text
    assert "lat_seconds_count 4" in text
    assert "lat_seconds_sum 6.05" in text
    assert LATENCY_BUCKETS == tuple(sorted(LATENCY_BUCKETS))


def test_registration_is_idempotent_and_typechecked():
    reg = MetricsRegistry()
    a = reg.counter("again_total", "x")
    b = reg.counter("again_total", "x")
    assert a is b                       # hot-swapped engines re-register
    with pytest.raises(ValueError):
        reg.gauge("again_total", "x")   # same name, different kind
    reg.counter("lbl_total", "x", labelnames=("shard",))
    with pytest.raises(ValueError):
        reg.counter("lbl_total", "x", labelnames=("replica",))


def test_disabled_registry_is_inert():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("noop_total", "x")
    c.inc(99)
    c.labels(shard="0").inc()
    assert c.value == 0.0
    reg.histogram("h", "x").observe(1.0)
    reg.gauge("g", "x").set(5)
    assert reg.render_prometheus().strip() == ""
    assert reg.snapshot() == {}


def test_snapshot_is_json_serializable():
    reg = MetricsRegistry()
    reg.counter("a_total", "x", labelnames=("shard",)).labels(
        shard="0").inc()
    reg.histogram("b_seconds", "x").observe(0.2)
    reg.gauge("c", "x").set(1)
    payload = json.loads(json.dumps(reg.snapshot()))
    assert payload["a_total"]["type"] == "counter"
    assert payload["b_seconds"]["series"][0]["count"] == 1


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


def test_span_nesting_supplies_parent():
    tr = Tracer()
    with tr.span("outer") as outer:
        with tr.span("inner"):
            pass
    spans = {s.name: s for s in tr.snapshot()}
    assert spans["inner"].parent_id == outer.span_id
    assert spans["outer"].parent_id is None
    assert spans["inner"].t1 >= spans["inner"].t0


def test_explicit_parent_crosses_threads():
    tr = Tracer()
    root = tr.start("query", qid=7)

    def other():
        tr.instant("hedge.redispatch", parent=root.span_id, qid=7)

    t = threading.Thread(target=other)
    t.start()
    t.join()
    tr.end(root)
    by_name = {s.name: s for s in tr.snapshot()}
    hedge = by_name["hedge.redispatch"]
    assert hedge.parent_id == root.span_id
    assert hedge.thread != by_name["query"].thread
    assert hedge.t0 == hedge.t1         # instant: zero duration


def test_ring_buffer_caps_span_history():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant("tick", i=i)
    kept = tr.snapshot()
    assert len(kept) == 4
    assert [s.attrs["i"] for s in kept] == [6, 7, 8, 9]   # oldest drop


def test_injected_clock_makes_timestamps_deterministic():
    ticks = iter(float(t) for t in range(100))
    tr = Tracer(clock=lambda: next(ticks))
    with tr.span("a"):
        pass
    (span,) = tr.snapshot()
    assert (span.t0, span.t1) == (1.0, 2.0)   # 0.0 is the origin


def test_chrome_trace_schema_and_causality_args():
    tr = Tracer()
    with tr.span("parent") as p:
        with tr.span("child", shard=3):
            pass
    tr.instant("mark")
    payload = tr.chrome_trace()
    validate_chrome_trace(payload)
    events = {e["name"]: e for e in payload["traceEvents"]}
    assert events["child"]["args"]["parent_id"] == p.span_id
    assert events["child"]["args"]["shard"] == 3
    assert events["child"]["ph"] == "X"
    assert events["mark"]["ph"] == "i"
    assert events["thread_name"]["ph"] == "M"


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace({"events": []})
    with pytest.raises(ValueError):
        validate_chrome_trace(
            {"traceEvents": [{"name": "x", "ph": "X", "pid": 1,
                              "tid": 1, "ts": 0.0}]})    # X without dur
    with pytest.raises(ValueError):
        validate_chrome_trace(
            {"traceEvents": [{"name": "x", "ph": "B", "pid": 1,
                              "tid": 1, "ts": 0.0}]})    # unsupported ph


def test_null_tracer_is_inert_but_usable():
    with NULL_TRACER.span("x", a=1) as s:
        s.set(b=2)                      # must not pollute shared attrs
        assert s.span_id is None
        assert s.attrs == {}
    NULL_TRACER.instant("y")
    NULL_TRACER.end(NULL_TRACER.start("z"))
    assert NULL_TRACER.snapshot() == []


def test_disabled_tracer_records_nothing_until_enabled():
    tr = Tracer(enabled=False)
    with tr.span("a"):
        pass
    assert tr.snapshot() == []
    tr.enabled = True                   # the obs-overhead gate's toggle
    with tr.span("b"):
        pass
    assert [s.name for s in tr.snapshot()] == ["b"]


# ---------------------------------------------------------------------------
# StatsServer
# ---------------------------------------------------------------------------


def _get(url):
    with urllib.request.urlopen(url, timeout=10) as r:
        return r.read().decode()


def test_stats_server_serves_metrics_stats_healthz():
    reg = MetricsRegistry()
    reg.counter("served_total", "x").inc(3)
    with StatsServer(reg, host="127.0.0.1", port=0) as srv:
        base = f"http://127.0.0.1:{srv.port}"
        assert "served_total 3" in _get(f"{base}/metrics")
        srv.add_stats_provider(
            "engine", lambda: {"qps": np.float64(1.5),
                               "shards": np.arange(2)})
        stats = json.loads(_get(f"{base}/stats"))
        assert stats["engine"] == {"qps": 1.5, "shards": [0, 1]}
        assert "ok" in _get(f"{base}/healthz")
    srv.stop()                          # idempotent


# ---------------------------------------------------------------------------
# LatencyTracker edge cases (the hedge machinery's quantile source)
# ---------------------------------------------------------------------------


def test_tracker_window_evicts_at_exactly_window():
    t = LatencyTracker(window=8, min_samples=1)
    for _ in range(8):
        t.observe(0, 1.0)
    assert t.quantile(0, 100.0) == 1.0
    assert t.snapshot()[0]["n"] == 8
    t.observe(0, 2.0)                   # 9th sample evicts the oldest
    assert t.snapshot()[0]["n"] == 8    # still exactly `window`
    assert t.quantile(0, 100.0) == 2.0


def test_tracker_min_samples_boundary():
    t = LatencyTracker(window=64, min_samples=8)
    for _ in range(7):
        t.observe(1, 0.5)
    assert t.quantile(1, 99.0) is None      # 7 < min_samples
    t.observe(1, 0.5)
    assert t.quantile(1, 99.0) == 0.5       # exactly min_samples
    assert t.quantile(2, 99.0) is None      # untouched shard


def test_tracker_quantile_matches_numpy_inverted_cdf():
    rng = np.random.default_rng(5)
    t = LatencyTracker(window=256, min_samples=1)
    xs = rng.exponential(0.01, size=100)
    for v in xs:
        t.observe(0, float(v))
    for q in (1.0, 50.0, 90.0, 99.0, 100.0):
        want = float(np.percentile(xs, q, method="inverted_cdf"))
        assert t.quantile(0, q) == want
        assert nearest_rank(sorted(xs.tolist()), q) == want


def test_tracker_concurrent_observe_and_snapshot():
    t = LatencyTracker(window=128, min_samples=1)
    stop = threading.Event()
    errors = []

    def writer(shard):
        i = 0
        while not stop.is_set():
            t.observe(shard, 0.001 * (i % 50 + 1))
            i += 1

    def reader():
        try:
            while not stop.is_set():
                t.quantile(0, 99.0)
                t.snapshot()
        except Exception as e:          # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(s,))
               for s in (0, 1)] + [threading.Thread(target=reader)]
    for th in threads:
        th.start()
    import time
    time.sleep(0.3)
    stop.set()
    for th in threads:
        th.join(timeout=10)
    assert not errors
    snap = t.snapshot()
    assert snap[0]["n"] <= 128 and snap[1]["n"] <= 128
    assert t.quantile(0, 50.0) is not None


# ---------------------------------------------------------------------------
# Compactor counter parity (registry IS the bookkeeping)
# ---------------------------------------------------------------------------


def test_compactor_counters_match_stats(tmp_path):
    from repro.common.config import PyramidConfig
    from repro.core.meta_index import build_pyramid_index
    from repro.data.synthetic import clustered_vectors
    from repro.store import Compactor, IndexStore

    x = clustered_vectors(400, 8, 4, seed=0)
    cfg = PyramidConfig(metric="l2", num_shards=2, meta_size=16,
                        sample_size=200, branching_factor=2,
                        max_degree=8, max_degree_upper=4,
                        ef_construction=30, ef_search=30, kmeans_iters=4)
    store = IndexStore(str(tmp_path / "store"))
    store.publish(build_pyramid_index(x, cfg))
    reg, tr = MetricsRegistry(), Tracer()
    comp = Compactor(store, store.load(), rebalance=False,
                     registry=reg, tracer=tr)
    comp.add_items(np.random.default_rng(1).normal(
        size=(6, 8)).astype(np.float32))
    comp.run_once(force=True)
    stats = comp.stats()
    prom = reg.render_prometheus()
    assert f"pyramid_maintenance_cycles_total {stats['cycles']}" in prom
    assert (f"pyramid_maintenance_folded_records_total "
            f"{stats['folded_records']}") in prom
    assert f"pyramid_maintenance_swaps_total {stats['swaps']}" in prom
    names = {s.name for s in tr.snapshot()}
    assert {"compaction.cycle", "compaction.fold",
            "compaction.commit"} <= names
    cycle = next(s for s in tr.snapshot()
                 if s.name == "compaction.cycle")
    fold = next(s for s in tr.snapshot() if s.name == "compaction.fold")
    assert fold.parent_id == cycle.span_id


# ---------------------------------------------------------------------------
# serve --trace-out writes a schema-valid Chrome trace
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_serve_trace_out_is_schema_valid(tmp_path):
    from repro.launch.serve import main as serve_main

    out = tmp_path / "trace.json"
    serve_main(argv=["--tokens", "3", "--batch", "1",
                     "--prompt-len", "4", "--trace-out", str(out)])
    doc = json.loads(out.read_text())
    validate_chrome_trace(doc)
    names = {ev["name"] for ev in doc["traceEvents"]
             if ev["ph"] == "X"}
    assert "serve.prefill" in names
    assert "serve.decode_step" in names
