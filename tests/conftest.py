"""Suite-wide fixtures.

The full suite compiles hundreds of XLA programs in one process; on the
CPU backend the accumulated executables eventually segfault a later
compile (observed deterministically in test_system once the suite grew
past ~220 tests). Dropping the compilation caches between modules keeps
peak XLA state at single-module level — each module mostly compiles its
own shapes anyway, so the cost is seconds, not a recompile storm.
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
