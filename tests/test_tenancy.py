"""Multi-tenant namespaces: admission control at the HBM budget,
LRU eviction + transparent re-pin, cross-tenant isolation, replica
arbitration, and (faults lane) exactly-once delivery per tenant while
both tenants ride a fault storm."""
import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.client import gather, gather_arrays
from repro.core.meta_index import build_pyramid_index
from repro.core.updates import remove_items
from repro.data.synthetic import clustered_vectors, query_set
from repro.serving.tenancy import (AdmissionError, TenantManager,
                                   estimate_arena_bytes)


def _make(n=500, d=8, seed=0, shards=2):
    x = clustered_vectors(n, d, 8, seed=seed)
    cfg = PyramidConfig(metric="l2", num_shards=shards, meta_size=16,
                        sample_size=min(n, 300), branching_factor=2,
                        max_degree=10, max_degree_upper=5,
                        ef_construction=40, ef_search=50,
                        kmeans_iters=5, seed=seed)
    return x, build_pyramid_index(x, cfg)


def _ids(client, queries, k=10):
    ids, _ = gather_arrays(client.search_batch(queries, k=k), k, 60.0)
    return ids


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_admission_at_exact_budget():
    x, idx = _make()
    est = estimate_arena_bytes(idx)
    assert est > 0
    # an arena exactly at the budget is admitted ...
    with TenantManager(est) as tm:
        tm.create("a", idx)
        assert tm.stats()["tenants"]["a"]["live"]
        assert tm.used_bytes == est   # estimate == engine's true-up
    # ... one byte less is refused up front, before any device work
    with TenantManager(est - 1) as tm:
        with pytest.raises(AdmissionError, match="over the total"):
            tm.create("a", idx)
        assert tm.tenants() == []     # failed create leaves no tenant


def test_budget_must_be_positive():
    with pytest.raises(ValueError, match="budget_bytes"):
        TenantManager(0)


def test_admission_error_when_nothing_evictable():
    xa, ia = _make(seed=0)
    xb, ib = _make(seed=1)
    big_x, big = _make(n=1600, seed=2)
    est = estimate_arena_bytes(ia)
    with TenantManager(2 * est) as tm:
        tm.create("a", ia)
        tm.create("b", ib)
        # big needs more than the whole budget: rejected at create
        with pytest.raises(AdmissionError):
            tm.create("big", big)


# ---------------------------------------------------------------------------
# LRU eviction / re-pin
# ---------------------------------------------------------------------------


def test_evict_repin_roundtrip_identical():
    xa, ia = _make(seed=0)
    xb, ib = _make(seed=1)
    qa, qb = query_set(xa, 8, seed=2), query_set(xb, 8, seed=3)
    budget = int(max(estimate_arena_bytes(ia),
                     estimate_arena_bytes(ib)) * 1.25)
    with TenantManager(budget) as tm:      # fits ONE tenant at a time
        tm.create("a", ia)
        ca = tm.client("a")
        ids0 = _ids(ca, qa)
        tm.create("b", ib)                 # admitting b evicts cold a
        st = tm.stats()["tenants"]
        assert st["b"]["live"] and not st["a"]["live"]
        assert tm.stats()["used_bytes"] <= budget
        _ids(tm.client("b"), qb)
        # the SAME client session transparently re-pins a (evicting b)
        ids1 = _ids(ca, qa)
        st = tm.stats()["tenants"]
        assert st["a"]["live"] and not st["b"]["live"]
        np.testing.assert_array_equal(ids0, ids1)
        assert st["a"]["evictions"] == 1


def test_explicit_evict_and_lazy_repin():
    x, idx = _make()
    q = query_set(x, 4, seed=1)
    with TenantManager(4 * estimate_arena_bytes(idx)) as tm:
        tm.create("a", idx)
        ids0 = _ids(tm.client("a"), q)
        assert tm.evict("a") is True
        assert not tm.stats()["tenants"]["a"]["live"]
        assert tm.evict("a") is False     # already cold
        ids1 = _ids(tm.client("a"), q)    # lazy re-pin
        np.testing.assert_array_equal(ids0, ids1)


# ---------------------------------------------------------------------------
# cross-tenant isolation
# ---------------------------------------------------------------------------


def test_remove_items_in_one_tenant_never_affects_other():
    xa, ia = _make(seed=0)
    xb, ib = _make(seed=1)
    qa, qb = query_set(xa, 8, seed=4), query_set(xb, 8, seed=5)
    with TenantManager(
            4 * (estimate_arena_bytes(ia)
                 + estimate_arena_bytes(ib))) as tm:
        tm.create("a", ia)
        tm.create("b", ib)
        ids_b0 = _ids(tm.client("b"), qb)
        victims = np.unique(_ids(tm.client("a"), qa)[:, 0])
        remove_items(ia, victims)
        # re-pin a so its engine rebuilds from the mutated host index
        tm.evict("a")
        ids_a = _ids(tm.client("a"), qa)
        assert not np.isin(victims, ids_a).any()
        # b is untouched: same engine, bit-identical results
        np.testing.assert_array_equal(_ids(tm.client("b"), qb), ids_b0)
        assert tm.stats()["tenants"]["b"]["evictions"] == 0


def test_arbitrate_splits_replica_budget_by_access_rate():
    xa, ia = _make(seed=0)
    xb, ib = _make(seed=1)
    qa = query_set(xa, 4, seed=6)
    with TenantManager(
            4 * (estimate_arena_bytes(ia)
                 + estimate_arena_bytes(ib))) as tm:
        tm.create("a", ia)
        tm.create("b", ib)
        tm.attach_autoscaler("a")
        tm.attach_autoscaler("b")
        for _ in range(8):                  # make a the hot tenant
            gather(tm.submit("a", qa, k=5), 60.0)
        alloc = tm.arbitrate(8)
        assert sum(alloc.values()) == 8
        assert alloc["a"] > alloc["b"] >= 1
        st = tm.stats("a")
        assert st["tenancy"]["live"]


# ---------------------------------------------------------------------------
# faults lane: both tenants ride a storm, exactly-once per tenant
# ---------------------------------------------------------------------------


@pytest.mark.faults
def test_two_tenant_storm_exactly_once_per_tenant():
    from repro.serving.faults import FaultSchedule
    xa, ia = _make(n=900, d=10, seed=0, shards=3)
    xb, ib = _make(n=700, d=10, seed=1, shards=3)
    qa, qb = query_set(xa, 24, seed=7), query_set(xb, 24, seed=8)
    with TenantManager(
            4 * (estimate_arena_bytes(ia)
                 + estimate_arena_bytes(ib))) as tm:
        # each tenant gets its OWN storm (schedules are single-use);
        # hedging + supervised restarts keep both lossless
        tm.create("a", ia, replicas=2, hedge=True,
                  hedge_deadline_s=0.25, executor_batch=4,
                  fault_schedule=FaultSchedule.storm(
                      13, num_shards=3, replicas=2))
        tm.create("b", ib, replicas=2, hedge=True,
                  hedge_deadline_s=0.25, executor_batch=4,
                  fault_schedule=FaultSchedule.storm(
                      14, num_shards=3, replicas=2))
        futs = {"a": tm.client("a").search_batch(qa, k=10),
                "b": tm.client("b").search_batch(qb, k=10)}
        for t, (x, q) in (("a", (xa, qa)), ("b", (xb, qb))):
            results = [f.result(timeout=120) for f in futs[t]]
            qids = [r.query_id for r in results]
            # exactly-once, in submit order, no foreign results
            assert qids == [f.query_id for f in futs[t]]
            assert len(set(qids)) == len(qids)
            for r in results:
                assert len(set(r.ids.tolist())) == len(r.ids)
            true_ids, _ = M.brute_force_topk(q, x, 10, "l2")
            hits = sum(
                len(set(r.ids.tolist()) & set(true_ids[i].tolist()))
                for i, r in enumerate(results))
            assert hits / true_ids.size >= 0.8, \
                f"tenant {t} lost recall under the storm"
