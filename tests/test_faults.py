"""Deterministic fault-injection tests for the serving engine's active
robustness: scripted FaultSchedule storms (kill / restart / cpu_share at
batch-drain boundaries), hedged dispatch with first-result-wins dedup,
and the supervising Monitor (in-flight redispatch + bounded respawn).

Everything here asserts the exactly-once contract: every submitted
future resolves exactly once, no result is lost or duplicated, and
recall stays within 2% of the fault-free run.
"""
import time

import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.meta_index import build_pyramid_index
from repro.data.synthetic import clustered_vectors, query_set
from repro.serving import engine as E
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultEvent, FaultSchedule

pytestmark = pytest.mark.faults


@pytest.fixture(scope="module")
def engine_index():
    x = clustered_vectors(1500, 12, 12, seed=0)
    cfg = PyramidConfig(metric="l2", num_shards=4, meta_size=48,
                        sample_size=800, branching_factor=2, max_degree=12,
                        max_degree_upper=6, ef_construction=40,
                        ef_search=50, kmeans_iters=6)
    return x, build_pyramid_index(x, cfg)


def _collect(futures, timeout=60):
    """Resolve all futures; assert the exactly-once contract."""
    results = [f.result(timeout=timeout) for f in futures]
    assert len(results) == len(futures)
    qids = [r.query_id for r in results]
    assert len(set(qids)) == len(qids), "a future resolved a foreign query"
    assert qids == [f.query_id for f in futures]
    for r in results:   # hedged duplicate partials must never leak through
        assert len(set(r.ids.tolist())) == len(r.ids), \
            f"duplicate ids in merged result {r.query_id}"
        assert (np.diff(r.scores) <= 1e-5).all()
    return results


def _recall(results, queries, x, k=10):
    true_ids, _ = M.brute_force_topk(queries, x, k, "l2")
    hits = sum(len(set(r.ids.tolist()) & set(true_ids[i].tolist()))
               for i, r in enumerate(results))
    return hits / true_ids.size


# ---------------------------------------------------------------------------
# FaultSchedule semantics
# ---------------------------------------------------------------------------


def test_storm_is_seed_deterministic():
    a = FaultSchedule.storm(5, num_shards=4, replicas=2)
    b = FaultSchedule.storm(5, num_shards=4, replicas=2)
    assert a.events == b.events          # same seed -> identical script
    c = FaultSchedule.storm(6, num_shards=4, replicas=2)
    assert a.events != c.events


def test_fault_event_rejects_unknown_action():
    with pytest.raises(ValueError, match="unknown fault action"):
        FaultEvent(step=1, action="explode", target="exec-s0-r0")


def test_cpu_share_event_requires_valid_share():
    # a forgotten value would set share 0.0 -> divide-by-zero throttle
    with pytest.raises(ValueError, match="cpu_share"):
        FaultEvent(step=1, action="cpu_share", target="exec-s0-r0")
    with pytest.raises(ValueError, match="cpu_share"):
        FaultEvent(step=1, action="cpu_share", target="exec-s0-r0",
                   value=1.5)


# ---------------------------------------------------------------------------
# scripted storm: kill every r0 mid-batch, restart half, straggle one
# ---------------------------------------------------------------------------


def test_scripted_storm_exactly_once_and_recall(engine_index):
    x, idx = engine_index
    q = query_set(x, 48, seed=11)

    # fault-free reference run (passive, no faults)
    eng = ServingEngine(idx, replicas=2, hedge=False, auto_restart=False)
    try:
        free = _collect(eng.submit(q, k=10))
    finally:
        eng.shutdown()
    recall_free = _recall(free, q, x)

    # the storm: auto_restart off so ONLY the scripted restarts happen
    storm = FaultSchedule([
        FaultEvent(step=3, action="cpu_share", target="exec-s2-r1",
                   value=0.1),                              # straggle one
        FaultEvent(step=4, action="kill", target="exec-s*-r0"),  # all r0
        FaultEvent(step=8, action="restart", target="exec-s0-r0"),
        FaultEvent(step=8, action="restart", target="exec-s1-r0"),
    ])
    eng = ServingEngine(idx, replicas=2, hedge=True,
                        hedge_deadline_s=0.25, auto_restart=False,
                        executor_batch=4, fault_schedule=storm)
    try:
        stormy = _collect(eng.submit(q, k=10), timeout=120)
        stats = eng.stats()
    finally:
        eng.shutdown()

    recall_storm = _recall(stormy, q, x)
    assert abs(recall_storm - recall_free) <= 0.02, \
        f"storm cost recall: {recall_storm:.3f} vs {recall_free:.3f}"
    # the whole script fired, and the kill matched every shard's r0
    assert len(storm.fired) == len(storm.events)
    kill = next(f for f in storm.fired if f["action"] == "kill")
    assert kill["matched"] == [f"exec-s{s}-r0" for s in range(4)]
    assert stats["fault_step"] >= 8


def test_seeded_storm_with_supervisor(engine_index):
    """A random (but seeded) storm under the full supervisor: whatever
    the script kills, the Monitor redispatches + respawns, and every
    future still resolves exactly once."""
    x, idx = engine_index
    q = query_set(x, 32, seed=13)
    storm = FaultSchedule.storm(21, num_shards=4, replicas=2,
                                n_events=6, max_step=10)
    eng = ServingEngine(idx, replicas=2, auto_restart=True,
                        executor_batch=4, fault_schedule=storm,
                        monitor_opts={"backoff_base_s": 0.02,
                                      "period_s": 0.05})
    try:
        results = _collect(eng.submit(q, k=10), timeout=120)
        assert _recall(results, q, x) > 0.6
        assert storm.done()
    finally:
        eng.shutdown()


def test_when_actor_pins_kill_to_victims_own_drain(engine_index):
    """``when_actor`` defers a due kill until the victim itself ticks,
    so it always dies holding a drained batch — its in-flight items are
    re-enqueued with full bookkeeping and the supervisor respawns it."""
    x, idx = engine_index
    victim = "exec-s2-r0"
    storm = FaultSchedule([FaultEvent(step=1, action="kill",
                                      target=victim, when_actor=victim)])
    eng = ServingEngine(idx, replicas=1, hedge=False, executor_batch=4,
                        fault_schedule=storm,
                        monitor_opts={"backoff_base_s": 0.02,
                                      "period_s": 0.05})
    try:
        results = _collect(eng.submit(query_set(x, 24, seed=19), k=5),
                           timeout=60)
        assert len(results) == 24
        assert storm.done()
        assert storm.fired[0]["matched"] == [victim]
        stats = eng.stats()
        assert stats["redispatched"] >= 1   # died with items in hand
        assert stats["restarts"] >= 1
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# monitor-as-supervisor: heartbeat seeding, stuck detection, redispatch
# ---------------------------------------------------------------------------


def test_kill_before_first_heartbeat_is_restarted(engine_index):
    """Regression: heartbeats are seeded at spawn, so an executor killed
    before its first beat (e.g. still in jit warmup) is detected and
    respawned instead of being treated as live forever."""
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=1,
                        monitor_opts={"backoff_base_s": 0.02,
                                      "period_s": 0.05})
    try:
        assert set(eng.heartbeat) == set(eng.executors)  # seeded at spawn
        eng.kill_executor("exec-s1-r0")   # quite possibly pre-first-beat
        deadline = time.monotonic() + 15
        while time.monotonic() < deadline and eng.stats()["restarts"] == 0:
            time.sleep(0.05)
        assert eng.stats()["restarts"] >= 1
        _collect(eng.submit(query_set(x, 8, seed=14), k=5))
    finally:
        eng.shutdown()


def test_stuck_executor_detected_via_seeded_heartbeat(engine_index,
                                                      monkeypatch):
    """An executor that hangs before ever heartbeating (mid-warmup) must
    be fenced off and respawned. Under the old ``heartbeat.get(name,
    now)`` default it looked perpetually fresh and shard 0 hung."""
    x, idx = engine_index
    orig = E.Executor._warmup
    hung = []

    def warmup(self):
        if self.name == "exec-s0-r0" and not hung:
            hung.append(self.name)
            while self.alive:        # never heartbeats, never serves
                time.sleep(0.01)
            return                   # fenced off; run() exits on alive
        return orig(self)

    monkeypatch.setattr(E.Executor, "_warmup", warmup)
    eng = ServingEngine(idx, replicas=1,
                        monitor_opts={"warmup_grace_s": 0.4,
                                      "timeout_s": 0.4, "period_s": 0.05,
                                      "backoff_base_s": 0.02})
    try:
        results = _collect(eng.submit(query_set(x, 16, seed=15), k=5),
                           timeout=60)
        assert len(results) == 16
        stats = eng.stats()
        events = [e for e in stats["recovery_timeline"]
                  if e["executor"] == "exec-s0-r0"]
        assert any(e["event"] == "stuck" for e in events)
        assert any(e["event"] == "restart" for e in events)
        assert stats["restarts"] >= 1
    finally:
        eng.shutdown()


def test_monitor_redispatches_inflight_of_hung_executor(engine_index,
                                                        monkeypatch):
    """An executor that hangs *mid-batch* (items drained, search never
    returns) loses nothing: the Monitor fences it, atomically claims its
    in-flight batch, re-enqueues it, and respawns the replica."""
    x, idx = engine_index
    orig = E.Executor._search
    hung = []

    def search(self, batch):
        if self.name == "exec-s0-r0" and self.warmed and not hung:
            hung.append(self.name)
            # hold the batch until the monitor has fenced us off AND
            # claimed the in-flight items (atomic pop -> exactly once)
            while self.alive or self.has_inflight():
                time.sleep(0.01)
            return []                # fenced off; run() exits on alive
        return orig(self, batch)

    monkeypatch.setattr(E.Executor, "_search", search)
    eng = ServingEngine(idx, replicas=1, hedge=False,
                        monitor_opts={"timeout_s": 0.3, "period_s": 0.05,
                                      "search_grace_s": 0.3,
                                      "backoff_base_s": 0.02})
    try:
        results = _collect(eng.submit(query_set(x, 24, seed=16), k=5),
                           timeout=60)
        assert len(results) == 24
        stats = eng.stats()
        assert stats["redispatched"] >= 1     # monitor path, hedging off
        events = {e["event"] for e in stats["recovery_timeline"]
                  if e["executor"] == "exec-s0-r0"}
        assert {"stuck", "redispatch", "restart"} <= events
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# hedged dispatch
# ---------------------------------------------------------------------------


def test_hedged_dispatch_rescues_straggling_shard(engine_index):
    """Both replicas of shard 0 straggle hard: the latency deadline
    trips, hedges are issued, duplicate partials are dropped
    first-result-wins, and the hedge count is visible on the future and
    the result."""
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=2, hedge=True,
                        hedge_deadline_s=0.05, hedge_max_attempts=1,
                        executor_batch=4)
    try:
        eng.set_cpu_share("exec-s0-r0", 0.05)
        eng.set_cpu_share("exec-s0-r1", 0.05)
        futs = eng.submit(query_set(x, 32, seed=17), k=5)
        results = _collect(futs, timeout=120)
        stats = eng.stats()
        assert stats["hedged_queries"] >= 1
        assert stats["redispatched"] >= stats["hedged_queries"]
        hedged = [(f, r) for f, r in zip(futs, results) if r.hedges]
        assert hedged, "no query recorded its hedges"
        for f, r in hedged:
            assert f.hedges == r.hedges   # future-level visibility
    finally:
        eng.shutdown()


def test_hedging_idle_on_healthy_engine(engine_index):
    """With healthy replicas the tracked-percentile deadline must not
    fire spurious hedges (cold shards get the long cold deadline)."""
    x, idx = engine_index
    eng = ServingEngine(idx, replicas=2, hedge=True, hedge_cold_s=5.0)
    try:
        _collect(eng.submit(query_set(x, 24, seed=18), k=5))
        stats = eng.stats()
        assert stats["hedged_queries"] == 0
        assert stats["redispatched"] == 0
        assert stats["latency"], "tracker saw no partials"
    finally:
        eng.shutdown()
