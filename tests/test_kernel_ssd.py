"""Shape sweep of the SSD Pallas kernel vs the chunked-jnp oracle (which is
itself equivalence-tested against recurrent decode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_ref


def _case(b, s, h, p, n, chunk, block_h=4, seed=0, tol=2e-3):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(
        rng.uniform(0.01, 0.3, size=(b, s, h)).astype(np.float32))
    a = jnp.asarray(-rng.uniform(0.5, 4.0, size=(h,)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    y_ref, st_ref = ssd_ref(x, dt, a, bm, cm, chunk=chunk)
    y_ker, st_ker = ssd_pallas(x, dt, a, bm, cm, chunk=chunk,
                               block_h=block_h, interpret=True)
    np.testing.assert_allclose(np.asarray(y_ker),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(st_ker), np.asarray(st_ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [
    (1, 64, 4, 8, 16, 16),    # multi-chunk
    (2, 96, 8, 16, 8, 32),    # head blocks
    (1, 128, 2, 8, 32, 64),   # large chunk
])
def test_ssd_kernel_matches_ref(shape):
    b, s, h, p, n, chunk = shape
    _case(b, s, h, p, n, chunk, seed=sum(shape))


def test_ssd_kernel_unaligned_seq():
    # S not a multiple of chunk: dt=0 padding must be a scan no-op
    _case(1, 50, 4, 8, 16, 16, seed=3)
    _case(2, 33, 2, 8, 8, 32, seed=4)


def test_ssd_kernel_single_chunk_degenerate():
    _case(1, 16, 2, 4, 8, 16, seed=5)


def test_ssd_state_carries_across_chunks():
    """The final state must reflect ALL chunks (catches scratch resets)."""
    rng = np.random.default_rng(6)
    b, s, h, p, n, chunk = 1, 64, 2, 4, 8, 16
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(
        rng.uniform(0.05, 0.2, size=(b, s, h)).astype(np.float32))
    a = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    _, st_full = ssd_pallas(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    _, st_half = ssd_pallas(x[:, : s // 2], dt[:, : s // 2], a,
                            bm[:, : s // 2], cm[:, : s // 2],
                            chunk=chunk, interpret=True)
    assert not np.allclose(np.asarray(st_full), np.asarray(st_half))
