"""Quantized ShardArena: float32-vs-int8 parity on all three metrics,
exact-rerank semantics, quantize/dequantize round-trip properties,
frozen-grid store persistence, and the >= 3x memory contract."""
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.arena import QuantizedShardArena
from repro.core.distributed import search_single_host
from repro.core.meta_index import build_pyramid_index
from repro.core.quant import QuantParams, exact_rerank_np
from repro.data.synthetic import clustered_vectors

RERANK = 4


def _mips_data(seed=0, n=2000, d=12):
    rng = np.random.default_rng(seed)
    dirs = rng.normal(size=(16, d))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    asg = rng.integers(0, 16, size=n)
    x = dirs[asg] + 0.2 * rng.normal(size=(n, d))
    norms = rng.lognormal(mean=0.0, sigma=0.8, size=(n, 1))
    return (x * norms).astype(np.float32), \
        rng.normal(size=(48, d)).astype(np.float32)


def _build(x, metric, replication_r=0, num_shards=4):
    cfg = PyramidConfig(metric=metric, num_shards=num_shards, meta_size=48,
                        sample_size=1200, branching_factor=2,
                        max_degree=12, max_degree_upper=6,
                        ef_construction=40, ef_search=60,
                        replication_r=replication_r, kmeans_iters=6)
    return build_pyramid_index(x, cfg)


_CACHE = {}


def _fixture(metric):
    if metric not in _CACHE:
        if metric == "ip":
            x, q = _mips_data(seed=3)
            idx = _build(x, metric, replication_r=40)
        else:
            x = clustered_vectors(2000, 12, 16, seed=1)
            rng = np.random.default_rng(2)
            q = x[rng.choice(2000, 48)] + 0.01 * rng.normal(
                size=(48, 12)).astype(np.float32)
            idx = _build(x, metric)
        xn = M.preprocess_dataset(x, metric)
        qn = M.preprocess_queries(q, metric)
        true_ids, _ = M.brute_force_topk(qn, xn, 10, metric)
        _CACHE[metric] = (idx, x, q, true_ids)
    return _CACHE[metric]


def _recall(ids, true_ids):
    return sum(len(set(np.asarray(a).tolist()) & set(b.tolist()))
               for a, b in zip(ids, true_ids)) / true_ids.size


# ---------------------------------------------------------------------------
# float32 vs int8 parity (tentpole acceptance: recall within 1%)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["l2", "angular", "ip"])
def test_int8_recall_within_1pct_of_float(metric):
    idx, x, q, true_ids = _fixture(metric)
    ids_f, _, _ = search_single_host(idx, q, k=10)
    ids_q, scores_q, _ = search_single_host(
        idx, q, k=10, quantize=True, rerank_factor=RERANK)
    r_f, r_q = _recall(ids_f, true_ids), _recall(ids_q, true_ids)
    assert r_q >= r_f - 0.01, (metric, r_f, r_q)
    # no duplicate ids may survive the merge + rerank
    for row in np.asarray(ids_q):
        valid = row[row >= 0]
        assert len(set(valid.tolist())) == len(valid), row
    # rerank output is descending with (-1, -inf) suffix padding
    for rs, ri in zip(np.asarray(scores_q), np.asarray(ids_q)):
        valid = ri >= 0
        assert (np.diff(rs[valid]) <= 1e-6).all()
        assert not np.any(np.diff(valid.astype(int)) > 0)


def test_int8_memory_reduction_at_least_3x():
    idx, *_ = _fixture("l2")
    af = idx.arena("float32")
    aq = idx.arena("int8")
    assert isinstance(aq, QuantizedShardArena)
    assert aq.data.dtype == jnp.int8
    reduction = af.vector_nbytes / aq.vector_nbytes
    assert reduction >= 3.0, reduction
    # adjacency/ids are identical across the two arena forms
    np.testing.assert_array_equal(np.asarray(af.ids), np.asarray(aq.ids))
    np.testing.assert_array_equal(np.asarray(af.bottom),
                                  np.asarray(aq.bottom))


def test_quant_arena_memoised_and_invalidated():
    from repro.core.updates import add_items
    x = clustered_vectors(1200, 8, 8, seed=20)
    idx = _build(x, "l2")
    af, aq = idx.arena(), idx.arena("int8")
    assert idx.arena() is af and idx.arena("int8") is aq   # per-dtype memo
    qp = idx.quant_params()
    add_items(idx, clustered_vectors(40, 8, 4, seed=21))
    assert idx.arena("int8") is not aq        # arena invalidated...
    assert idx.quant_params() is qp           # ...but the grid is frozen
    with pytest.raises(ValueError):
        idx.arena("bf16")


# ---------------------------------------------------------------------------
# exact rerank semantics
# ---------------------------------------------------------------------------


def test_rerank_scores_are_exact_float32():
    """Every score the quantized path returns must equal the exact
    float32 similarity of that (query, item) pair — the rerank removes
    quantization error from the reported scores entirely."""
    idx, x, q, _ = _fixture("l2")
    ids_q, scores_q, _ = search_single_host(
        idx, q, k=10, quantize=True, rerank_factor=RERANK)
    xn = M.preprocess_dataset(x, "l2")
    qn = M.preprocess_queries(q, "l2")
    for i in range(len(q)):
        valid = ids_q[i] >= 0
        want = M.similarity_matrix_np(
            qn[i][None, :], xn[ids_q[i][valid]], "l2")[0]
        # 1-ulp slack: the rerank batches a different candidate row set
        # than this direct check, so the matmul may reassociate
        np.testing.assert_allclose(scores_q[i][valid], want, rtol=1e-5,
                                   atol=1e-6)


def test_rerank_exactness_on_ties():
    """Exact duplicate vectors (distinct ids) are exact score ties: the
    rerank must give them bit-equal scores and break the tie by the
    incoming quantized rank (stable), deterministically."""
    rng = np.random.default_rng(5)
    base = rng.normal(size=(8, 6)).astype(np.float32)
    table_ids = np.arange(16, dtype=np.int64)
    table_vecs = np.concatenate([base, base])   # ids i and i+8 identical
    q = (base[:4] + 0.01 * rng.normal(size=(4, 6))).astype(np.float32)
    # candidate lists contain both copies, the duplicate listed SECOND
    cand = np.stack([
        np.array([i, i + 8, (i + 1) % 8, -1], np.int64)
        for i in range(4)])
    ids1, scores1 = exact_rerank_np(
        q, cand, 3, table_ids=table_ids, table_vecs=table_vecs,
        metric="l2")
    ids2, scores2 = exact_rerank_np(
        q, cand, 3, table_ids=table_ids, table_vecs=table_vecs,
        metric="l2")
    np.testing.assert_array_equal(ids1, ids2)          # deterministic
    np.testing.assert_array_equal(scores1, scores2)
    for i in range(4):
        # both copies returned, tied bit-for-bit, incoming order kept
        assert ids1[i][0] == i and ids1[i][1] == i + 8, ids1[i]
        assert scores1[i][0] == scores1[i][1]
        want = M.similarity_matrix_np(
            q[i][None, :], table_vecs[ids1[i]], "l2")[0]
        np.testing.assert_allclose(scores1[i], want, rtol=1e-6)


def test_rerank_drops_unknown_ids_and_handles_empty_rows():
    table_ids = np.array([2, 5, 9], np.int64)
    table_vecs = np.eye(3, dtype=np.float32)
    q = np.ones((2, 3), np.float32)
    cand = np.array([[5, 777, -1], [-1, -1, -1]], np.int64)
    ids, scores = exact_rerank_np(q, cand, 2, table_ids=table_ids,
                                  table_vecs=table_vecs, metric="ip")
    assert ids[0].tolist() == [5, -1]
    assert np.isneginf(scores[0][1])
    assert (ids[1] == -1).all() and np.isneginf(scores[1]).all()


# ---------------------------------------------------------------------------
# quantize/dequantize round-trip
# ---------------------------------------------------------------------------


def test_round_trip_error_bounded_by_half_step():
    rng = np.random.default_rng(0)
    x = (rng.normal(size=(500, 16)) * rng.uniform(
        0.1, 50.0, size=(1, 16))).astype(np.float32)
    p = QuantParams.from_data(x)
    codes = p.quantize(x)
    err = np.abs(p.dequantize(codes) - x)
    bound = p.scale / 2 + 1e-4 * (1 + np.abs(p.zero))
    assert (err <= bound).all(), float((err - bound).max())
    # codes are a fixed point of dequantize-then-quantize
    np.testing.assert_array_equal(p.quantize(p.dequantize(codes)), codes)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

if given is not None:

    @st.composite
    def float_blocks(draw):
        n = draw(st.integers(1, 40))
        d = draw(st.integers(1, 8))
        rows = draw(st.lists(
            st.lists(st.floats(-1e4, 1e4, width=32), min_size=d,
                     max_size=d),
            min_size=n, max_size=n))
        return np.asarray(rows, np.float32)

    @settings(max_examples=40, deadline=None)
    @given(float_blocks())
    def test_property_quantize_dequantize_round_trip(x):
        p = QuantParams.from_data(x)
        codes = p.quantize(x)
        assert codes.dtype == np.int8
        assert codes.min() >= -127 and codes.max() <= 127
        err = np.abs(p.dequantize(codes) - x)
        bound = p.scale / 2 + 1e-3 * (1 + np.abs(p.zero))
        assert (err <= bound).all()
        np.testing.assert_array_equal(
            p.quantize(p.dequantize(codes)), codes)

    @settings(max_examples=40, deadline=None)
    @given(float_blocks())
    def test_property_grid_is_deterministic(x):
        p1, p2 = QuantParams.from_data(x), QuantParams.from_data(x.copy())
        np.testing.assert_array_equal(p1.scale, p2.scale)
        np.testing.assert_array_equal(p1.zero, p2.zero)


# ---------------------------------------------------------------------------
# store persistence: frozen grid, bit-identical reopen + replay
# ---------------------------------------------------------------------------


def test_store_reopen_parity_for_quantized_manifest():
    from repro.core.updates import add_items
    from repro.store import IndexStore

    x = clustered_vectors(1200, 8, 8, seed=30)
    idx = _build(x, "l2")
    qp = idx.quant_params()           # freeze the grid pre-publish
    rng = np.random.default_rng(31)
    q = x[rng.choice(1200, 16)] + 0.01 * rng.normal(
        size=(16, 8)).astype(np.float32)
    with tempfile.TemporaryDirectory() as tmp:
        store = IndexStore(tmp)
        store.publish(idx)
        # insert AFTER publish: reopen must replay through the journal
        # and requantize the appended rows on the frozen grid
        add_items(idx, clustered_vectors(50, 8, 4, seed=32))
        loaded = store.load()
        qp2 = loaded.quant_params()
        np.testing.assert_array_equal(qp.scale, qp2.scale)   # no
        np.testing.assert_array_equal(qp.zero, qp2.zero)     # re-derive
        live, reopened = idx.arena("int8"), loaded.arena("int8")
        np.testing.assert_array_equal(            # codes bit-identical
            np.asarray(live.data), np.asarray(reopened.data))
        ids_live, s_live, _ = search_single_host(
            idx, q, k=10, quantize=True)
        ids_re, s_re, _ = search_single_host(
            loaded, q, k=10, quantize=True)
        np.testing.assert_array_equal(ids_live, ids_re)
        np.testing.assert_array_equal(s_live, s_re)


def test_from_store_serves_quantized_without_requantizing():
    from repro.serving.engine import ServingEngine
    from repro.store import IndexStore

    idx, x, q, true_ids = _fixture("angular")
    qp = idx.quant_params()
    with tempfile.TemporaryDirectory() as tmp:
        IndexStore(tmp).publish(idx)
        eng = ServingEngine.from_store(tmp, replicas=1, quantize=True)
        try:
            # the engine's grid IS the manifest's (no re-derivation)
            np.testing.assert_array_equal(
                eng.index.quant_params().scale, qp.scale)
            res = [f.result(60) for f in eng.submit(q, k=10)]
            st = eng.stats()
        finally:
            eng.shutdown()
    assert st["quantized"] and st["rerank_factor"] == 4
    assert 0.0 < st["access_rate"] <= 1.0
    assert (st["routing"]["effective_ef"]
            >= st["routing"]["requested_ef"])
    assert st["routing"]["branching_factor"] == 2
    r_eng = _recall([r.ids for r in res], true_ids)
    ids_f, _, _ = search_single_host(idx, q, k=10)
    assert r_eng >= _recall(ids_f, true_ids) - 0.01, r_eng


def test_spmd_quantized_path_parity():
    import jax

    from repro.core.distributed import make_pyramid_search_fn

    idx, x, q, true_ids = _fixture("l2")
    mesh = jax.make_mesh((1,), ("model",))
    fn = make_pyramid_search_fn(
        mesh, idx.config, k=10, batch=len(q), ef=idx.config.ef_search,
        quantize=True, rerank_factor=RERANK, index=idx)
    qn = M.preprocess_queries(q, "l2")
    ids_spmd, scores_spmd = fn(
        idx.arena("int8"), idx.meta_arrays(),
        jnp.asarray(idx.part_of_center), jnp.asarray(qn))
    ids_host, _, _ = search_single_host(idx, q, k=10)
    assert _recall(ids_spmd, true_ids) >= _recall(ids_host, true_ids) - 0.01
    with pytest.raises(ValueError):   # rerank table requires the index
        make_pyramid_search_fn(mesh, idx.config, k=10, batch=len(q),
                               quantize=True)


# ---------------------------------------------------------------------------
# routing satellites
# ---------------------------------------------------------------------------


def test_route_queries_warns_once_when_ef_raised():
    import warnings

    from repro.core import router
    idx, x, q, _ = _fixture("l2")
    qn = M.preprocess_queries(q, "l2")
    router._EF_RAISED_WARNED.clear()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        for _ in range(2):
            mask, _ = router.route_queries(
                idx.meta_arrays(), jnp.asarray(idx.part_of_center),
                jnp.asarray(qn), metric="l2", branching_factor=8,
                num_shards=idx.num_shards, ef=2)
    warns = [w for w in caught if "route_queries" in str(w.message)]
    assert len(warns) == 1, [str(w.message) for w in caught]
    assert router.effective_ef(2, 8) == 8
    assert np.asarray(mask).any()
