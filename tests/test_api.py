"""The paper's public API surface (Sec. IV-A Listings 1-3)."""
import threading

import pytest

from repro.core import metrics as M
from repro.core.api import (Brokers, BuildPara, Coordinator, Executor,
                            GraphConstructor, QueryPara)
from repro.data.synthetic import clustered_vectors, query_set


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("idx"))
    x = clustered_vectors(2000, 12, 16, seed=0)
    gc = GraphConstructor(x, "l2", path)
    gc.build_graphs(BuildPara(meta_size=48, num_shards=4, sample_size=1000,
                              max_degree=12, ef_construction=40))
    return x, path, gc


def test_coordinator_execute(built):
    x, path, _ = built
    brokers = Brokers()
    try:
        coord = Coordinator(brokers, path, "demo", "l2")
        q = query_set(x, 1, seed=1)[0]
        res = coord.execute(q, QueryPara(k=5, branching_factor=2))
        assert res.ids.shape[0] == 5
        true_ids, _ = M.brute_force_topk(q[None], x, 5, "l2")
        assert len(set(res.ids.tolist()) & set(true_ids[0].tolist())) >= 3
    finally:
        brokers.shutdown()


def test_coordinator_execute_async_callback(built):
    x, path, _ = built
    brokers = Brokers()
    try:
        coord = Coordinator(brokers, path, "demo2", "l2")
        q = query_set(x, 1, seed=2)[0]
        done = threading.Event()
        out = {}

        def cb(res):
            out["res"] = res
            done.set()

        coord.execute_async(q, QueryPara(k=5), cb)
        assert done.wait(timeout=60)
        assert out["res"].ids.shape[0] == 5
    finally:
        brokers.shutdown()


def test_executor_elastic_scaling(built):
    """Sec. IV-B: executors can be added to a replica group at runtime."""
    x, path, _ = built
    brokers = Brokers()
    try:
        coord = Coordinator(brokers, path, "demo3", "l2")
        eng = brokers.engine_for("demo3", coord.index)
        before = len(eng.executors)
        ex = Executor(brokers, path, "demo3", "l2", shard_id=0)
        ex.start()
        assert len(eng.executors) == before + 1
        # queries still answered with the extra replica
        res = coord.execute_batch(query_set(x, 8, seed=3), QueryPara(k=5))
        assert len(res) == 8
        ex.stop()
    finally:
        brokers.shutdown()


def test_graph_constructor_refresh(built, tmp_path):
    x, path, gc = built
    brokers = Brokers()
    try:
        coord = Coordinator(brokers, path, "demo4", "l2")
        res = coord.execute(x[0], QueryPara(k=3))
        assert res.ids.shape[0] == 3
        # refresh with shifted data; old engine is torn down
        x2 = x + 100.0
        gc.refresh(x2, BuildPara(meta_size=48, num_shards=4,
                                 sample_size=1000, max_degree=12,
                                 ef_construction=40),
                   brokers=brokers, name="demo4")
        coord2 = Coordinator(brokers, path, "demo4", "l2")
        res2 = coord2.execute(x2[0], QueryPara(k=3))
        true_ids, _ = M.brute_force_topk(x2[0][None], x2, 3, "l2")
        assert len(set(res2.ids.tolist()) & set(true_ids[0].tolist())) >= 2
    finally:
        brokers.shutdown()
