"""End-to-end observability under a deterministic FaultSchedule storm:
the trace must contain the hedge re-dispatch and executor-respawn
machinery with correct parent/child causality, and the Prometheus
endpoint must agree exactly with ``engine.stats()`` — the counters ARE
the bookkeeping, so the two can never drift.
"""
import time
import urllib.request

import pytest

from repro.common.config import PyramidConfig
from repro.core.meta_index import build_pyramid_index
from repro.data.synthetic import clustered_vectors, query_set
from repro.obs import MetricsRegistry, StatsServer, Tracer
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultEvent, FaultSchedule

pytestmark = pytest.mark.faults


@pytest.fixture(scope="module")
def engine_index():
    x = clustered_vectors(1500, 12, 12, seed=0)
    cfg = PyramidConfig(metric="l2", num_shards=4, meta_size=48,
                        sample_size=800, branching_factor=2,
                        max_degree=12, max_degree_upper=6,
                        ef_construction=40, ef_search=50, kmeans_iters=6)
    return x, build_pyramid_index(x, cfg)


def _prom_value(text: str, name: str) -> float:
    for line in text.splitlines():
        if line.startswith(f"{name} "):
            return float(line.split()[1])
    raise AssertionError(f"{name} not found in /metrics")


def test_storm_trace_causality_and_metrics_parity(engine_index):
    x, idx = engine_index
    registry, tracer = MetricsRegistry(), Tracer()
    victim = "exec-s1-r0"
    storm = FaultSchedule([
        # throttle one replica of shard 2 to 2% CPU: whatever batch it
        # grabs outlives the hedge deadline -> hedge re-dispatch
        FaultEvent(step=2, action="cpu_share", target="exec-s2-r1",
                   value=0.02),
        # kill one executor while it holds a drained batch: the monitor
        # must redispatch its in-flight items and respawn it
        FaultEvent(step=4, action="kill", target=victim,
                   when_actor=victim),
    ])
    eng = ServingEngine(idx, replicas=2, hedge=True,
                        hedge_deadline_s=0.12, executor_batch=4,
                        fault_schedule=storm,
                        monitor_opts={"backoff_base_s": 0.02,
                                      "period_s": 0.05},
                        registry=registry, tracer=tracer)
    try:
        # two waves: the straggler is throttled from wave 1, so wave 2
        # queries landing on shard 2 reliably outlive the deadline
        for seed in (11, 12):
            q = query_set(x, 32, seed=seed)
            results = [f.result(timeout=120)
                       for f in eng.submit(q, k=10)]
            assert len(results) == 32

        # quiesce: the respawn is async behind the monitor's period
        deadline = time.monotonic() + 30
        while (time.monotonic() < deadline
               and eng.stats()["restarts"] < 1):
            time.sleep(0.05)
        assert storm.done()
        assert eng.stats()["restarts"] >= 1

        spans = tracer.snapshot()
        by_id = {s.span_id: s for s in spans}
        roots = {s.attrs["qid"]: s for s in spans if s.name == "query"}

        # hedge re-dispatch instants, each parented to ITS query's root
        hedges = [s for s in spans if s.name == "hedge.redispatch"]
        assert hedges, "storm produced no hedge re-dispatch spans"
        for h in hedges:
            root = roots[h.attrs["qid"]]
            assert h.parent_id == root.span_id
            assert root.t0 <= h.t0      # child cannot precede its root

        # the kill: monitor.recover wraps the whole recovery, with the
        # in-flight redispatch and the respawn as its children
        recovers = [s for s in spans if s.name == "monitor.recover"
                    and s.attrs.get("executor") == victim]
        assert recovers
        recover_ids = {s.span_id for s in recovers}
        respawns = [s for s in spans if s.name == "executor.respawn"
                    and s.attrs.get("executor") == victim]
        assert respawns, "no executor.respawn span for the killed victim"
        assert all(s.parent_id in recover_ids for s in respawns)
        redisp = [s for s in spans if s.name == "monitor.redispatch"]
        assert all(s.parent_id in {r.span_id for r in spans
                                   if s and r.name == "monitor.recover"}
                   for s in redisp)
        # the per-query recovery instants are parented to query roots
        for s in spans:
            if s.name == "recovery.redispatch":
                assert by_id[s.parent_id].name == "query"

        # Prometheus endpoint vs stats(): same counter objects, so the
        # scrape and the dict must agree EXACTLY
        with StatsServer(registry, host="127.0.0.1", port=0) as srv:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{srv.port}/metrics",
                    timeout=10) as r:
                text = r.read().decode()
        stats = eng.stats()
        assert _prom_value(
            text, "pyramid_queries_submitted_total") == \
            stats["submitted_queries"]
        assert _prom_value(
            text, "pyramid_queries_hedged_total") == \
            stats["hedged_queries"]
        assert _prom_value(
            text, "pyramid_executor_restarts_total") == stats["restarts"]
        assert _prom_value(
            text, "pyramid_queries_expired_total") == \
            stats["expired_queries"]
        assert stats["hedged_queries"] >= 1

        # the Chrome export of this storm is schema-valid
        from repro.obs import validate_chrome_trace
        validate_chrome_trace(tracer.chrome_trace())
    finally:
        eng.shutdown()


def test_registry_survives_hot_swap(engine_index):
    """``Brokers.replace_index`` hands the old engine's registry to the
    replacement, so counters keep accumulating across a hot-swap
    instead of resetting — scrapes see one monotone series."""
    from repro.core.api import Brokers

    x, idx = engine_index
    registry = MetricsRegistry()
    with Brokers() as brokers:
        brokers.engine_for("svc", idx, replicas=1, registry=registry,
                           tracer=Tracer())
        q = query_set(x, 16, seed=3)
        eng = brokers.get_engine("svc")
        [f.result(timeout=60) for f in eng.submit(q, k=5)]
        before = int(eng._m_submitted.value)
        assert before == 16
        brokers.replace_index("svc", idx)
        eng2 = brokers.get_engine("svc")
        assert eng2 is not eng
        assert eng2.obs is registry     # same registry, same counters
        [f.result(timeout=60) for f in eng2.submit(q, k=5)]
        assert int(eng2._m_submitted.value) == before + 16
        # stats() reads the counter, so it reports the cumulative
        # service-level total too — /metrics parity across the swap
        assert eng2.stats()["submitted_queries"] == 32
