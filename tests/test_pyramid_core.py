"""Pyramid core: kmeans, partitioning, index build, Alg. 4 search."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.distributed import (
    make_pyramid_search_fn, search_single_host, stack_shards)
from repro.core.kmeans import kmeans
from repro.core.meta_index import build_pyramid_index
from repro.core.partition import balance_stats, edge_cut, partition_graph
from repro.core.router import access_rate, route_queries


def _clustered(n, d, c, seed=0, spread=0.15):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(c, d))
    asg = rng.integers(0, c, size=n)
    return (centers[asg] + spread * rng.normal(size=(n, d))).astype(np.float32)


# --------------------------------------------------------------------------
# kmeans
# --------------------------------------------------------------------------


def test_kmeans_reduces_quantization_error():
    x = _clustered(2000, 8, 10)
    c1, counts = kmeans(x, 10, iters=1, seed=0)
    c12, counts12 = kmeans(x, 10, iters=12, seed=0)

    def qerr(centers):
        d = -M.similarity_matrix_np(x, centers, "l2")
        return float(np.min(d, axis=1).mean())

    assert qerr(c12) < qerr(c1)
    assert counts12.sum() == 2000


def test_spherical_kmeans_unit_norm():
    x = _clustered(1000, 16, 8, seed=1)
    c, _ = kmeans(x, 8, iters=8, spherical=True, seed=0)
    np.testing.assert_allclose(np.linalg.norm(c, axis=1), 1.0, atol=1e-4)


# --------------------------------------------------------------------------
# graph partitioning
# --------------------------------------------------------------------------


def test_partition_balanced_and_better_than_random():
    from repro.core.hnsw import build_hnsw
    x = _clustered(600, 8, 12, seed=2)
    g = build_hnsw(x, metric="l2", max_degree=12, max_degree_upper=6,
                   ef_construction=40)
    wts = np.ones(600)
    labels = partition_graph(g.neighbors[0], wts, 4, seed=0)
    assert labels.shape == (600,)
    assert set(labels.tolist()) == {0, 1, 2, 3}
    bal, _ = balance_stats(wts, labels, 4)
    assert bal <= 1.12, f"imbalance {bal}"
    rng = np.random.default_rng(0)
    random_labels = rng.integers(0, 4, size=600).astype(np.int32)
    assert edge_cut(g.neighbors[0], labels) < \
        0.7 * edge_cut(g.neighbors[0], random_labels)


# --------------------------------------------------------------------------
# index build + routing
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_index():
    x = _clustered(3000, 16, 24, seed=3)
    cfg = PyramidConfig(metric="l2", num_shards=4, meta_size=64,
                        sample_size=1500, branching_factor=2,
                        max_degree=12, max_degree_upper=6,
                        ef_construction=40, ef_search=60, kmeans_iters=8)
    return x, build_pyramid_index(x, cfg)


def test_index_build_invariants(small_index):
    x, idx = small_index
    assert idx.num_shards == 4
    stored = np.concatenate([s.ids for s in idx.subs])
    # Alg. 3 without replication: every item stored exactly once
    assert np.sort(stored).tolist() == list(range(3000))
    assert idx.part_of_center.min() >= 0
    assert idx.part_of_center.max() < 4


def test_routing_masks(small_index):
    x, idx = small_index
    rng = np.random.default_rng(5)
    q = x[rng.choice(3000, 64)] + 0.01 * rng.normal(size=(64, 16)).astype(
        np.float32)
    mask, meta_ids = route_queries(
        idx.meta_arrays(), jnp.asarray(idx.part_of_center),
        jnp.asarray(q), metric="l2", branching_factor=2, num_shards=4)
    mask = np.asarray(mask)
    per_query = mask.sum(axis=1)
    assert (per_query >= 1).all() and (per_query <= 2).all()
    assert 0 < access_rate(jnp.asarray(mask)) <= 0.5


def test_search_quality_vs_bruteforce(small_index):
    x, idx = small_index
    rng = np.random.default_rng(6)
    q = x[rng.choice(3000, 50)] + 0.01 * rng.normal(size=(50, 16)).astype(
        np.float32)
    ids, scores, mask = search_single_host(idx, q, k=10)
    true_ids, _ = M.brute_force_topk(q, x, 10, "l2")
    hits = sum(len(set(a.tolist()) & set(b.tolist()))
               for a, b in zip(ids, true_ids))
    recall = hits / true_ids.size
    assert recall > 0.75, f"pyramid recall too low: {recall}"
    # routing actually prunes work
    assert mask.mean() < 0.75


def test_query_frequency_weighted_partitioning(small_index):
    """Sec. III-A hot-item path: when sample queries are supplied, center
    weights come from query-result frequency and partitions balance the
    QUERY load, not the item count."""
    x, _ = small_index
    rng = np.random.default_rng(11)
    # skewed workload: queries hammer a small region of the dataset
    hot = x[rng.choice(300, 200)] + 0.01 * rng.normal(
        size=(200, 16)).astype(np.float32)
    cfg = PyramidConfig(metric="l2", num_shards=4, meta_size=64,
                        sample_size=1500, branching_factor=1,
                        max_degree=12, max_degree_upper=6,
                        ef_construction=40, ef_search=60, kmeans_iters=8)
    idx = build_pyramid_index(x, cfg, sample_queries=hot)
    mask_hot, _ = route_queries(
        idx.meta_arrays(), jnp.asarray(idx.part_of_center),
        jnp.asarray(hot), metric="l2", branching_factor=1, num_shards=4)
    load = np.asarray(mask_hot).sum(axis=0)
    # the hot queries must not all land on one shard
    assert load.max() / max(load.sum(), 1) < 0.9, load


def test_naive_baseline_at_least_as_good(small_index):
    x, idx = small_index
    rng = np.random.default_rng(7)
    q = x[rng.choice(3000, 30)]
    ids_p, _, mask_p = search_single_host(idx, q, k=10)
    ids_n, _, mask_n = search_single_host(idx, q, k=10, naive=True)
    true_ids, _ = M.brute_force_topk(q, x, 10, "l2")

    def rec(ids):
        return sum(len(set(a.tolist()) & set(b.tolist()))
                   for a, b in zip(ids, true_ids)) / true_ids.size

    assert mask_n.all()
    assert rec(ids_n) >= rec(ids_p) - 0.05  # naive touches all shards


# --------------------------------------------------------------------------
# SPMD path vs reference
# --------------------------------------------------------------------------


def test_spmd_search_matches_reference(small_index):
    x, idx = small_index
    mesh = jax.make_mesh((1,), ("model",))
    stacked = stack_shards(idx)
    rng = np.random.default_rng(8)
    q = x[rng.choice(3000, 32)]
    fn = make_pyramid_search_fn(
        mesh, idx.config, k=10, batch=32, ef=60)
    ids_spmd, scores_spmd = fn(
        stacked, idx.meta_arrays(), jnp.asarray(idx.part_of_center),
        jnp.asarray(q))
    ids_ref, scores_ref, _ = search_single_host(idx, q, k=10)
    # same recall against brute force (exact tie-order may differ)
    true_ids, _ = M.brute_force_topk(q, x, 10, "l2")

    def rec(ids):
        return sum(len(set(np.asarray(a).tolist()) & set(b.tolist()))
                   for a, b in zip(ids, true_ids)) / true_ids.size

    r_spmd, r_ref = rec(np.asarray(ids_spmd)), rec(ids_ref)
    assert r_spmd > 0.7
    assert abs(r_spmd - r_ref) < 0.25


def test_spmd_naive_mode(small_index):
    x, idx = small_index
    mesh = jax.make_mesh((1,), ("model",))
    stacked = stack_shards(idx)
    q = x[:16]
    fn = make_pyramid_search_fn(mesh, idx.config, k=5, batch=16, ef=60,
                                naive=True)
    ids, scores = fn(stacked, idx.meta_arrays(),
                     jnp.asarray(idx.part_of_center), jnp.asarray(q))
    # querying with dataset items: top-1 must be the item itself
    top1 = np.asarray(ids)[:, 0]
    assert (top1 == np.arange(16)).mean() > 0.9
