"""End-to-end behaviour tests for the full Pyramid system."""
import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.meta_index import build_pyramid_index
from repro.data.synthetic import clustered_vectors, query_set
from repro.serving.engine import ServingEngine

# full-pipeline module: runs in the slow CI lane, not the fast PR lane
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def system():
    """data -> index -> engine, the full production pipeline."""
    x = clustered_vectors(2500, 16, 20, seed=0)
    cfg = PyramidConfig(metric="l2", num_shards=4, meta_size=64,
                        sample_size=1200, branching_factor=2,
                        max_degree=12, max_degree_upper=6,
                        ef_construction=40, ef_search=60, kmeans_iters=6)
    index = build_pyramid_index(x, cfg)
    return x, index


def test_full_pipeline_quality(system):
    x, index = system
    eng = ServingEngine(index, replicas=1)
    try:
        q = query_set(x, 40, seed=1)
        futures = eng.submit(q, k=10)
        res = [f.result(timeout=60) for f in futures]
        assert len(res) == len(futures)
        true_ids, _ = M.brute_force_topk(q, x, 10, "l2")
        hits = sum(
            len(set(r.ids.tolist()) & set(true_ids[i].tolist()))
            for i, r in enumerate(res))
        assert hits / true_ids.size > 0.7
    finally:
        eng.shutdown()


def test_results_are_deduplicated_and_sorted(system):
    x, index = system
    from repro.core.distributed import search_single_host
    q = query_set(x, 20, seed=2)
    ids, scores, _ = search_single_host(index, q, k=10)
    for row_ids, row_scores in zip(ids, scores):
        valid = row_ids[row_ids >= 0]
        assert len(set(valid.tolist())) == len(valid)
        vs = row_scores[row_ids >= 0]
        assert (np.diff(vs) <= 1e-5).all()


def test_index_is_picklable_roundtrip(tmp_path, system):
    """The paper's GraphConstructor persists indexes for coordinators
    and executors to load."""
    from repro.launch.build_index import load_index, save_index
    x, index = system
    save_index(index, str(tmp_path))
    loaded = load_index(str(tmp_path))
    assert loaded.num_shards == index.num_shards
    np.testing.assert_array_equal(loaded.part_of_center,
                                  index.part_of_center)
    q = query_set(x, 10, seed=3)
    from repro.core.distributed import search_single_host
    ids1, _, _ = search_single_host(index, q, k=5)
    ids2, _, _ = search_single_host(loaded, q, k=5)
    np.testing.assert_array_equal(ids1, ids2)


def test_query_visits_at_most_k_shards(system):
    x, index = system
    from repro.core.distributed import search_single_host
    q = query_set(x, 30, seed=4)
    for kb in (1, 2, 3):
        _, _, mask = search_single_host(index, q, k=5, branching_factor=kb)
        assert (mask.sum(axis=1) <= kb).all()
        assert (mask.sum(axis=1) >= 1).all()
