"""Online index maintenance (repro.store.maintenance): delta-log
compaction into a freshly published version under a live write+query
storm, tombstone durability, shard split/merge + centroid refresh, and
crash recovery at every commit boundary of the compaction protocol.

The storm driver is fully deterministic — batch-drain-step scheduling,
no sleeps: writes journal through the compactor's write path, queries
flow through the brokers-resolved engine between steps, and the
compactor's ``tick()`` fires exactly when the record threshold crosses.
"""
import os

import numpy as np
import pytest

from repro.build.planner import (BuildError, merge_shards, plan_rebalance,
                                 split_shard)
from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.api import Brokers
from repro.core.client import gather_arrays
from repro.core.distributed import search_single_host
from repro.core.meta_index import build_pyramid_index
from repro.core.router import refresh_centroids
from repro.core.updates import add_items, remove_items
from repro.data.synthetic import clustered_vectors, query_set
from repro.store import Compactor, IndexStore


def _cfg(num_shards=4, **kw):
    base = dict(metric="l2", num_shards=num_shards, meta_size=24,
                sample_size=400, branching_factor=2, max_degree=10,
                max_degree_upper=5, ef_construction=30, ef_search=50,
                kmeans_iters=4)
    base.update(kw)
    return PyramidConfig(**base)


def _stored_ids(index):
    return np.sort(np.concatenate([g.ids for g in index.subs]))


def _recall(ids, true_ids):
    return sum(len(set(a.tolist()) & set(b.tolist()))
               for a, b in zip(np.asarray(ids), true_ids)) / true_ids.size


# ---------------------------------------------------------------------------
# the acceptance storm: >= 100 records folded + hot-swapped under serving
# ---------------------------------------------------------------------------


def test_write_query_storm_compacts_and_hot_swaps(tmp_path):
    """Deterministic write+query storm: 100 journaled records (inserts
    and tombstones) stream through the compactor while queries keep
    flowing through the brokers engine. Mid-storm threshold crossings
    fold the log into new published versions and hot-swap the engine;
    at the end the delta log is empty, recall@10 is within 2% of a
    storm-free build over the same final corpus, and no deleted id ever
    appeared in any result."""
    rng = np.random.default_rng(0)
    x = clustered_vectors(600, 12, 8, seed=0)
    idx = build_pyramid_index(x, _cfg())
    store = IndexStore(str(tmp_path))
    store.publish(idx)

    live = {i: x[i] for i in range(600)}     # ground-truth shadow copy
    removed = set()
    next_id = 600

    with Brokers() as brokers:
        brokers.engine_for("storm", store.load(), replicas=1)
        comp = brokers.attach_maintenance(
            "storm", store, threshold_records=40, rebalance=False)

        steps, leaks = 0, set()
        for step in range(80):               # 80 inserts + 20 removes
            base = x[rng.choice(600, 2)]
            new = (base + 0.02 * rng.normal(size=base.shape)
                   ).astype(np.float32)
            comp.add_items(new)
            for v in new:
                live[next_id] = v
                next_id += 1
            if step % 4 == 3:
                pool = [i for i in sorted(live) if i not in removed]
                victims = np.asarray(
                    [pool[int(r)] for r in rng.choice(len(pool), 2,
                                                      replace=False)])
                comp.remove_items(victims)
                removed.update(victims.tolist())
                for v in victims.tolist():
                    del live[v]
            futs = None
            if step % 4 == 0:                # queries keep flowing —
                eng = brokers.get_engine("storm")   # submitted BEFORE the
                q = x[rng.choice(600, 4)]           # tick, so in-flight
                futs = eng.submit(q, k=10)          # futures cross any
            comp.tick()                      # fold + hot-swap (drain
            if futs is not None:             # semantics: they resolve
                ids, _ = gather_arrays(futs, 10, 120)   # on the old engine)
                leaks |= (set(np.asarray(ids).reshape(-1).tolist())
                          & removed)
                steps += 1
        assert steps >= 20 and not leaks, leaks

        comp.run_once(force=True)            # drain the tail
        assert len(comp.index.delta_log()) == 0
        assert comp.cycles >= 3              # >=2 mid-storm + final
        assert comp.folded_records >= 100
        assert comp.truncated_records >= 100

        # final recall on the post-swap engine vs a storm-free build
        live_ids = np.asarray(sorted(live))
        corpus = np.stack([live[i] for i in live_ids.tolist()])
        assert np.array_equal(_stored_ids(comp.index), live_ids)
        q = query_set(corpus, 30, seed=1)
        true_pos, _ = M.brute_force_topk(q, corpus, 10, "l2")
        true_glob = live_ids[true_pos]

        eng = brokers.get_engine("storm")
        got, _ = gather_arrays(eng.submit(q, k=10), 10, 120)
        leaks = set(np.asarray(got).reshape(-1).tolist()) & removed
        assert not leaks, leaks
        storm_recall = _recall(got, true_glob)

    fresh = build_pyramid_index(corpus, _cfg())
    ref_ids, _, _ = search_single_host(fresh, q, k=10)
    ref_recall = _recall(ref_ids, true_pos)
    assert storm_recall >= ref_recall - 0.02, (storm_recall, ref_recall)


# ---------------------------------------------------------------------------
# crash windows: the publish rename is the single commit point
# ---------------------------------------------------------------------------


class SimulatedCrash(RuntimeError):
    pass


def _apply_ops(comp, x):
    """The shared op script for crash tests: 3 insert records + 2
    tombstone records. Returns the expected surviving id set."""
    rng = np.random.default_rng(7)
    for i in range(3):
        base = x[rng.choice(len(x), 3)]
        comp.add_items((base + 0.02 * rng.normal(size=base.shape)
                        ).astype(np.float32))
    comp.remove_items(np.asarray([5, 6, 7]))
    comp.remove_items(np.asarray([len(x) + 1]))   # a storm-era insert
    expected = set(range(len(x))) | set(range(len(x), len(x) + 9))
    return expected - {5, 6, 7, len(x) + 1}


@pytest.mark.faults
@pytest.mark.parametrize("crash_at", ["fold", "publish", "truncate", "swap"])
def test_crash_window_recovers_exactly_once(tmp_path, crash_at):
    """Kill the compactor at each commit boundary — before the publish,
    between publish and truncation, between truncation and the CURRENT
    flip, and mid hot-swap. Recovery via ``ServingEngine.from_store``
    must land on the identical logical state (every journaled record
    applied exactly once, tombstones never resurrected) and answer
    within 2% recall of the fault-free run."""
    from repro.serving.engine import ServingEngine

    x = clustered_vectors(300, 10, 6, seed=3)
    index = build_pyramid_index(x, _cfg(num_shards=2))

    # fault-free control: same ops, completed cycle
    ctrl_store = IndexStore(str(tmp_path / "ctrl"))
    ctrl_store.publish(index)
    ctrl = Compactor(ctrl_store, ctrl_store.load(), rebalance=False)
    expected = _apply_ops(ctrl, x)
    ctrl.run_once(force=True)
    assert np.array_equal(_stored_ids(ctrl.index),
                          np.asarray(sorted(expected)))

    def boom(step):
        if step == crash_at:
            raise SimulatedCrash(step)

    store = IndexStore(str(tmp_path / "crash"))
    store.publish(index)
    comp = Compactor(store, store.load(), rebalance=False,
                     fault_hook=boom)
    assert _apply_ops(comp, x) == expected
    with pytest.raises(SimulatedCrash):
        comp.run_once(force=True)

    eng = ServingEngine.from_store(str(tmp_path / "crash"), replicas=1)
    try:
        # exactly-once: the recovered state holds precisely the
        # surviving ids — nothing lost, duplicated, or resurrected —
        # and is bit-identical to the fault-free run, shard by shard
        assert np.array_equal(_stored_ids(eng.index),
                              np.asarray(sorted(expected)))
        for s in range(len(eng.index.subs)):
            assert np.array_equal(eng.index.subs[s].ids,
                                  ctrl.index.subs[s].ids)
            assert np.array_equal(eng.index.subs[s].data,
                                  ctrl.index.subs[s].data)
        q = query_set(x, 20, seed=4)
        got, _ = gather_arrays(eng.submit(q, k=10), 10, 120)
        assert not (set(np.asarray(got).reshape(-1).tolist())
                    & {5, 6, 7, len(x) + 1})
    finally:
        eng.shutdown()
    # recall within 2% of the fault-free run over the same corpus
    id_to_vec = {}
    for g in ctrl.index.subs:
        for i, v in zip(g.ids.tolist(), g.data):
            id_to_vec[i] = v
    live_ids = np.asarray(sorted(id_to_vec))
    corpus = np.stack([id_to_vec[i] for i in live_ids.tolist()])
    true_pos, _ = M.brute_force_topk(q, corpus, 10, "l2")
    true_glob = live_ids[true_pos]
    ctrl_ids, _, _ = search_single_host(ctrl.index, q, k=10)
    assert (_recall(got, true_glob)
            >= _recall(ctrl_ids, true_glob) - 0.02)


# ---------------------------------------------------------------------------
# tombstone durability (satellite 2)
# ---------------------------------------------------------------------------


def test_insert_only_log_stays_byte_identical(tmp_path):
    """Insert-only delta logs must not grow an ``op`` field — replay
    compatibility with logs written before tombstones existed."""
    x = clustered_vectors(400, 10, 6, seed=5)
    index = build_pyramid_index(x, _cfg(num_shards=2))
    store = IndexStore(str(tmp_path))
    store.publish(index)
    idx = store.load()
    add_items(idx, clustered_vectors(6, 10, 2, seed=6))
    add_items(idx, clustered_vectors(4, 10, 2, seed=7))
    log_path = idx.delta_log().dir
    with open(os.path.join(log_path, "LOG")) as f:
        text = f.read()
    assert text.count("\n") == 2
    assert '"op"' not in text
    remove_items(idx, np.asarray([0, 1]))
    with open(os.path.join(log_path, "LOG")) as f:
        lines = f.read().splitlines()
    assert '"op"' not in lines[0] and '"op"' not in lines[1]
    assert '"remove"' in lines[2]


def test_tombstones_survive_restart(tmp_path):
    """``remove_items`` after a publish must not resurrect on reload —
    the regression this PR's delta-log tombstones exist to prevent."""
    x = clustered_vectors(400, 10, 6, seed=8)
    index = build_pyramid_index(x, _cfg(num_shards=2))
    store = IndexStore(str(tmp_path))
    store.publish(index)
    idx = store.load()
    add_items(idx, clustered_vectors(5, 10, 2, seed=9))
    remove_items(idx, np.asarray([3, 4, 400, 401]))
    add_items(idx, clustered_vectors(3, 10, 2, seed=10))

    recovered = store.load()    # replays inserts AND tombstones in order
    assert np.array_equal(_stored_ids(recovered), _stored_ids(idx))
    gone = {3, 4, 400, 401}
    assert not (set(_stored_ids(recovered).tolist()) & gone)
    ids, _, _ = search_single_host(recovered, x[[3, 4]], k=10)
    assert not (set(np.asarray(ids).reshape(-1).tolist()) & gone)


# ---------------------------------------------------------------------------
# rebalance planning + ops (tentpole satellites)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def balanced_index():
    x = clustered_vectors(800, 12, 8, seed=11)
    return x, build_pyramid_index(x, _cfg())


def test_plan_rebalance_balanced_is_noop(balanced_index):
    _, idx = balanced_index
    assert plan_rebalance(idx) is None


def test_plan_rebalance_size_skew_splits(balanced_index):
    x, base = balanced_index
    idx = store_roundtrip_copy(base)
    # pile inserts near one shard's items until it dominates
    s = int(np.argmax([g.n for g in idx.subs]))
    seed_pts = idx.subs[s].data
    rng = np.random.default_rng(12)
    for _ in range(9):
        pick = seed_pts[rng.choice(len(seed_pts), 50)]
        add_items(idx, (pick + 0.01 * rng.normal(size=pick.shape)
                        ).astype(np.float32), log_delta=False)
    sizes = [g.n for g in idx.subs]
    heavy = int(np.argmax(sizes))
    assert sizes[heavy] > 2.0 * (sum(sizes) / len(sizes))
    op = plan_rebalance(idx, split_factor=2.0)
    assert op == ("split", heavy)

    w = len(idx.subs)
    before = _stored_ids(idx)
    split_shard(idx, heavy)
    assert len(idx.subs) == w + 1
    assert idx.config.num_shards == w + 1
    assert idx.subs[heavy].n > 0 and idx.subs[w].n > 0
    assert np.array_equal(_stored_ids(idx), before)   # no item lost
    # routing still lands on every item's shard: self-hit stays high
    probe = np.concatenate([idx.subs[heavy].data[:20],
                            idx.subs[w].data[:20]])
    want = np.concatenate([idx.subs[heavy].ids[:20],
                           idx.subs[w].ids[:20]])
    ids, _, _ = search_single_host(idx, probe, k=4)
    hit = np.asarray([w_ in row for w_, row in
                      zip(want.tolist(), np.asarray(ids).tolist())])
    assert hit.mean() >= 0.9


def test_plan_rebalance_latency_skew_splits(balanced_index):
    _, base = balanced_index
    idx = store_roundtrip_copy(base)
    sizes = [g.n for g in idx.subs]
    hot = int(np.argmax(sizes))
    lat = {s: {"n": 100, "p50": 1.0, "p99": 2.0}
           for s in range(len(sizes))}
    lat[hot] = {"n": 100, "p50": 5.0, "p99": 40.0}
    op = plan_rebalance(idx, engine_stats={"latency": lat},
                        latency_factor=4.0)
    assert op == ("split", hot)
    # without stats the same index plans nothing (sizes are balanced)
    assert plan_rebalance(idx) is None


def test_merge_small_shards(balanced_index):
    x, base = balanced_index
    idx = store_roundtrip_copy(base)
    sizes = [g.n for g in idx.subs]
    small = np.argsort(sizes)[:2].tolist()
    # shrink the two smallest shards to a handful of items each
    for s in small:
        victims = idx.subs[s].ids[4:]
        if victims.size:
            remove_items(idx, victims, log_delta=False)
    op = plan_rebalance(idx, merge_factor=0.25)
    a, b = sorted(small)
    assert op == ("merge", a, b)

    w = len(idx.subs)
    before = set(_stored_ids(idx).tolist())
    merge_shards(idx, a, b)
    assert len(idx.subs) == w - 1
    assert idx.config.num_shards == w - 1
    assert set(_stored_ids(idx).tolist()) == before
    part = np.asarray(idx.part_of_center)
    assert part.min() >= 0 and part.max() < w - 1
    probe = idx.subs[a].data[:10]
    ids, _, _ = search_single_host(idx, probe, k=4)
    hit = [i in row for i, row in
           zip(idx.subs[a].ids[:10].tolist(), np.asarray(ids).tolist())]
    assert np.mean(hit) >= 0.9


def test_split_shard_rejects_degenerate(balanced_index):
    _, base = balanced_index
    idx = store_roundtrip_copy(base)
    from repro.core import hnsw as H
    d = idx.subs[0].data.shape[1]
    idx.subs[0] = H.empty_hnsw(d, metric="l2",
                               max_degree=idx.config.max_degree)
    idx.invalidate_device_cache()
    with pytest.raises(BuildError, match="cannot split"):
        split_shard(idx, 0)


def test_refresh_centroids_preserves_quality(balanced_index):
    x, base = balanced_index
    idx = store_roundtrip_copy(base)
    rng = np.random.default_rng(13)
    drift = clustered_vectors(200, 12, 4, seed=14) + 3.0
    add_items(idx, drift.astype(np.float32), log_delta=False)
    refresh_centroids(idx)
    assert idx.build_stats["centroid_refreshes"] == 1
    # every live vector must still be found at its own position
    probe_ids = rng.choice(_stored_ids(idx), 40, replace=False)
    id_to_vec = {}
    for g in idx.subs:
        for i, v in zip(g.ids.tolist(), g.data):
            id_to_vec[i] = v
    probe = np.stack([id_to_vec[i] for i in probe_ids.tolist()])
    ids, _, _ = search_single_host(idx, probe, k=4)
    hit = [i in row for i, row in
           zip(probe_ids.tolist(), np.asarray(ids).tolist())]
    assert np.mean(hit) >= 0.9


def store_roundtrip_copy(index):
    """Deep-copy an index the way the compactor does: through the
    store's serialisation (keeps fixtures immutable across tests)."""
    import tempfile
    with tempfile.TemporaryDirectory() as root:
        store = IndexStore(root)
        store.publish(index)
        return store.load(attach_delta=False)


# ---------------------------------------------------------------------------
# compactor unit behaviour
# ---------------------------------------------------------------------------


def test_run_once_below_threshold_is_noop(tmp_path):
    x = clustered_vectors(300, 10, 6, seed=15)
    store = IndexStore(str(tmp_path))
    store.publish(build_pyramid_index(x, _cfg(num_shards=2)))
    comp = Compactor(store, store.load(), threshold_records=10,
                     rebalance=False)
    comp.add_items(clustered_vectors(3, 10, 2, seed=16))
    assert comp.run_once() is None          # 1 record < threshold 10
    assert comp.cycles == 0
    assert comp.tick() is None
    vid = comp.run_once(force=True)         # force folds regardless
    assert vid is not None and comp.cycles == 1
    assert len(comp.index.delta_log()) == 0
    st = comp.stats()
    assert st["folded_records"] == 1 and st["pending_records"] == 0


def test_compactor_requires_store_attached_index():
    x = clustered_vectors(300, 10, 6, seed=17)
    idx = build_pyramid_index(x, _cfg(num_shards=2))

    class FakeStore:
        root = "nowhere"
    comp = Compactor(FakeStore(), idx, rebalance=False)
    with pytest.raises(ValueError, match="store-attached"):
        comp.run_once(force=True)


def test_compaction_cycle_applies_split(tmp_path):
    """A size-skewed shard splits during the cycle and the published
    version carries the new shard count (reload agrees)."""
    x = clustered_vectors(600, 12, 8, seed=18)
    store = IndexStore(str(tmp_path))
    store.publish(build_pyramid_index(x, _cfg()))
    comp = Compactor(store, store.load(), split_factor=2.0)
    idx = comp.index
    s = int(np.argmax([g.n for g in idx.subs]))
    seed_pts = idx.subs[s].data
    rng = np.random.default_rng(19)
    for _ in range(8):
        pick = seed_pts[rng.choice(len(seed_pts), 50)]
        comp.add_items((pick + 0.01 * rng.normal(size=pick.shape)
                        ).astype(np.float32))
    w = len(idx.subs)
    comp.run_once(force=True)
    assert comp.rebalance_ops and comp.rebalance_ops[0][0] == "split"
    assert len(comp.index.subs) == w + 1
    reloaded = store.load()
    assert reloaded.config.num_shards == w + 1
    assert np.array_equal(_stored_ids(reloaded), _stored_ids(comp.index))
