"""Metadata-filtered kNN: kernel/oracle/numpy parity, the sel-1.0
bit-identity contract, empty filters, tag persistence through the delta
log + compaction, the engine's filtered serving path, and the merge
alive-mask (tombstones must never crowd live results out of k)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.core import filters as F
from repro.core import hnsw as H
from repro.core import metrics as M
from repro.core.client import gather
from repro.core.distributed import search_single_host
from repro.core.meta_index import build_pyramid_index
from repro.core.updates import add_items, remove_items, set_item_tags
from repro.data.synthetic import query_set
from repro.kernels.beam_search.kernel import beam_search_pallas
from repro.kernels.beam_search.ops import _apply_filter
from repro.kernels.beam_search.ref import beam_search_ref
from repro.kernels.merge_topk.ref import merge_topk_np
from repro.serving.engine import ServingEngine
from repro.store import IndexStore

METRICS = ("l2", "ip", "angular")


def _make_index(metric: str, n=600, d=10, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    cfg = PyramidConfig(metric=metric, num_shards=3, meta_size=24,
                        sample_size=min(n, 400), branching_factor=2,
                        max_degree=10, max_degree_upper=5,
                        ef_construction=40, ef_search=60, kmeans_iters=5,
                        seed=seed)
    return x, build_pyramid_index(x, cfg)


def _random_tags(n, seed=3, bits=4):
    rng = np.random.default_rng(seed)
    return rng.integers(1, 1 << bits, size=n).astype(np.int64)


# ---------------------------------------------------------------------------
# selectivity 1.0: a filter every item matches must be bit-identical to
# the unfiltered search — on every metric and every search path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", METRICS)
def test_sel1_bit_identical_fused_pipeline(metric):
    x, index = _make_index(metric)
    set_item_tags(index, np.arange(len(x)), np.ones(len(x), np.int64))
    q = query_set(x, 16, seed=1)
    ids_u, scores_u, _ = search_single_host(index, q, k=10)
    ids_f, scores_f, _ = search_single_host(index, q, k=10,
                                            filter_tags=1)
    np.testing.assert_array_equal(ids_f, ids_u)
    np.testing.assert_array_equal(scores_f, scores_u)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("impl", ("fused", "loop"))
def test_sel1_bit_identical_graph_paths(metric, impl):
    rng = np.random.default_rng(2)
    x = rng.normal(size=(300, 8)).astype(np.float32)
    g = H.build_hnsw(x, metric=metric, max_degree=8, max_degree_upper=4,
                     ef_construction=40, seed=0,
                     tags=np.ones(len(x), np.int64))
    q = np.asarray(M.preprocess_queries(
        rng.normal(size=(8, 8)).astype(np.float32), metric))
    ga = g.device_arrays()
    tw = jnp.asarray(F.split_tag_words(g.tags_or_zeros()))
    fw = jnp.asarray(F.filter_words(np.ones(len(q), np.int64)))
    ids_u, scores_u = H.hnsw_search(ga, jnp.asarray(q), metric=metric,
                                    k=10, ef=60, impl=impl)
    ids_f, scores_f = H.hnsw_search(ga, jnp.asarray(q), metric=metric,
                                    k=10, ef=60, impl=impl,
                                    tag_words=tw, filter_words=fw)
    np.testing.assert_array_equal(np.asarray(ids_f), np.asarray(ids_u))
    np.testing.assert_array_equal(np.asarray(scores_f),
                                  np.asarray(scores_u))
    # numpy oracle obeys the same identity
    nids_u, nsc_u = H.search_numpy(g, q, 10, ef=60)
    nids_f, nsc_f = H.search_numpy(g, q, 10, ef=60, filter_tags=1)
    np.testing.assert_array_equal(nids_f, nids_u)
    np.testing.assert_array_equal(nsc_f, nsc_u)


def test_filtered_kernel_oracle_parity():
    """Non-trivial filters: the Pallas kernel (interpret) and the jnp
    oracle agree exactly after the shared alive-mask, and every
    surviving candidate actually matches its slot's filter."""
    rng = np.random.default_rng(7)
    s, n, d, c, m0 = 2, 64, 6, 8, 6
    x = rng.integers(-8, 9, size=(s, n, d)).astype(np.float32)
    bottom = rng.integers(-1, n, size=(s, n, m0)).astype(np.int32)
    queries = rng.integers(-8, 9, size=(s, c, d)).astype(np.float32)
    entries = rng.integers(0, n, size=(s, c)).astype(np.int32)
    tags = rng.integers(1, 16, size=(s, n)).astype(np.int64)
    filters = rng.integers(0, 16, size=(s, c)).astype(np.int64)

    tw = jnp.asarray(F.split_tag_words(tags))
    fw = jnp.asarray(F.filter_words(filters))
    kw = dict(metric="l2", ef=16, max_iters=100)
    s_k, n_k = beam_search_pallas(
        jnp.asarray(x), jnp.asarray(bottom), jnp.asarray(queries),
        jnp.asarray(entries), interpret=True, **kw)
    s_k = jnp.where(n_k >= 0, s_k, -jnp.inf)
    s_k, n_k = _apply_filter(s_k, n_k, tw, fw)
    s_r, n_r = beam_search_ref(
        jnp.asarray(x), jnp.asarray(bottom), jnp.asarray(queries),
        jnp.asarray(entries), **kw)
    s_r, n_r = _apply_filter(s_r, n_r, tw, fw)
    np.testing.assert_array_equal(np.asarray(n_k), np.asarray(n_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-5, atol=1e-5)
    nodes = np.asarray(n_k)
    for si in range(s):
        for ci in range(c):
            for v in nodes[si, ci]:
                if v >= 0:
                    assert F.alive_np(tags[si, v], filters[si, ci])


def test_filtered_results_match_filter_and_fill_k():
    x, index = _make_index("l2", n=800)
    tags = _random_tags(len(x))
    set_item_tags(index, np.arange(len(x)), tags)
    q = query_set(x, 12, seed=4)
    f = 0b0100     # ~50% selectivity under 4 random bits
    ids, scores, _ = search_single_host(index, q, k=10, filter_tags=f)
    alive = ids >= 0
    assert alive.all(), "moderate selectivity must fill k"
    assert F.alive_np(tags[ids[alive]], f).all()
    # sorted best-first
    assert (np.diff(np.asarray(scores), axis=1) <= 1e-5).all()


def test_sel0_empty_and_no_crash():
    x, index = _make_index("l2")
    tags = _random_tags(len(x), bits=4)   # bits 0..3 only
    set_item_tags(index, np.arange(len(x)), tags)
    q = query_set(x, 6, seed=5)
    unknown = np.int64(1) << 17           # no item carries this bit
    ids, scores, _ = search_single_host(index, q, k=10,
                                        filter_tags=unknown)
    assert (np.asarray(ids) == -1).all()
    assert np.isneginf(np.asarray(scores)).all()


# ---------------------------------------------------------------------------
# persistence: tags survive publish -> delta replay -> compaction
# ---------------------------------------------------------------------------


def test_tags_roundtrip_store_and_delta(tmp_path):
    x, index = _make_index("l2", n=400)
    tags = _random_tags(len(x))
    set_item_tags(index, np.arange(len(x)), tags)
    store = IndexStore(str(tmp_path / "store"))
    store.publish(index)   # publish attaches the delta log

    rng = np.random.default_rng(9)
    extra = rng.normal(size=(20, x.shape[1])).astype(np.float32)
    extra_tags = _random_tags(20, seed=11)
    add_items(index, extra, np.arange(1000, 1020), tags=extra_tags)
    set_item_tags(index, [0, 1], np.int64(1 << 9))
    remove_items(index, [2, 1005])

    loaded = store.load()
    want = index.tags_host()
    got = loaded.tags_host()
    # order within shards is deterministic (same build + same replay)
    np.testing.assert_array_equal(got, want)
    q = query_set(x, 8, seed=6)
    f = np.int64(1 << 9)
    ids_a, sc_a, _ = search_single_host(index, q, k=5, filter_tags=f)
    ids_b, sc_b, _ = search_single_host(loaded, q, k=5, filter_tags=f)
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_array_equal(sc_a, sc_b)


def test_untagged_delta_records_stay_untagged(tmp_path):
    """Inserting without tags must journal the pre-tag record format
    (no "tags" array) and keep the untagged fast path (`tags is None`)
    after replay."""
    x, index = _make_index("l2", n=300)
    store = IndexStore(str(tmp_path / "store"))
    store.publish(index)
    add_items(index, np.random.default_rng(0).normal(
        size=(8, x.shape[1])).astype(np.float32))
    loaded = store.load()
    assert all(g.tags is None for g in loaded.subs)
    assert not loaded.tags_host().any()


def test_compactor_folds_tags(tmp_path):
    from repro.store.maintenance import Compactor
    x, index = _make_index("l2", n=300)
    store = IndexStore(str(tmp_path / "store"))
    store.publish(index)
    comp = Compactor(store, store.load(), rebalance=False)
    rng = np.random.default_rng(1)
    comp.add_items(rng.normal(size=(10, x.shape[1])).astype(np.float32),
                   np.arange(2000, 2010),
                   tags=np.full(10, 1 << 5, np.int64))
    comp.set_item_tags(np.arange(2000, 2005), np.int64(1 << 6))
    assert comp.run_once(force=True) is not None
    loaded = store.load()
    tags = {}
    for g in loaded.subs:
        for i, gid in enumerate(np.asarray(g.ids)):
            tags[int(gid)] = int(g.tags_or_zeros()[i])
    assert tags[2001] == (1 << 6)    # set_item_tags assigns, not ORs
    assert tags[2007] == (1 << 5)


# ---------------------------------------------------------------------------
# serving: engine-side filtered search + pre-merge alive-mask
# ---------------------------------------------------------------------------


def test_engine_filtered_search_matches_single_host():
    x, index = _make_index("l2", n=800)
    tags = _random_tags(len(x))
    set_item_tags(index, np.arange(len(x)), tags)
    q = query_set(x, 10, seed=8)
    f = 0b0010
    want_ids, _, _ = search_single_host(index, q, k=10, filter_tags=f)
    eng = ServingEngine(index, hedge=False)
    try:
        got = gather(eng.submit(q, k=10, filter_tags=f), 60.0)
        # a mixed batch: filtered and unfiltered queries coexist
        mixed = gather(eng.submit(
            q, k=10,
            filter_tags=np.asarray([f, 0] * 5, np.int64)), 60.0)
    finally:
        eng.shutdown()
    for i, r in enumerate(got):
        assert F.alive_np(tags[r.ids], f).all()
        overlap = len(set(r.ids.tolist())
                      & set(np.asarray(want_ids[i]).tolist()))
        assert overlap >= 8, f"query {i}: {overlap}/10 vs single-host"
    for i, r in enumerate(mixed):
        if i % 2 == 0:
            assert F.alive_np(tags[r.ids], f).all()
        else:
            assert len(r.ids) == 10 and (r.ids >= 0).all()


def test_engine_unfiltered_untagged_and_sel0():
    x, index = _make_index("l2", n=400)   # untagged corpus
    q = query_set(x, 4, seed=2)
    eng = ServingEngine(index, hedge=False)
    try:
        plain = gather(eng.submit(q, k=5), 60.0)
        filt = gather(eng.submit(q, k=5, filter_tags=3), 60.0)
    finally:
        eng.shutdown()
    for r in plain:
        assert (r.ids >= 0).all()
    for r in filt:     # selectivity 0 on an untagged corpus: empty, fast
        assert len(r.ids) == 0


def test_merge_alive_mask_pre_merge():
    """A dead (tombstoned/filtered) candidate with the best score must
    not crowd a live candidate out of the merged top-k."""
    scores = np.asarray([[9.0, 5.0, 4.0, 3.0]], np.float32)
    ids = np.asarray([[7, 1, 2, 3]], np.int64)
    alive = np.asarray([[False, True, True, True]])
    s, i = merge_topk_np(scores, ids, k=3, alive=alive)
    np.testing.assert_array_equal(i[0], [1, 2, 3])
    np.testing.assert_array_equal(s[0], [5.0, 4.0, 3.0])
    # without the mask the dead id wins the top slot
    s2, i2 = merge_topk_np(scores, ids, k=3)
    assert i2[0, 0] == 7
