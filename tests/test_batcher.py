"""Continuous batching: slot reuse, correctness vs sequential decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.registry import get_arch
from repro.models.transformer import forward, init_params, make_cache
from repro.serving.batcher import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def model():
    cfg = get_arch("qwen3-1.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _sequential_greedy(params, cfg, prompt, n_new, max_seq):
    """Reference: single-sequence greedy decode."""
    cache = make_cache(cfg, 1, max_seq)
    toks = list(prompt)
    out = []
    for t in range(len(prompt)):
        logits, _, cache = forward(
            params, cfg, jnp.asarray([[toks[t]]], jnp.int32), cache=cache,
            decode_pos=jnp.asarray([t], jnp.int32))
    nxt = int(jnp.argmax(logits[0, 0]))
    out.append(nxt)
    pos = len(prompt)
    while len(out) < n_new:
        logits, _, cache = forward(
            params, cfg, jnp.asarray([[nxt]], jnp.int32), cache=cache,
            decode_pos=jnp.asarray([pos], jnp.int32))
        nxt = int(jnp.argmax(logits[0, 0]))
        out.append(nxt)
        pos += 1
    return out


def test_batcher_matches_sequential(model):
    cfg, params = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=l).astype(np.int32)
               for l in (5, 9, 7)]
    n_new = 6
    b = ContinuousBatcher(params, cfg, num_slots=2, max_seq=32)
    for i, p in enumerate(prompts):
        b.submit(Request(i, p, max_new_tokens=n_new))
    done = b.run_until_drained()
    assert len(done) == 3
    by_id = {c.request_id: c for c in done}
    for i, p in enumerate(prompts):
        ref = _sequential_greedy(params, cfg, p, n_new, 32)
        assert by_id[i].tokens == ref, (i, by_id[i].tokens, ref)


def test_batcher_slot_reuse_and_eviction(model):
    cfg, params = model
    rng = np.random.default_rng(1)
    # more requests than slots: slots must be reused
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=4).astype(
        np.int32), max_new_tokens=3) for i in range(5)]
    b = ContinuousBatcher(params, cfg, num_slots=2, max_seq=16)
    for r in reqs:
        b.submit(r)
    done = b.run_until_drained()
    assert sorted(c.request_id for c in done) == [0, 1, 2, 3, 4]
    assert all(len(c.tokens) == 3 for c in done)


def test_batcher_eos_stops_early(model):
    cfg, params = model
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    # find what greedy emits first, use it as eos -> stops after 1 token
    ref = _sequential_greedy(params, cfg, p, 1, 32)
    b = ContinuousBatcher(params, cfg, num_slots=1, max_seq=32)
    b.submit(Request(0, p, max_new_tokens=10, eos_id=ref[0]))
    done = b.run_until_drained()
    assert len(done) == 1
    assert done[0].tokens[0] == ref[0]
    assert len(done[0].tokens) == 1


def test_batcher_greedy_deterministic_across_num_slots(model):
    """Greedy tokens are a property of the request, not the schedule:
    any slot count yields identical per-request outputs."""
    cfg, params = model
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=l).astype(np.int32)
               for l in (4, 8, 6, 10, 5)]
    runs = []
    for num_slots in (1, 2, 4):
        b = ContinuousBatcher(params, cfg, num_slots=num_slots, max_seq=32)
        for i, p in enumerate(prompts):
            b.submit(Request(i, p, max_new_tokens=5))
        done = b.run_until_drained()
        runs.append({c.request_id: c.tokens for c in done})
        assert sorted(runs[-1]) == list(range(len(prompts)))
    assert runs[0] == runs[1] == runs[2]


def test_batcher_mixed_lengths_recycles_slots(model):
    """Mixed prompt and output lengths: short sequences free their slot
    early and the freed slot serves later requests (strictly more
    requests complete than slots exist), all matching the sequential
    reference."""
    cfg, params = model
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, size=l).astype(np.int32)
               for l in (3, 11, 5, 9, 4, 7)]
    n_new = [2, 6, 3, 5, 2, 4]
    b = ContinuousBatcher(params, cfg, num_slots=2, max_seq=32)
    for i, p in enumerate(prompts):
        b.submit(Request(i, p, max_new_tokens=n_new[i]))
    done = b.run_until_drained()
    assert sorted(c.request_id for c in done) == list(range(len(prompts)))
    by_id = {c.request_id: c for c in done}
    for i, p in enumerate(prompts):
        assert len(by_id[i].tokens) == n_new[i]
        ref = _sequential_greedy(params, cfg, p, n_new[i], 32)
        assert by_id[i].tokens == ref, (i, by_id[i].tokens, ref)


def test_batcher_slot_reuse_after_eviction_is_clean(model):
    """A slot that served a long sequence must not leak cache state into
    the next request admitted after its eviction: the recycled slot's
    output equals a fresh single-slot run."""
    cfg, params = model
    rng = np.random.default_rng(6)
    first = rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
    second = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
    b = ContinuousBatcher(params, cfg, num_slots=1, max_seq=32)
    b.submit(Request(0, first, max_new_tokens=6))
    b.submit(Request(1, second, max_new_tokens=6))   # waits for slot 0
    done = b.run_until_drained()
    by_id = {c.request_id: c for c in done}
    assert by_id[1].tokens == _sequential_greedy(params, cfg, second, 6, 32)
