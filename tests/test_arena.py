"""ShardArena + fused pipeline: oracle parity on all three metrics,
three-way path parity (SPMD / single-host / engine) incl. MIPS
replication dedup, and the one-arena-per-index memory model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import PyramidConfig
from repro.core import hnsw as H
from repro.core import metrics as M
from repro.core.arena import ShardArena, arena_search
from repro.core.distributed import (make_pyramid_search_fn,
                                    search_single_host,
                                    search_single_host_python)
from repro.core.meta_index import build_pyramid_index
from repro.core.router import route_queries
from repro.data.synthetic import clustered_vectors


def _mips_data(seed=0, n=2000, d=12):
    rng = np.random.default_rng(seed)
    dirs = rng.normal(size=(16, d))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    asg = rng.integers(0, 16, size=n)
    x = dirs[asg] + 0.2 * rng.normal(size=(n, d))
    norms = rng.lognormal(mean=0.0, sigma=0.8, size=(n, 1))
    return (x * norms).astype(np.float32), \
        rng.normal(size=(32, d)).astype(np.float32)


def _build(x, metric, replication_r=0, branching_factor=2, num_shards=4):
    cfg = PyramidConfig(metric=metric, num_shards=num_shards, meta_size=48,
                        sample_size=1200, branching_factor=branching_factor,
                        max_degree=12, max_degree_upper=6,
                        ef_construction=40, ef_search=60,
                        replication_r=replication_r, kmeans_iters=6)
    return build_pyramid_index(x, cfg)


def _oracle_search(index, queries, k):
    """Host-side Alg. 4 oracle: ``search_numpy`` per routed shard + a
    plain-python first-occurrence dedup merge. Fully independent of the
    fused pipeline and of the merge_topk kernel family."""
    cfg = index.config
    metric = "ip" if cfg.is_mips else cfg.metric
    q = M.preprocess_queries(queries, cfg.metric)
    mask, _ = route_queries(
        index.meta_arrays(), jnp.asarray(index.part_of_center),
        jnp.asarray(q), metric=metric,
        branching_factor=cfg.branching_factor,
        num_shards=index.num_shards, ef=max(64, cfg.branching_factor))
    mask = np.asarray(mask)
    out = np.full((q.shape[0], k), -1, np.int64)
    for i in range(q.shape[0]):
        found = []
        for s in np.where(mask[i])[0]:
            ids, scores = H.search_numpy(
                index.subs[s], q[i][None, :], k=k, ef=cfg.ef_search)
            found += [(float(sc), int(v)) for v, sc in
                      zip(ids[0], scores[0]) if v >= 0]
        seen = set()
        j = 0
        for sc, v in sorted(found, key=lambda t: -t[0]):
            if v in seen:
                continue
            seen.add(v)
            out[i, j] = v
            j += 1
            if j == k:
                break
    return out


def _recall(ids, true_ids):
    return sum(len(set(np.asarray(a).tolist()) & set(b.tolist()))
               for a, b in zip(ids, true_ids)) / true_ids.size


def _assert_deduped(ids):
    for row in np.asarray(ids):
        valid = row[row >= 0]
        assert len(set(valid.tolist())) == len(valid), row


@pytest.mark.parametrize("metric", ["l2", "angular", "ip"])
def test_arena_search_matches_search_numpy_oracle(metric):
    if metric == "l2":
        x = clustered_vectors(2000, 12, 16, seed=1)
        rng = np.random.default_rng(2)
        q = x[rng.choice(2000, 32)] + 0.01 * rng.normal(
            size=(32, 12)).astype(np.float32)
        idx = _build(x, metric)
    else:
        x, q = _mips_data(seed=3)
        # ip exercises Alg. 5 replication: one global id in two shards
        idx = _build(x, metric, replication_r=40 if metric == "ip" else 0)
    if metric == "ip":
        assert idx.build_stats["replicated_items"] > 0
    xn = M.preprocess_dataset(x, metric)
    qn = M.preprocess_queries(q, metric)
    bf_metric = "ip" if metric != "l2" else "l2"
    true_ids, _ = M.brute_force_topk(qn, xn, 10, bf_metric)

    cfg = idx.config
    m = "ip" if cfg.is_mips else metric
    ids, scores, mask = arena_search(
        idx.arena(), idx.meta_arrays(), jnp.asarray(idx.part_of_center),
        jnp.asarray(qn), metric=m, k=10, ef=cfg.ef_search,
        branching_factor=cfg.branching_factor)
    ids = np.asarray(ids)
    _assert_deduped(ids)
    oracle_ids = _oracle_search(idx, q, k=10)
    _assert_deduped(oracle_ids)
    r_fused, r_oracle = _recall(ids, true_ids), _recall(oracle_ids, true_ids)
    assert r_fused > 0.5, (metric, r_fused)
    assert abs(r_fused - r_oracle) < 0.25, (metric, r_fused, r_oracle)


def test_three_way_parity_with_mips_replication_dedup():
    """SPMD / single-host / engine must agree, including on the MIPS
    replication case where one global id comes back from two shards."""
    from repro.serving.engine import ServingEngine

    x, q = _mips_data(seed=5)
    idx = _build(x, "ip", replication_r=60, branching_factor=2)
    assert idx.build_stats["replicated_items"] > 0
    true_ids, _ = M.brute_force_topk(q, x, 10, "ip")

    ids_host, _, _ = search_single_host(idx, q, k=10)
    _assert_deduped(ids_host)

    mesh = jax.make_mesh((1,), ("model",))
    fn = make_pyramid_search_fn(mesh, idx.config, k=10, batch=len(q),
                                ef=idx.config.ef_search)
    ids_spmd, _ = fn(idx.arena(), idx.meta_arrays(),
                     jnp.asarray(idx.part_of_center), jnp.asarray(q))
    ids_spmd = np.asarray(ids_spmd)
    _assert_deduped(ids_spmd)

    eng = ServingEngine(idx, replicas=1)
    try:
        futures = eng.submit(q, k=10)
        results = [f.result(timeout=60) for f in futures]
    finally:
        eng.shutdown()
    ids_eng = [r.ids for r in results]
    _assert_deduped(ids_eng)

    recalls = {
        "host": _recall(ids_host, true_ids),
        "spmd": _recall(ids_spmd, true_ids),
        "engine": _recall(ids_eng, true_ids),
    }
    for name, r in recalls.items():
        assert r > 0.5, (name, recalls)
    rs = list(recalls.values())
    assert max(rs) - min(rs) < 0.25, recalls


def test_fused_matches_legacy_python_loop():
    x = clustered_vectors(2000, 12, 16, seed=7)
    rng = np.random.default_rng(8)
    q = x[rng.choice(2000, 24)] + 0.01 * rng.normal(
        size=(24, 12)).astype(np.float32)
    idx = _build(x, "l2")
    ids_f, _, mask_f = search_single_host(idx, q, k=10)
    ids_p, _, mask_p = search_single_host_python(idx, q, k=10)
    np.testing.assert_array_equal(mask_f, mask_p)
    same = sum(set(a[a >= 0].tolist()) == set(b[b >= 0].tolist())
               for a, b in zip(ids_f, ids_p))
    assert same >= int(0.9 * len(q)), (same, len(q))


def test_one_arena_per_index_shared_views():
    x = clustered_vectors(1200, 8, 8, seed=9)
    idx = _build(x, "l2")
    arena = idx.arena()
    assert idx.arena() is arena                  # memoised
    assert arena.num_shards == idx.num_shards
    # equal-padded: every shard view has identical shapes => one jit
    # compile serves every executor in an engine
    v0 = arena.shard_view(0)
    assert arena.shard_view(0) is v0             # memoised view
    for s in range(arena.num_shards):
        assert arena.shard_view(s).data.shape == v0.data.shape
    # sub_arrays is a view of the same arena (migration surface)
    assert idx.sub_arrays(1) is arena.shard_view(1)
    # pad rows are inert: id -1, no neighbours
    n1 = idx.subs[1].n
    pad_ids = np.asarray(arena.ids[1][n1:])
    assert (pad_ids == -1).all()
    assert (np.asarray(arena.bottom[1][n1:]) == -1).all()


def test_arena_cache_dropped_on_pickle_and_update():
    import pickle

    from repro.core.updates import add_items
    x = clustered_vectors(1200, 8, 8, seed=10)
    idx = _build(x, "l2")
    a1 = idx.arena()
    blob = pickle.dumps(idx)
    loaded = pickle.loads(blob)
    assert getattr(loaded, "_arena", None) is None   # derived, not stored
    add_items(idx, clustered_vectors(40, 8, 4, seed=11))
    assert idx.arena() is not a1                     # invalidated


def test_stacked_shards_alias_still_works():
    from repro.core.distributed import StackedShards, stack_shards
    assert StackedShards is ShardArena
    x = clustered_vectors(1200, 8, 8, seed=12)
    idx = _build(x, "l2")
    assert stack_shards(idx) is idx.arena()
