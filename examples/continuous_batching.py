"""Continuous batching: more requests than decode slots, slots recycled
as sequences finish (vLLM-style scheduling on this framework).

This drives the LM decode engine (`serving.batcher`); similarity-search
traffic has the analogous asynchronous surface in
`repro.core.client.PyramidClient` — `search_batch` returns
`SearchFuture`s and `as_completed` streams merges as they land, so a
retrieval-augmented decode loop can overlap lookups with decoding
(see API.md and examples/serve_cluster.py).

PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.common.registry import get_arch
from repro.models.transformer import init_params
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.sampler import SamplerConfig


def main() -> None:
    cfg = get_arch("qwen3-1.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batcher = ContinuousBatcher(
        params, cfg, num_slots=4, max_seq=48,
        sampler=SamplerConfig(greedy=True))

    n_reqs = 10
    for i in range(n_reqs):
        plen = int(rng.integers(4, 12))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        batcher.submit(Request(i, prompt, max_new_tokens=int(
            rng.integers(4, 10))))

    t0 = time.time()
    done = batcher.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(c.tokens) for c in done)
    print(f"{len(done)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s) on 4 slots")
    for c in sorted(done, key=lambda c: c.request_id):
        print(f"  req {c.request_id}: prompt={c.prompt_len} "
              f"generated={len(c.tokens)} ids={c.tokens[:8]}")


if __name__ == "__main__":
    main()
