"""Continuous batching: more requests than decode slots, slots recycled
as sequences finish (vLLM-style scheduling on this framework).

This drives the streaming engine (`serving.stream`) in LM-only mode
(datastore=None): the explicit prefill / insert / generate_step surface
of JetStream-style serving, with tokens streamed back per step. The
same engine pointed at a Pyramid datastore turns every decode step into
a batched similarity query (see examples/retrieval_decode.py); the
simpler fixed-loop scheduler lives on as `serving.batcher.
ContinuousBatcher` and produces identical greedy tokens.

PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.common.registry import get_arch
from repro.models.transformer import init_params
from repro.serving.batcher import Request
from repro.serving.sampler import SamplerConfig
from repro.serving.stream import StreamEngine


def main() -> None:
    cfg = get_arch("qwen3-1.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    eng = StreamEngine(params, cfg, num_slots=4, max_seq=48,
                       sampler=SamplerConfig(greedy=True))

    n_reqs = 10
    with eng:
        for i in range(n_reqs):
            plen = int(rng.integers(4, 12))
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=plen).astype(np.int32)
            sess = eng.prefill(Request(i, prompt, max_new_tokens=int(
                rng.integers(4, 10))))
            eng.insert(sess)

        t0 = time.time()
        streamed = 0
        while eng.has_work():
            streamed += len(eng.generate_step())   # [(req id, token)]
        dt = time.time() - t0
        done = eng.done

    total_tokens = sum(len(c.tokens) for c in done)
    assert streamed == total_tokens
    print(f"{len(done)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s) on {eng.num_slots} slots")
    for c in sorted(done, key=lambda c: c.request_id):
        print(f"  req {c.request_id}: prompt={c.prompt_len} "
              f"generated={len(c.tokens)} ids={c.tokens[:8]}")


if __name__ == "__main__":
    main()
