"""End-to-end training driver: train a reduced assigned architecture for a
few hundred steps on the synthetic LM pipeline with the sharded train step,
checkpointing included.

PYTHONPATH=src python examples/train_lm.py [--arch qwen3-1.7b] [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.registry import get_arch
from repro.data.synthetic import SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_sharded, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    mesh = make_local_mesh()
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                          weight_decay=0.01)
    step_fn, _ = make_train_step(mesh, cfg, opt_cfg)
    params, opt_state = init_sharded(mesh, cfg)
    data = iter(SyntheticLM(cfg, batch=args.batch, seq_len=args.seq))

    t0 = time.time()
    for i in range(args.steps):
        b = next(data)
        batch = {"inputs": jnp.asarray(b.inputs),
                 "targets": jnp.asarray(b.targets),
                 "mask": jnp.asarray(b.mask)}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss={float(m['loss']):.4f}  "
                  f"lr={float(m['lr']):.2e}  "
                  f"gnorm={float(m['grad_norm']):.2f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    save_checkpoint(args.ckpt, params, opt_state,
                    step=args.steps, meta={"arch": cfg.name})
    print(f"checkpoint saved to {args.ckpt}")
    p2, _, step = load_checkpoint(args.ckpt, params, opt_state)
    ok = all(np.allclose(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    print(f"checkpoint roundtrip verified (step={step}, match={ok})")


if __name__ == "__main__":
    main()
