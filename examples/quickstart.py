"""Quickstart: build a Pyramid index and run distributed similarity search.

This uses the single-host search path (`search_single_host`) — the
whole index queried in one jitted call, no serving engine. For served
traffic use the futures-based session API instead (see API.md)::

    with Brokers() as brokers:
        client = brokers.open_client("demo", index_path, metric="l2")
        res = client.search(q, k=10).result(timeout=5.0)

`examples/serve_cluster.py` shows that flow end to end, including
`as_completed` streaming and live `client.scale()` resizing.

PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.distributed import search_single_host
from repro.core.meta_index import build_pyramid_index
from repro.data.synthetic import clustered_vectors, query_set


def main() -> None:
    # A Deep/SIFT-like clustered dataset (paper Table I, laptop scale)
    x = clustered_vectors(n=10_000, d=32, num_clusters=48, seed=0)
    queries = query_set(x, 50, seed=1)

    cfg = PyramidConfig(
        metric="l2",          # also: "ip" (MIPS, Alg. 5) or "angular"
        num_shards=8,         # w sub-HNSWs (one per worker in the paper)
        meta_size=256,        # m: meta-HNSW vertices (kmeans centers)
        sample_size=5_000,    # n': kmeans sample
        branching_factor=2,   # K: shards touched per query
    )
    print("building Pyramid index (meta-HNSW + partitions + sub-HNSWs)...")
    index = build_pyramid_index(x, cfg, verbose=True)

    ids, scores, mask = search_single_host(index, queries, k=10)
    true_ids, _ = M.brute_force_topk(queries, x, 10, "l2")
    hits = sum(len(set(a.tolist()) & set(b.tolist()))
               for a, b in zip(ids, true_ids))
    print(f"precision@10 = {hits / true_ids.size:.3f}")
    print(f"access rate  = {mask.mean():.3f} "
          f"(fraction of sub-HNSWs touched per query)")
    print(f"top-3 neighbours of query 0: ids={ids[0, :3]} "
          f"scores={scores[0, :3]}")

    # Persist it: publish a version into the on-disk store (atomic,
    # checksummed — the paper's HDFS layer; API.md "Index build & store").
    # Reloading answers bit-identically; post-publish add_items are
    # journaled to the version's delta log and replayed on load, which
    # is how a crashed serving engine recovers (ServingEngine.from_store).
    import tempfile

    from repro.store import IndexStore

    with tempfile.TemporaryDirectory() as root:
        store = IndexStore(root)
        vid = store.publish(index)
        reloaded = store.load()
        ids2, _, _ = search_single_host(reloaded, queries, k=10)
        print(f"published {vid}; reload parity: "
              f"{bool(np.array_equal(ids, ids2))}")


if __name__ == "__main__":
    main()
