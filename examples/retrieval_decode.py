"""Retrieval-augmented decoding (kNN-LM) over a Pyramid datastore.

Trains a small qwen3-family model for a few steps, builds a Pyramid
datastore from its hidden states, then decodes with kNN interpolation —
the paper's technique as a first-class serving feature (DESIGN.md §4).

Two parts:
  1. the anatomy of one retrieval step — hidden-state query through the
     futures client, kNN vocab distribution, interpolation;
  2. the streaming engine (`repro.serving.stream`) doing the same thing
     continuously: prefill / insert / generate_step with the per-step
     batched lookup double-buffered behind the next decode step.

PYTHONPATH=src python examples/retrieval_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import PyramidConfig
from repro.common.registry import get_arch
from repro.data.synthetic import SyntheticLM
from repro.models.transformer import forward, init_params
from repro.serving.batcher import Request
from repro.serving.retrieval import (build_datastore, hidden_states,
                                     interpolate, knn_probs,
                                     open_datastore_client)
from repro.serving.stream import StreamEngine


def main() -> None:
    cfg = get_arch("qwen3-1.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    data = iter(SyntheticLM(cfg, batch=8, seq_len=32, seed=0))
    corpus = np.stack([next(data).inputs for _ in range(2)]).reshape(16, 32)

    print("building Pyramid datastore from model hidden states ...")
    pyr = PyramidConfig(metric="l2", num_shards=4, meta_size=32,
                        sample_size=400, branching_factor=2, max_degree=12,
                        max_degree_upper=6, ef_construction=40, ef_search=60)
    ds = build_datastore(params, cfg, [corpus], pyr)
    print(f"datastore: {ds.values.shape[0]} (hidden -> next-token) entries "
          f"across {ds.index.num_shards} sub-HNSWs")

    # -- part 1: one retrieval step, by hand ------------------------------
    # the datastore client owns its serving engine and is a context
    # manager — the with-block is the teardown (no manual
    # engine.shutdown() to forget)
    prompt = corpus[:2, :16]
    with open_datastore_client(ds) as client:
        hid = np.asarray(hidden_states(params, cfg, jnp.asarray(prompt)),
                         np.float32)
        q = hid[:, -1]                     # current-position hidden state
        kp = knn_probs(ds, q, k=8, vocab_size=cfg.vocab_size,
                       client=client)

    logits, _, _ = forward(params, cfg, jnp.asarray(prompt))
    lm_logits = np.asarray(logits[:, -1], np.float32)

    mixed = interpolate(lm_logits, kp, lam=0.5)
    gold = corpus[:2, 16]
    print(f"gold next tokens:          {gold}")
    print(f"LM-only argmax:            {lm_logits.argmax(-1)}")
    print(f"kNN-only argmax:           {kp.argmax(-1)}")
    print(f"interpolated argmax:       {mixed.argmax(-1)}")
    print("(the kNN memory recovers memorised continuations an untrained "
        "LM cannot)")

    # -- part 2: the streaming engine doing it continuously ---------------
    # every decode step issues ONE batched kNN lookup for all active
    # slots, resolved while the other slot group's decode step runs
    # (overlap=True); the int8 arena serves the datastore (quantize=True)
    print("\nstreaming decode: prefill / insert / generate_step ...")
    with StreamEngine(params, cfg, num_slots=4, max_seq=48,
                      datastore=ds, knn_k=8, lam=0.5,
                      quantize=True, rerank_factor=4) as eng:
        for i in range(6):
            eng.submit(Request(i, corpus[i, :16].astype(np.int32),
                               max_new_tokens=8))
        while eng.has_work():
            for rid, tok in eng.generate_step():
                print(f"  req {rid} -> token {tok}")
        st = eng.stats()
    print(f"{st['sessions']['completed']} sessions, "
          f"{st['tokens_emitted']} tokens at "
          f"{st['tokens_per_s']:.1f} tok/s; per-step retrieval p50 "
          f"{st['retrieval']['latency_p50_s'] * 1e3:.2f} ms, kNN hit rate "
          f"{st['retrieval']['knn_hit_rate']:.2f}")


if __name__ == "__main__":
    main()
