"""Retrieval-augmented decoding (kNN-LM) over a Pyramid datastore.

Trains a small qwen3-family model for a few steps, builds a Pyramid
datastore from its hidden states, then decodes with kNN interpolation —
the paper's technique as a first-class serving feature (DESIGN.md §4).

PYTHONPATH=src python examples/retrieval_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import PyramidConfig
from repro.common.registry import get_arch
from repro.data.synthetic import SyntheticLM
from repro.models.transformer import init_params
from repro.serving.retrieval import (build_datastore, hidden_states,
                                     interpolate, knn_probs,
                                     open_datastore_client)


def main() -> None:
    cfg = get_arch("qwen3-1.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    data = iter(SyntheticLM(cfg, batch=8, seq_len=32, seed=0))
    corpus = np.stack([next(data).inputs for _ in range(2)]).reshape(16, 32)

    print("building Pyramid datastore from model hidden states ...")
    pyr = PyramidConfig(metric="l2", num_shards=4, meta_size=32,
                        sample_size=400, branching_factor=2, max_degree=12,
                        max_degree_upper=6, ef_construction=40, ef_search=60)
    ds = build_datastore(params, cfg, [corpus], pyr)
    print(f"datastore: {ds.values.shape[0]} (hidden -> next-token) entries "
          f"across {ds.index.num_shards} sub-HNSWs")

    # serve the datastore through the distributed engine: lookups go via
    # the futures-based PyramidClient session (see API.md)
    client = open_datastore_client(ds)
    try:
        # decode continuation for a prompt the datastore has memorised
        prompt = corpus[:2, :16]
        hid = np.asarray(hidden_states(params, cfg, jnp.asarray(prompt)),
                         np.float32)
        q = hid[:, -1]                     # current-position hidden state
        kp = knn_probs(ds, q, k=8, vocab_size=cfg.vocab_size,
                       client=client)
    finally:
        client.engine.shutdown()

    from repro.models.transformer import forward
    logits, _, _ = forward(params, cfg, jnp.asarray(prompt))
    lm_logits = np.asarray(logits[:, -1], np.float32)

    mixed = interpolate(lm_logits, kp, lam=0.5)
    gold = corpus[:2, 16]
    print(f"gold next tokens:          {gold}")
    print(f"LM-only argmax:            {lm_logits.argmax(-1)}")
    print(f"kNN-only argmax:           {kp.argmax(-1)}")
    print(f"interpolated argmax:       {mixed.argmax(-1)}")
    print("(the kNN memory recovers memorised continuations an untrained "
          "LM cannot)")


if __name__ == "__main__":
    main()
