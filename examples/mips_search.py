"""MIPS (maximum inner-product search) with Alg. 5: spherical k-means
partitioning + norm replication, on Tiny-like norm-spread data.

PYTHONPATH=src python examples/mips_search.py
"""
import numpy as np

from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.distributed import search_single_host
from repro.core.meta_index import build_pyramid_index
from repro.data.synthetic import norm_spread_vectors


def main() -> None:
    x = norm_spread_vectors(n=8_000, d=24, num_dirs=48, seed=0)
    q = np.random.default_rng(1).normal(size=(64, 24)).astype(np.float32)
    true_ids, _ = M.brute_force_topk(q, x, 10, "ip")

    for r in (0, 100):
        cfg = PyramidConfig(metric="ip", num_shards=8, meta_size=128,
                            sample_size=4_000, branching_factor=1,
                            replication_r=r, max_degree=16,
                            max_degree_upper=8, ef_construction=60,
                            ef_search=80)
        idx = build_pyramid_index(x, cfg)
        ids, _, mask = search_single_host(idx, q, k=10)
        hits = sum(len(set(a.tolist()) & set(b.tolist()))
                   for a, b in zip(ids, true_ids))
        overhead = idx.build_stats["total_stored"] / len(x) - 1
        print(f"r={r:4d}: precision@10={hits/true_ids.size:.3f}  "
              f"access_rate={mask.mean():.3f}  "
              f"storage_overhead={overhead:+.1%}")
    print("norm replication (Alg. 5 lines 12-15) pulls large-norm items "
          "into every direction cone that needs them")


if __name__ == "__main__":
    main()
