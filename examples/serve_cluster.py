"""End-to-end serving driver (deliverable (b) end-to-end example):
the full coordinator/executor engine behind the futures-based
``PyramidClient`` session API — batched requests streamed back via
``as_completed``, a straggler injected halfway through, and the replica
group resized live with ``client.scale``.

PYTHONPATH=src python examples/serve_cluster.py
"""
import time

import numpy as np

from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.client import PyramidClient, as_completed
from repro.core.meta_index import build_pyramid_index
from repro.data.synthetic import clustered_vectors, query_set
from repro.serving.engine import ServingEngine


def main() -> None:
    x = clustered_vectors(n=8_000, d=32, num_clusters=48, seed=0)
    cfg = PyramidConfig(metric="l2", num_shards=4, meta_size=128,
                        sample_size=4_000, branching_factor=2,
                        max_degree=16, max_degree_upper=8,
                        ef_construction=60, ef_search=80)
    index = build_pyramid_index(x, cfg)

    print("starting engine: 4 topics x 2 replicas + monitor (Zookeeper "
          "analogue) ...")
    engine = ServingEngine(index, replicas=2)
    client = PyramidClient(engine)
    try:
        queries = query_set(x, 128, seed=2)
        true_ids, _ = M.brute_force_topk(queries, x, 10, "l2")

        t0 = time.perf_counter()
        futs1 = client.search_batch(queries[:64], k=10)
        # stream results in completion order — no barrier on the batch
        res1 = [f.result() for f in as_completed(futs1, timeout=60)]
        dt1 = time.perf_counter() - t0
        print(f"phase 1 (healthy): {len(res1)} queries in {dt1:.2f}s "
              f"({len(res1)/dt1:.0f} qps)")

        print("injecting straggler on exec-s0-r0 (cpu share 10%) and "
              "scaling shard 0 to 3 replicas to compensate...")
        engine.set_cpu_share("exec-s0-r0", 0.1)
        client.scale(0, 3)
        t0 = time.perf_counter()
        futs2 = client.search_batch(queries[64:], k=10)
        res2 = [f.result() for f in as_completed(futs2, timeout=120)]
        dt2 = time.perf_counter() - t0
        print(f"phase 2 (straggler): {len(res2)} queries in {dt2:.2f}s "
              f"({len(res2)/dt2:.0f} qps) — replicas absorbed the load")

        by_id = {r.query_id: r for r in res1 + res2}
        hits = sum(
            len(set(by_id[f.query_id].ids.tolist()) &
                set(true_ids[i].tolist()))
            for i, f in enumerate(futs1 + futs2) if f.query_id in by_id)
        print(f"overall precision@10 = {hits / true_ids.size:.3f}")
        p90 = np.percentile([r.latency_s for r in res1], 90) * 1e3
        print(f"p90 latency (healthy phase) = {p90:.1f} ms")
        stats = client.stats()
        print(f"engine stats: replicas={stats['replicas']} "
              f"submitted={stats['submitted_queries']} "
              f"restarts={stats['monitor_restarts']}")
    finally:
        engine.shutdown()


if __name__ == "__main__":
    main()
