"""Build planner: staged Pyramid construction with a parallel fan-out.

Alg. 3 / Alg. 5 split into two halves:

  * :func:`plan_build` — the *routing layer* stages that are cheap and
    inherently sequential-ish: sample -> k-means -> meta-HNSW ->
    balanced min-cut partition -> device-batched item assignment ->
    MIPS norm-replication. Produces a :class:`BuildPlan` that pins down
    every sub-dataset and its construction seed.
  * :func:`build_subgraphs` — the expensive half: one HNSW build per
    partition, fanned out over a process pool. Each shard's build is a
    pure function of ``(sub-dataset, config, shard_seed(cfg.seed, i))``
    (numpy only — no device state crosses the process boundary), so the
    parallel result is bit-identical to the sequential loop and the
    store manifest checksums agree no matter how the work was scheduled.

Worker crashes follow the PR-3 robustness contract: a failed shard is
retried (bounded by ``max_retries``), falling back to an in-process
build when the pool itself died, and every recovery action is recorded
in ``build_stats["build_timeline"]``.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import multiprocessing
import os
import shutil
import tempfile
import time
# explicit submodule import: concurrent.futures lazily exposes only the
# executor classes, so `concurrent.futures.process` is unbound until a
# ProcessPoolExecutor has been constructed — which injected pools never do
from concurrent.futures.process import BrokenProcessPool
from typing import Callable, List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.common.config import PyramidConfig
from repro.obs import get_logger
from repro.core import hnsw as H
from repro.core import metrics as M
from repro.core.kmeans import kmeans
from repro.core.meta_index import PyramidIndex, _assign_items, _sample
from repro.core.partition import balance_stats, edge_cut, partition_graph
from repro.kernels.topk_distance import topk_similarity

log = get_logger(__name__)


class BuildError(RuntimeError):
    """A shard build failed past its retry budget."""


@dataclasses.dataclass
class BuildPlan:
    """Everything the sub-HNSW fan-out needs, fixed by the planner.

    ``x`` is the *preprocessed* dataset (normalised for angular);
    ``sub_ids[i]`` are the global ids assigned to partition ``i``.
    """

    x: np.ndarray
    cfg: PyramidConfig
    meta: H.HNSWGraph
    part_of_center: np.ndarray
    sub_ids: List[np.ndarray]
    stats: dict

    @property
    def metric(self) -> str:
        return "ip" if self.cfg.is_mips else self.cfg.metric

    @property
    def num_shards(self) -> int:
        return self.cfg.num_shards


@dataclasses.dataclass
class ShardSpec:
    """A self-contained, picklable description of ONE sub-HNSW build.

    Crossing a process boundary must not change the result: the spec
    carries plain numpy arrays plus scalar config, and the worker calls
    the same ``build_hnsw`` the sequential path does, with the same
    deterministic ``shard_seed``.
    """

    shard: int
    data: np.ndarray          # [n_i, d] rows of this sub-dataset
    ids: np.ndarray           # [n_i] global ids
    metric: str
    max_degree: int
    max_degree_upper: int
    ef_construction: int
    seed: int


# ---------------------------------------------------------------------------
# Stage 1: the plan (sample -> kmeans -> meta-HNSW -> partition -> assign)
# ---------------------------------------------------------------------------


def plan_build(x: np.ndarray, cfg: PyramidConfig, *,
               sample_queries: Optional[np.ndarray] = None) -> BuildPlan:
    """Alg. 3 lines 3-10 / Alg. 5 lines 3-15: everything up to (but not
    including) the per-partition sub-HNSW builds."""
    rng = np.random.default_rng(cfg.seed)
    x = M.preprocess_dataset(x, cfg.metric)
    n, d = x.shape
    m = min(cfg.meta_size, max(cfg.num_shards, n // 4))
    stats: dict = {"n": n, "d": d, "m": m, "w": cfg.num_shards}
    timings: dict = {}

    # -- Alg. 3 lines 3-5 / Alg. 5 lines 3-6: sample, kmeans, meta-HNSW ----
    t0 = time.perf_counter()
    sample = _sample(x, cfg.sample_size, rng)
    spherical = cfg.is_mips
    centers, counts = kmeans(sample, m, iters=cfg.kmeans_iters,
                             spherical=spherical, seed=cfg.seed)
    timings["kmeans_s"] = time.perf_counter() - t0
    meta_metric = "ip" if cfg.is_mips else cfg.metric
    t0 = time.perf_counter()
    meta = H.build_hnsw(centers, metric=meta_metric,
                        max_degree=cfg.max_degree,
                        max_degree_upper=cfg.max_degree_upper,
                        ef_construction=cfg.ef_construction, seed=cfg.seed)
    timings["meta_hnsw_s"] = time.perf_counter() - t0

    # -- center weights: cluster sizes (or query-frequency when provided) --
    if sample_queries is not None:
        k_hot = 10
        ids, _ = H.search_numpy(meta, sample_queries, k=k_hot,
                                ef=cfg.ef_search)
        weights = np.bincount(ids[ids >= 0].reshape(-1), minlength=m) + 1.0
    else:
        weights = np.asarray(counts, dtype=np.float64) + 1.0

    # -- Alg. 3 line 6: balanced min-cut partition of the bottom layer -----
    part_of_center = partition_graph(
        meta.neighbors[0], weights, cfg.num_shards, seed=cfg.seed)
    stats["edge_cut"] = edge_cut(meta.neighbors[0], part_of_center)
    stats["balance"], stats["part_weights"] = balance_stats(
        weights, part_of_center, cfg.num_shards)

    # -- Alg. 3 lines 7-10: assign every item to a sub-dataset -------------
    t0 = time.perf_counter()
    meta_arrays = meta.device_arrays()
    item_part = _assign_items(x, meta_arrays, part_of_center, meta_metric)
    timings["assign_s"] = time.perf_counter() - t0

    sub_ids: List[np.ndarray] = [
        np.where(item_part == i)[0] for i in range(cfg.num_shards)]

    # -- Alg. 5 lines 12-15: MIPS norm-replication -------------------------
    replicated = 0
    if cfg.is_mips and cfg.replication_r > 0:
        r = min(cfg.replication_r, n)
        # top-r MIPS neighbours of every meta vertex in the full dataset;
        # blocked Pallas scan (the paper suggests LSH here; exact scan is
        # affordable at our scale and strictly more faithful to recall).
        _, top_r = topk_similarity(
            jnp.asarray(centers), jnp.asarray(x), k=r, metric="ip")
        top_r = np.asarray(top_r)
        extra: List[set] = [set() for _ in range(cfg.num_shards)]
        for c in range(m):
            extra[part_of_center[c]].update(top_r[c].tolist())
        for i in range(cfg.num_shards):
            base = set(sub_ids[i].tolist())
            add = np.fromiter((v for v in extra[i] if v not in base),
                              dtype=np.int64, count=-1)
            replicated += add.size
            if add.size:
                sub_ids[i] = np.concatenate([sub_ids[i], add])
    stats["replicated_items"] = replicated

    # degenerate partitions get one random item (a zero-item shard could
    # not build an HNSW); drawn here, in shard order, so the sequential
    # and parallel paths consume the same rng stream
    for i in range(cfg.num_shards):
        if sub_ids[i].size == 0:
            sub_ids[i] = rng.choice(n, size=1)
    stats["total_stored"] = int(sum(s.size for s in sub_ids))
    stats["sub_sizes"] = [int(s.size) for s in sub_ids]
    stats["plan_timings"] = {k: round(v, 4) for k, v in timings.items()}
    return BuildPlan(x=x, cfg=cfg, meta=meta,
                     part_of_center=part_of_center.astype(np.int32),
                     sub_ids=sub_ids, stats=stats)


def shard_specs(plan: BuildPlan) -> List[ShardSpec]:
    """One picklable build spec per partition, seeds threaded via
    :func:`repro.core.hnsw.shard_seed`."""
    cfg = plan.cfg
    return [
        ShardSpec(
            shard=i, data=plan.x[plan.sub_ids[i]], ids=plan.sub_ids[i],
            metric=plan.metric, max_degree=cfg.max_degree,
            max_degree_upper=cfg.max_degree_upper,
            ef_construction=cfg.ef_construction,
            seed=H.shard_seed(cfg.seed, i))
        for i in range(plan.num_shards)]


# ---------------------------------------------------------------------------
# Stage 2: the fan-out
# ---------------------------------------------------------------------------


def _build_shard(spec: ShardSpec) -> Tuple[H.HNSWGraph, float]:
    """Build one sub-HNSW. Pure numpy — safe to run in a spawned
    process, deterministic given the spec."""
    t0 = time.perf_counter()
    g = H.build_hnsw(
        spec.data, metric=spec.metric, max_degree=spec.max_degree,
        max_degree_upper=spec.max_degree_upper,
        ef_construction=spec.ef_construction, seed=spec.seed,
        ids=spec.ids)
    return g, time.perf_counter() - t0


@dataclasses.dataclass
class _ShardPayload:
    """What actually crosses the pool's call pipe: a file path plus
    scalars. Shard arrays go via a temp file, NOT through the pickled
    submit payload — a large payload stuck in the call-queue pipe when
    every worker has died deadlocks CPython 3.10's ``terminate_broken``
    (the feeder thread blocks in ``_send`` with no reader, and the
    broken-pool cleanup joins it forever, hanging interpreter exit)."""

    path: str
    shard: int
    metric: str
    max_degree: int
    max_degree_upper: int
    ef_construction: int
    seed: int


def _build_shard_payload(task: _ShardPayload) -> Tuple[H.HNSWGraph, float]:
    """Pool worker entry: load the shard's arrays from disk, build."""
    with np.load(task.path) as z:
        data, ids = z["data"], z["ids"]
    return _build_shard(ShardSpec(
        shard=task.shard, data=data, ids=ids, metric=task.metric,
        max_degree=task.max_degree,
        max_degree_upper=task.max_degree_upper,
        ef_construction=task.ef_construction, seed=task.seed))


def _default_pool(workers: int):
    # spawn, not fork: the parent has a live XLA backend (the planner's
    # device-batched assignment) and forking its threads can deadlock;
    # workers only need numpy, so a clean interpreter is cheap and safe
    return concurrent.futures.ProcessPoolExecutor(
        max_workers=workers, mp_context=multiprocessing.get_context("spawn"))


def build_subgraphs(plan: BuildPlan, *, workers: int = 0,
                    max_retries: int = 2,
                    pool_factory: Optional[Callable] = None,
                    verbose: bool = False
                    ) -> Tuple[List[H.HNSWGraph], dict]:
    """Build every partition's sub-HNSW, optionally in parallel.

    ``workers <= 1`` runs the sequential in-process loop; otherwise the
    specs are fanned out over a process pool (``pool_factory() ->
    executor`` is injectable for tests). A shard whose worker raises or
    dies is retried up to ``max_retries`` times — through the pool while
    it is healthy, in-process once it is broken — and every retry is
    recorded in the returned stats' ``build_timeline``. Results are
    bit-identical either way: each shard is a pure function of its spec.
    """
    w = plan.num_shards
    subs: List[Optional[H.HNSWGraph]] = [None] * w
    shard_s = [0.0] * w
    timeline: List[dict] = []
    retries = 0
    t_start = time.perf_counter()

    if workers <= 1 or w <= 1:
        for spec in shard_specs(plan):
            subs[spec.shard], shard_s[spec.shard] = _build_shard(spec)
        mode = "sequential"
    else:
        mode = "parallel"
        factory = pool_factory or (lambda: _default_pool(min(workers, w)))
        pool = factory()
        pool_broken = False
        pending = {i: 0 for i in range(w)}   # shard -> attempts
        payload_dir = tempfile.mkdtemp(prefix="pyramid-build-")
        # payload files, not in-memory spec copies: the pool pipe then
        # carries only small descriptors (see _ShardPayload), and peak
        # memory stays ~1x the dataset — each shard's fancy-indexed
        # copy lives only for the duration of its write
        cfg = plan.cfg
        tasks: dict = {}
        for i in range(w):
            path = os.path.join(payload_dir, f"shard-{i}.npz")
            np.savez(path, data=plan.x[plan.sub_ids[i]],
                     ids=plan.sub_ids[i])
            tasks[i] = _ShardPayload(
                path=path, shard=i, metric=plan.metric,
                max_degree=cfg.max_degree,
                max_degree_upper=cfg.max_degree_upper,
                ef_construction=cfg.ef_construction,
                seed=H.shard_seed(cfg.seed, i))
        try:
            futs = {pool.submit(_build_shard_payload, tasks[i]): i
                    for i in range(w)}
            while futs:
                done, _ = concurrent.futures.wait(
                    futs, return_when=concurrent.futures.FIRST_COMPLETED)
                for fut in done:
                    shard = futs.pop(fut)
                    try:
                        subs[shard], shard_s[shard] = fut.result()
                        pending.pop(shard, None)
                        continue
                    except Exception as e:   # worker raised or died
                        attempt = pending[shard] = pending[shard] + 1
                        retries += 1
                        if isinstance(e, BrokenProcessPool):
                            pool_broken = True
                        if attempt > max_retries:
                            raise BuildError(
                                f"shard {shard} build failed after "
                                f"{max_retries} retries: {e!r}") from e
                        timeline.append({
                            "shard": shard, "event": "retry",
                            "attempt": attempt,
                            "via": ("inline" if pool_broken else "pool"),
                            "error": repr(e)})
                        if verbose:
                            log.info(f"[build] shard {shard} attempt "
                                  f"{attempt} failed ({e!r}); retrying "
                                  f"{'inline' if pool_broken else 'in pool'}")
                    if not pool_broken:
                        try:
                            futs[pool.submit(_build_shard_payload,
                                             tasks[shard])] = shard
                            continue
                        except BrokenProcessPool:
                            # the pool broke between this worker's
                            # failure and the resubmit (another worker
                            # died): fall through to the inline path
                            pool_broken = True
                            timeline[-1]["via"] = "inline"
                    # the pool died with the worker: rebuild this shard
                    # in-process (same payload -> same bits)
                    try:
                        subs[shard], shard_s[shard] = (
                            _build_shard_payload(tasks[shard]))
                    except Exception as e2:
                        raise BuildError(
                            f"shard {shard} inline rebuild failed "
                            f"after pool break: {e2!r}") from e2
                    pending.pop(shard, None)
        finally:
            pool.shutdown(wait=False, cancel_futures=True)
            shutil.rmtree(payload_dir, ignore_errors=True)

    stats = {
        "build_mode": mode,
        "build_workers": int(workers),
        "build_retries": retries,
        "build_timeline": timeline,
        "shard_build_s": [round(t, 4) for t in shard_s],
        "subgraphs_wall_s": round(time.perf_counter() - t_start, 4),
    }
    return subs, stats   # type: ignore[return-value]


def build_pyramid_index_parallel(
        x: np.ndarray, cfg: PyramidConfig, *,
        workers: Optional[int] = None,
        sample_queries: Optional[np.ndarray] = None,
        max_retries: int = 2,
        pool_factory: Optional[Callable] = None,
        verbose: bool = False) -> PyramidIndex:
    """Full Pyramid build with the sub-HNSW stage fanned out over a
    process pool.

    ``workers=None`` picks ``min(num_shards, cpu_count)``; ``workers=0``
    (or 1) is the sequential path — :func:`repro.core.meta_index.
    build_pyramid_index` delegates here with exactly that. Parallel and
    sequential builds are bit-identical (deterministic per-shard seeds;
    the store manifest checksums are the proof, see
    ``benchmarks/bench_build.py``).
    """
    if workers is None:
        workers = min(cfg.num_shards, os.cpu_count() or 1)
    t0 = time.perf_counter()
    plan = plan_build(x, cfg, sample_queries=sample_queries)
    subs, build_stats = build_subgraphs(
        plan, workers=workers, max_retries=max_retries,
        pool_factory=pool_factory, verbose=verbose)
    stats = dict(plan.stats)
    stats.update(build_stats)
    stats["build_wall_s"] = round(time.perf_counter() - t0, 4)
    if verbose:
        log.info(f"[pyramid] build stats: {stats}")
    return PyramidIndex(config=cfg, meta=plan.meta,
                        part_of_center=plan.part_of_center,
                        subs=subs, build_stats=stats)


# ---------------------------------------------------------------------------
# Online rebalancing: split / merge planning + apply (reused by the
# store compactor — repro.store.maintenance)
# ---------------------------------------------------------------------------


def plan_rebalance(index: PyramidIndex, *,
                   engine_stats: Optional[dict] = None,
                   split_factor: float = 4.0,
                   merge_factor: float = 0.25,
                   latency_factor: float = 4.0,
                   min_split_items: int = 8) -> Optional[Tuple]:
    """Decide at most ONE split/merge op for the next maintenance cycle.

    Signals, in priority order:
      * size skew — a shard holding > ``split_factor`` x the mean
        sub-dataset size (``build_stats["sub_sizes"]``) splits; two
        shards both under ``merge_factor`` x the mean merge;
      * access/latency skew — with ``engine_stats`` (the serving
        engine's ``stats()``), a shard whose streaming p99 exceeds
        ``latency_factor`` x the median p99 splits even when its size
        alone would not trigger (a hot shard is a routing hotspot the
        paper's static partitioning cannot fix).

    Returns ``("split", s)``, ``("merge", a, b)`` or ``None``. One op
    per cycle keeps shard indices stable while the op is applied; the
    compactor re-plans every cycle, so sustained skew drains over
    successive cycles.
    """
    sizes = [g.n for g in index.subs]
    w = len(sizes)
    total = sum(sizes)
    if w == 0 or total == 0:
        return None
    mean = total / w
    centers_per = np.bincount(
        np.asarray(index.part_of_center, np.int64), minlength=w)

    def splittable(s: int) -> bool:
        # routing granularity: a split relabels the shard's meta
        # centers, so it needs at least two of them (and enough items
        # for two non-trivial halves)
        return sizes[s] >= max(min_split_items, 2) and centers_per[s] >= 2

    order = np.argsort(sizes)[::-1]
    for s in order:
        if sizes[s] > split_factor * mean and splittable(int(s)):
            return ("split", int(s))
    lat = (engine_stats or {}).get("latency") or {}
    p99s = sorted(v["p99"] for v in lat.values() if v.get("n", 0))
    if p99s:
        med = p99s[len(p99s) // 2]
        hot = sorted(
            (int(s) for s, v in lat.items()
             if med > 0 and v["p99"] > latency_factor * med
             and splittable(int(s)) and sizes[int(s)] > mean),
            key=lambda s: -lat[s]["p99"])
        if hot:
            return ("split", hot[0])
    if w >= 2:
        a, b = sorted(np.argsort(sizes)[:2].tolist())
        if (sizes[a] < merge_factor * mean
                and sizes[b] < merge_factor * mean):
            return ("merge", int(a), int(b))
    return None


def split_shard(index: PyramidIndex, s: int) -> PyramidIndex:
    """Split sub-HNSW ``s`` in two (in place): kmeans++ (k=2) over its
    items, the shard's meta centers relabelled to whichever half is
    nearest — routing stays consistent because a query landing on one
    of those centers now probes exactly the half holding that center's
    items. Both halves rebuild through ``shard_seed`` and the new shard
    takes index ``w`` (``config.num_shards`` grows by one)."""
    cfg = index.config
    metric = "ip" if cfg.is_mips else cfg.metric
    g = index.subs[s]
    center_sel = np.where(np.asarray(index.part_of_center) == s)[0]
    if g.n < 2 or center_sel.size < 2:
        raise BuildError(
            f"shard {s} cannot split: {g.n} items, "
            f"{center_sel.size} meta centers")
    halves, _ = kmeans(g.data, 2, iters=cfg.kmeans_iters,
                       spherical=cfg.is_mips,
                       seed=H.shard_seed(cfg.seed, s), init="kmeans++")
    halves = np.asarray(halves, np.float32)
    # relabel the partition's centers by nearest half, forcing at least
    # one center per side (kmeans on near-duplicate data can collapse)
    cvecs = index.meta.data[center_sel]
    side = np.argmax(
        M.similarity_matrix_np(cvecs, halves, metric), axis=1)
    if (side == 0).all():
        side[np.argmin(
            M.similarity_matrix_np(cvecs, halves[:1], metric)[:, 0])] = 1
    elif (side == 1).all():
        side[np.argmin(
            M.similarity_matrix_np(cvecs, halves[1:], metric)[:, 0])] = 0
    w = len(index.subs)
    part = np.asarray(index.part_of_center).copy()
    part[center_sel[side == 1]] = w
    # items follow their nearest center WITHIN the old partition, so an
    # item ends up exactly where routing via its center now points
    nearest = np.argmax(
        M.similarity_matrix_np(g.data, cvecs, metric), axis=1)
    item_side = side[nearest]
    new_subs = []
    for hs, shard_id in ((0, s), (1, w)):
        sel = item_side == hs
        new_subs.append(H.build_hnsw(
            g.data[sel], metric=metric, max_degree=cfg.max_degree,
            max_degree_upper=cfg.max_degree_upper,
            ef_construction=cfg.ef_construction,
            seed=H.shard_seed(cfg.seed, shard_id), ids=g.ids[sel]))
    index.subs[s] = new_subs[0]
    index.subs.append(new_subs[1])
    index.part_of_center = part.astype(np.int32)
    index.config = dataclasses.replace(cfg, num_shards=w + 1)
    index.build_stats["sub_sizes"] = [g.n for g in index.subs]
    index.build_stats["total_stored"] = sum(g.n for g in index.subs)
    index.invalidate_device_cache()
    return index


def merge_shards(index: PyramidIndex, a: int, b: int) -> PyramidIndex:
    """Merge sub-HNSW ``b`` into ``a`` (in place): ``b``'s meta centers
    relabel to ``a``, the combined items (id-deduped — MIPS replication
    can store one id in both) rebuild one graph through ``shard_seed``,
    and every shard index above ``b`` shifts down by one."""
    if a == b:
        raise BuildError("merge_shards needs two distinct shards")
    a, b = sorted((a, b))
    cfg = index.config
    metric = "ip" if cfg.is_mips else cfg.metric
    ga, gb = index.subs[a], index.subs[b]
    data = np.concatenate([ga.data, gb.data])
    ids = np.concatenate([ga.ids, gb.ids])
    _, first = np.unique(ids, return_index=True)
    first = np.sort(first)
    index.subs[a] = H.build_hnsw(
        data[first], metric=metric, max_degree=cfg.max_degree,
        max_degree_upper=cfg.max_degree_upper,
        ef_construction=cfg.ef_construction,
        seed=H.shard_seed(cfg.seed, a), ids=ids[first])
    del index.subs[b]
    part = np.asarray(index.part_of_center).copy()
    part[part == b] = a
    part[part > b] -= 1
    index.part_of_center = part.astype(np.int32)
    index.config = dataclasses.replace(
        cfg, num_shards=cfg.num_shards - 1)
    index.build_stats["sub_sizes"] = [g.n for g in index.subs]
    index.build_stats["total_stored"] = sum(g.n for g in index.subs)
    index.invalidate_device_cache()
    return index
