"""Parallel Pyramid index construction (paper Sec. IV-A GraphConstructor).

The paper builds sub-HNSWs *in parallel across the cluster*; this package
is that layer for a single host: a build planner that runs the shared
sample -> k-means -> meta-HNSW -> partition -> assignment stages once,
then fans per-partition sub-HNSW construction out over a process pool
with deterministic per-shard seeds — the parallel build is bit-identical
to the sequential one (same :func:`repro.store` manifest checksums).

    from repro.build import build_pyramid_index_parallel
    index = build_pyramid_index_parallel(x, cfg, workers=4)
"""
from repro.build.planner import (BuildError, BuildPlan, ShardSpec,
                                 build_pyramid_index_parallel,
                                 build_subgraphs, plan_build, shard_specs)

__all__ = [
    "BuildError", "BuildPlan", "ShardSpec",
    "build_pyramid_index_parallel", "build_subgraphs", "plan_build",
    "shard_specs",
]
