"""AdamW with warmup-cosine schedule (hand-rolled; optax is not available
offline). Optimizer state shards like the parameters (FSDP)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, stats dict)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
