"""Minimal sharded-aware checkpointing (npz-based; orbax not available).

Layout: one .npz with flattened param paths + a small JSON manifest with
step/config metadata. Arrays are gathered to host (fine at the scales this
container trains); the path-keyed format is restore-order independent.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, params, opt_state=None, *,
                    step: int = 0, meta: dict = None) -> None:
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, "opt_mu.npz"), **_flatten(opt_state.mu))
        np.savez(os.path.join(path, "opt_nu.npz"), **_flatten(opt_state.nu))
    manifest = {"step": int(step), "meta": meta or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_checkpoint(path: str, params_template,
                    opt_state_template=None) -> Tuple:
    """Returns (params, opt_state | None, step)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat = dict(np.load(os.path.join(path, "params.npz")))
    params = _unflatten_into(params_template, flat)
    opt_state = None
    if opt_state_template is not None and \
            os.path.exists(os.path.join(path, "opt_mu.npz")):
        mu = _unflatten_into(opt_state_template.mu,
                             dict(np.load(os.path.join(path, "opt_mu.npz"))))
        nu = _unflatten_into(opt_state_template.nu,
                             dict(np.load(os.path.join(path, "opt_nu.npz"))))
        opt_state = opt_state_template._replace(
            mu=mu, nu=nu,
            step=jax.numpy.asarray(manifest["step"], jax.numpy.int32))
    return params, opt_state, manifest["step"]
