"""Sharded train step: loss, grads, AdamW update under pjit/GSPMD.

Sharding: batch over the (pod,)data axes; params/optimizer FSDP+TP via the
name-based rules in ``models.layers``; logits keep the vocab dim sharded
over ``model`` so the softmax cross-entropy reduces shard-locally.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import ArchConfig
from repro.common import sharding as S
from repro.models import layers as L
from repro.models.transformer import forward, init_params
from repro.train.optimizer import (AdamWConfig, OptState, adamw_update,
                                   init_opt_state)


def softmax_xent(logits: jnp.ndarray, targets: jnp.ndarray,
                 mask: jnp.ndarray) -> jnp.ndarray:
    """Mean masked cross-entropy, vocab-parallel friendly.

    The gold logit is extracted with an iota==target select (reduces over
    the sharded vocab dim with a local partial + small all-reduce) instead
    of ``take_along_axis`` (which GSPMD lowers to an all-gather of the full
    [B, S, V] logits — measured 40+ GiB/chip on train_4k).
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(viota == targets[..., None], logits, 0.0),
                   axis=-1)
    nll = logz - gold
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_softmax_xent(hidden: jnp.ndarray, lm_head: jnp.ndarray,
                         targets: jnp.ndarray, mask: jnp.ndarray,
                         chunk: int = 512) -> jnp.ndarray:
    """Cross-entropy with the LM-head matmul inside a rematted seq-chunk
    scan: full-sequence logits NEVER materialise.

    GSPMD refuses to partial-reduce the lm_head backward over the data
    axis and instead all-gathers the [B, S, V] cotangent (measured
    3 x 37 GiB/chip on train_4k); bounding the live logits to one chunk
    makes that all-gather [B, chunk, V] regardless of its choice. dW
    accumulates across chunks in the scan-of-vjp.
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = jnp.moveaxis(hidden.reshape(b, n_chunks, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, n_chunks, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, n_chunks, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, inp):
        h, t, m = inp
        logits = jnp.einsum("bcd,dv->bcv", h, lm_head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(jnp.where(viota == t[..., None], logits, 0.0), -1)
        return carry + jnp.sum((logz - gold) * m), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hc, tc, mc))
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, cfg: ArchConfig, batch, aux_weight: float = 0.01,
            mesh: Mesh = None, remat_segments: bool = False):
    hidden, aux, _ = forward(params, cfg, batch["inputs"], skip_head=True,
                             mesh=mesh, remat_segments=remat_segments)
    head = params["embedding"].T if cfg.tie_embeddings else params["lm_head"]
    loss = chunked_softmax_xent(hidden, head, batch["targets"],
                                batch["mask"])
    return loss + aux_weight * aux, (loss, aux)


def train_step(params, opt_state: OptState, batch, *, cfg: ArchConfig,
               opt_cfg: AdamWConfig, mesh: Mesh = None,
               remat_segments: bool = False):
    (total, (loss, aux)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, cfg, batch, mesh=mesh,
                               remat_segments=remat_segments)
    new_params, new_state, stats = adamw_update(
        opt_cfg, params, grads, opt_state)
    metrics = {"loss": loss, "aux_loss": aux, "total_loss": total, **stats}
    return new_params, new_state, metrics


# ---------------------------------------------------------------------------
# sharding plumbing
# ---------------------------------------------------------------------------


def param_shardings(mesh: Mesh, cfg: ArchConfig, params_shape):
    """NamedShardings mirroring an (abstract) param tree. Dims that do not
    divide their mesh axes fall back to replicated (e.g. odd vocabs)."""
    specs = L.tree_specs(params_shape)
    return jax.tree.map(
        lambda spec, leaf: S.logical_to_sharding_shaped(
            mesh, spec, leaf.shape),
        specs, params_shape,
        is_leaf=lambda x: isinstance(x, tuple) and not hasattr(x, "shape"))


def opt_shardings(mesh: Mesh, cfg: ArchConfig, params_shape):
    ps = param_shardings(mesh, cfg, params_shape)
    return OptState(step=S.replicated(mesh), mu=ps, nu=ps)


def batch_shardings(mesh: Mesh, cfg: ArchConfig):
    bax = S.batch_axes(mesh)
    spec = bax if len(bax) > 1 else bax[0]
    tok = NamedSharding(mesh, P(spec, None))
    if cfg.frontend:
        tok_in = NamedSharding(mesh, P(spec, None, None))
    else:
        tok_in = tok
    return {"inputs": tok_in, "targets": tok, "mask": tok}


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))


def make_train_step(mesh: Mesh, cfg: ArchConfig, opt_cfg: AdamWConfig,
                    remat_segments: bool = None):
    """jit'd train step with explicit in/out shardings for the mesh.

    remat_segments=None reads REPRO_REMAT_SEGMENTS (hierarchical remat:
    one saved residual per segment instead of per layer, +1 fwd recompute).
    """
    if remat_segments is None:
        import os as _os
        remat_segments = bool(int(
            _os.environ.get("REPRO_REMAT_SEGMENTS", "0")))
    pshape = abstract_params(cfg)
    ps = param_shardings(mesh, cfg, pshape)
    os = opt_shardings(mesh, cfg, pshape)
    bs = batch_shardings(mesh, cfg)
    step = functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                             mesh=mesh, remat_segments=remat_segments)
    metric_shard = {k: S.replicated(mesh) for k in
                    ("loss", "aux_loss", "total_loss", "grad_norm", "lr")}
    return jax.jit(
        step,
        in_shardings=(ps, os, bs),
        out_shardings=(ps, os, metric_shard),
        donate_argnums=(0, 1),
    ), (ps, os, bs)


def init_sharded(mesh: Mesh, cfg: ArchConfig, seed: int = 0):
    """Initialise params + opt state directly with their shardings."""
    pshape = abstract_params(cfg)
    ps = param_shardings(mesh, cfg, pshape)
    params = jax.jit(
        functools.partial(init_params, cfg),
        out_shardings=ps)(jax.random.PRNGKey(seed))
    os_sh = opt_shardings(mesh, cfg, pshape)
    opt_state = jax.jit(init_opt_state, out_shardings=os_sh)(params)
    return params, opt_state
