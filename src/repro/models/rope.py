"""Rotary position embeddings: standard and 2d-style (chatglm3).

chatglm3 applies rotary to only the first half of each head dim ("2d RoPE"
lineage from GLM); the second half passes through unrotated.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.common.config import RoPEKind


def _rotate(x: jnp.ndarray, positions: jnp.ndarray,
            theta: float) -> jnp.ndarray:
    """Apply rotary to the full last dim. x: [B, S, H, D], positions: [B, S]."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq      # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]                          # [B, S, 1, half]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *, kind: RoPEKind,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: [B, S, H, D] query or key heads; positions: [B, S] int32."""
    if kind == RoPEKind.NONE:
        return x
    if kind == RoPEKind.STANDARD:
        return _rotate(x, positions, theta)
    if kind == RoPEKind.TWO_D:
        d = x.shape[-1]
        rot, keep = x[..., : d // 2], x[..., d // 2:]
        return jnp.concatenate(
            [_rotate(rot, positions, theta), keep], axis=-1)
    raise ValueError(kind)
