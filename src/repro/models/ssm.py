"""Mamba2 block via state-space duality (SSD), arXiv:2405.21060.

TPU-native choice: the SSD *chunked* formulation is used for train/prefill —
it re-expresses the selective-scan recurrence as dense intra-chunk matmuls
(MXU-friendly) plus a light inter-chunk state recurrence (lax.scan over
chunks). Decode is the O(1) recurrent state update.

Sharding: SSM heads are sharded over the ``model`` axis (all per-head
params: dt, A, D; and the d_inner channel dim of x/z/conv). B and C are
ngroups=1 (shared across heads) and replicated.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ArchConfig, SSMConfig
from repro.models import layers as L


def ssm_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    """(d_inner, num_heads, state_dim)."""
    s = cfg.ssm or SSMConfig()
    d_inner = s.expand * cfg.d_model
    return d_inner, d_inner // s.head_dim, s.state_dim


def init_mamba2_params(key, cfg: ArchConfig, dtype) -> dict:
    s = cfg.ssm or SSMConfig()
    d = cfg.d_model
    d_in, h, n = ssm_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": L.dense_init(ks[0], (d, 2 * d_in), d, dtype),   # z, x
        "bc_proj": L.dense_init(ks[1], (d, 2 * n), d, dtype),      # B, C
        "dt_w": L.dense_init(ks[2], (d, h), d, dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "conv_w": L.dense_init(ks[3], (s.conv_width, d_in),
                               s.conv_width, dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "ssm_norm": jnp.zeros((d_in,), dtype),
        "out_proj": L.dense_init(ks[4], (d_in, d), d_in, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv. x: [B, S, C]; w: [W, C]."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(width))
    return out + b


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular pairwise cumulative sums.

    a: [..., Q] -> out[..., i, j] = sum_{t=j+1..i} a[..., t]  (i >= j),
    -inf above the diagonal.
    """
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                b_mat: jnp.ndarray, c_mat: jnp.ndarray, *, chunk: int,
                initial_state: jnp.ndarray = None):
    """SSD scan (Mamba2 Alg. 1 'chunked' form).

    Args:
      x:     [B, S, H, P]  input heads
      dt:    [B, S, H]     positive step sizes
      a:     [H]           negative decay rates (A)
      b_mat: [B, S, N]     input projection (ngroups=1)
      c_mat: [B, S, N]     output projection
      chunk: chunk length Q (S padded to a multiple)
      initial_state: [B, H, N, P] or None

    Returns: (y [B, S, H, P], final_state [B, H, N, P])
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q

    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    bc = b_mat.reshape(bsz, nc, q, n)
    cc = c_mat.reshape(bsz, nc, q, n)

    da = dtc * a[None, None, None, :]                    # [B, C, Q, H] (<0)
    da_h = jnp.moveaxis(da, -1, -2)                      # [B, C, H, Q]
    seg = _segsum(da_h)                                  # [B, C, H, Q, Q]
    decay_in = jnp.exp(seg)                              # intra-chunk decays

    # intra-chunk (diagonal blocks): y_d = (C B^T ∘ L ∘ dt) x.
    # Two explicit stages: build the [B,C,H,Q,Q] score block, then ONE
    # batched [Q,Q]x[Q,P] matmul per (b,c,h). A fused 4-operand einsum
    # lets XLA materialise a 6-D [b,c,h,i,j,p] intermediate (measured
    # 28 GiB/chip on zamba2 train_4k).
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)           # [B, C, Q, Q]
    scores = cb[:, :, None] * decay_in * \
        jnp.moveaxis(dtc, -1, -2)[..., None, :]          # [B, C, H, Q, Q]
    ydt = jnp.einsum("bchij,bcjhp->bcihp",
                     scores.astype(xc.dtype), xc)        # [B, C, Q, H, P]

    # chunk states: S_c = sum_j B_j dt_j exp(sum_{t>j} da) x_j
    cum = jnp.cumsum(da_h, axis=-1)                      # [B, C, H, Q]
    decay_to_end = jnp.exp(cum[..., -1:] - cum)          # [B, C, H, Q]
    xw = xc * (dtc * jnp.moveaxis(decay_to_end, -2, -1)
               )[..., None].astype(xc.dtype)             # [B, C, Q, H, P]
    states = jnp.einsum("bcjn,bcjhp->bchnp", bc, xw)     # [B, C, H, N, P]

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(jnp.sum(da_h, axis=-1))        # [B, C, H]
    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, n, p), jnp.float32)

    def step(carry, inp):
        st_prev = carry                                  # [B, H, N, P]
        s_c, g = inp                                     # [B,H,N,P], [B,H]
        st = st_prev * g[..., None, None] + s_c
        return st, st_prev

    final, prev_states = jax.lax.scan(
        step, initial_state.astype(jnp.float32),
        (jnp.moveaxis(states.astype(jnp.float32), 1, 0),
         jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # [B, C, H, N, P]

    # inter-chunk contribution: y_off = C exp(cum) state_prev
    # (contract over n first; the decay multiply is a fused elementwise)
    state_decay = jnp.exp(cum)                           # [B, C, H, Q]
    yoff = jnp.einsum("bcin,bchnp->bcihp",
                      cc, prev_states.astype(cc.dtype))  # [B, C, Q, H, P]
    yoff = yoff * jnp.moveaxis(state_decay, -2, -1)[..., None].astype(
        yoff.dtype)
    y = (ydt + yoff).reshape(bsz, nc * q, h, p)[:, :s]
    return y.astype(x.dtype), final


def mamba2_block(p: dict, cfg: ArchConfig, u: jnp.ndarray,
                 ssm_state: jnp.ndarray = None,
                 conv_state: jnp.ndarray = None, *, decode: bool = False):
    """Full Mamba2 block.

    Train/prefill: u [B, S, D] -> (y [B, S, D], (ssm_state, conv_state)).
    Decode: u [B, 1, D] with states -> same signature, states updated.
    """
    s_cfg = cfg.ssm or SSMConfig()
    bsz, s, d = u.shape
    d_in, h, n = ssm_dims(cfg)
    phead = s_cfg.head_dim

    zx = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    z, x = jnp.split(zx, 2, axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", u, p["dt_w"]).astype(jnp.float32)
        + p["dt_bias"])
    bcm = jnp.einsum("bsd,de->bse", u, p["bc_proj"])
    b_mat, c_mat = jnp.split(bcm, 2, axis=-1)
    a = -jnp.exp(p["a_log"])                              # [H], negative

    if decode:
        # causal conv via rolling state [B, W-1, d_in]
        width = s_cfg.conv_width
        window = jnp.concatenate([conv_state, x], axis=1)  # [B, W, d_in]
        xconv = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
        xconv = jax.nn.silu(xconv)[:, None]                # [B, 1, d_in]
        new_conv_state = window[:, 1:]
        xh = xconv.reshape(bsz, h, phead)
        dt1 = dt[:, 0]                                     # [B, H]
        g = jnp.exp(dt1 * a[None, :])                      # [B, H]
        outer = jnp.einsum("bh,bn,bhp->bhnp", dt1, b_mat[:, 0],
                           xh.astype(jnp.float32))
        new_state = ssm_state * g[..., None, None] + outer
        y = jnp.einsum("bn,bhnp->bhp", c_mat[:, 0],
                       new_state.astype(c_mat.dtype))
        y = y + xh * p["d_skip"].astype(y.dtype)[None, :, None]
        y = y.reshape(bsz, 1, d_in)
        states = (new_state, new_conv_state)
        xc_for_skip = xconv
    else:
        width = s_cfg.conv_width
        xconv = jax.nn.silu(_causal_conv(x, p["conv_w"], p["conv_b"]))
        new_conv_state = x[:, -(width - 1):]               # raw pre-conv tail
        xh = xconv.reshape(bsz, s, h, phead)
        y, final_state = ssd_chunked(
            xh, dt, a, b_mat, c_mat, chunk=s_cfg.chunk_size,
            initial_state=ssm_state)
        y = y + xh * p["d_skip"].astype(y.dtype)[None, None, :, None]
        y = y.reshape(bsz, s, d_in)
        states = (final_state, new_conv_state)

    # gated RMSNorm then output projection (Mamba2)
    y = L.rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, states


def init_ssm_state(cfg: ArchConfig, batch: int):
    s_cfg = cfg.ssm or SSMConfig()
    d_in, h, n = ssm_dims(cfg)
    return (jnp.zeros((batch, h, n, s_cfg.head_dim), jnp.float32),
            jnp.zeros((batch, s_cfg.conv_width - 1, d_in),
                      jnp.dtype(cfg.dtype)))
