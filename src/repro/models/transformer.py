"""Model assembly: heterogeneous layer stacks, scan-over-layers, caches.

The layer stack of an ArchConfig is compiled into *segments*: maximal runs
of layers with identical (param group, static behaviour). Each group's
params are stacked on a leading layer axis and each segment runs as one
``lax.scan`` (with per-layer ``jax.checkpoint`` remat) over its slice —
this keeps the HLO small for 24..81-layer models and bounds activation
memory to one layer (MaxText-style).

Groups:
  attention        — stacked attn(+MLP/MoE) layers (dense/MoE models, gemma3
                     local & global layers share one stack; the window
                     behaviour is static per segment)
  mamba2           — stacked Mamba2 layers
  shared_attention — ONE weight-tied attention block (zamba2) invoked at
                     every SHARED_ATTENTION position; each invocation has
                     its own KV cache slot. (Simplification vs. zamba2's
                     per-invocation LoRA deltas — recorded in DESIGN.md.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import sharding as S
from repro.common.config import ArchConfig, BlockKind
from repro.models import layers as L
from repro.models.attention import (
    AttnSpec, attention_block, decode_attention_block, init_attention_params,
    layer_attn_spec, ring_pack)
from repro.models.moe import init_moe_params, moe_block
from repro.models.ssm import init_mamba2_params, init_ssm_state, mamba2_block


@dataclasses.dataclass(frozen=True)
class Segment:
    group: str            # param stack name
    start: int            # offset into the group's stacked params
    length: int
    spec: Optional[AttnSpec]  # static attention behaviour (attention groups)
    cache_start: int      # offset into the cache group's stack
    cache_group: str = ""  # cache stack name ('<group>@swa' = ring buffer)


def cache_group_of(group: str, spec: Optional[AttnSpec]) -> str:
    """Sliding-window layers keep a RING cache of window size (they never
    attend beyond the window), full-attention layers a max_seq cache."""
    if spec is not None and spec.is_sliding:
        return group + "@swa"
    return group


def build_plan(cfg: ArchConfig) -> Tuple[List[Segment], Dict[str, int]]:
    """Segment the layer stack; returns (segments, cache_group -> #slots)."""
    kinds = cfg.layer_kinds()
    per_layer = []
    attn_idx = 0
    for i, kind in enumerate(kinds):
        if kind == BlockKind.ATTENTION:
            per_layer.append(("attention", layer_attn_spec(cfg, attn_idx)))
            attn_idx += 1
        elif kind == BlockKind.SHARED_ATTENTION:
            per_layer.append(("shared_attention", layer_attn_spec(cfg, 0)))
        elif kind == BlockKind.MAMBA2:
            per_layer.append(("mamba2", None))
        else:
            raise ValueError(kind)

    segments: List[Segment] = []
    offsets = {"attention": 0, "mamba2": 0, "shared_attention": 0}
    cache_off: Dict[str, int] = {}
    i = 0
    while i < len(per_layer):
        g, spec = per_layer[i]
        j = i
        while j < len(per_layer) and per_layer[j] == (g, spec):
            j += 1
        length = j - i
        cg = cache_group_of(g, spec)
        segments.append(Segment(g, offsets[g], length, spec,
                                cache_off.get(cg, 0), cg))
        offsets[g] += length if g != "shared_attention" else 0
        cache_off[cg] = cache_off.get(cg, 0) + length
        i = j
    return segments, cache_off


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def _init_attn_layer(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"norm_attn": jnp.zeros((cfg.d_model,), dtype),
         "norm_mlp": jnp.zeros((cfg.d_model,), dtype)}
    p.update(init_attention_params(ks[0], cfg, dtype))
    if cfg.moe is not None:
        p.update(init_moe_params(ks[1], cfg, dtype))
    else:
        d, f = cfg.d_model, cfg.d_ff
        p["w_gate"] = L.dense_init(ks[1], (d, f), d, dtype)
        p["w_in"] = L.dense_init(ks[2], (d, f), d, dtype)
        p["w_out"] = L.dense_init(
            jax.random.fold_in(ks[2], 1), (f, d), f, dtype)
    return p


def _init_mamba_layer(key, cfg: ArchConfig, dtype) -> dict:
    p = {"norm_in": jnp.zeros((cfg.d_model,), dtype)}
    p.update(init_mamba2_params(key, cfg, dtype))
    return p


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    kinds = cfg.layer_kinds()
    n_attn = sum(k == BlockKind.ATTENTION for k in kinds)
    n_mamba = sum(k == BlockKind.MAMBA2 for k in kinds)
    has_shared = any(k == BlockKind.SHARED_ATTENTION for k in kinds)

    keys = jax.random.split(key, 8)
    params: dict = {"blocks": {}}
    if cfg.frontend:
        params["frontend_proj"] = L.dense_init(
            keys[0], (cfg.frontend_dim, cfg.d_model), cfg.frontend_dim, dtype)
    params["embedding"] = L.dense_init(
        keys[1], (cfg.vocab_size, cfg.d_model), cfg.d_model, dtype)
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(
            keys[2], (cfg.d_model, cfg.vocab_size), cfg.d_model, dtype)

    if n_attn:
        lkeys = jax.random.split(keys[3], n_attn)
        params["blocks"]["attention"] = jax.vmap(
            lambda k: _init_attn_layer(k, cfg, dtype))(lkeys)
    if n_mamba:
        lkeys = jax.random.split(keys[4], n_mamba)
        params["blocks"]["mamba2"] = jax.vmap(
            lambda k: _init_mamba_layer(k, cfg, dtype))(lkeys)
    if has_shared:
        params["blocks"]["shared_attention"] = _init_attn_layer(
            keys[5], cfg, dtype)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def make_cache(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    """Decode caches per cache group (leading dim = #layer instances).

    Sliding-window groups ('<g>@swa') are RING buffers of window length —
    a 512k-context gemma3 keeps 1024-slot caches for its 40 local layers
    and full caches only for the 8 global ones.
    """
    _, cache_slots = build_plan(cfg)
    dtype = jnp.dtype(cfg.dtype)
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    cache: dict = {}
    for g, slots in cache_slots.items():
        if g == "mamba2" or not slots:
            continue
        seq = min(cfg.sliding_window, max_seq) if g.endswith("@swa") \
            else max_seq
        cache[g] = {
            "k": jnp.zeros((slots, batch, seq, kvh, hd), dtype),
            "v": jnp.zeros((slots, batch, seq, kvh, hd), dtype),
        }
    if cache_slots.get("mamba2", 0):
        ssm, conv = init_ssm_state(cfg, batch)
        slots = cache_slots["mamba2"]
        cache["mamba2"] = {
            "ssm": jnp.broadcast_to(ssm[None], (slots,) + ssm.shape),
            "conv": jnp.broadcast_to(conv[None], (slots,) + conv.shape),
        }
    return cache


def _residual_constraint(mesh: Optional[Mesh]):
    """Constrain the residual stream to [batch(data), seq, d(replicated)].

    Without this, GSPMD resolves the (batch over data) x (weight-D over
    data/fsdp) dot conflict by ALL-GATHERING THE ACTIVATIONS per layer
    (measured 37 GiB/chip on train_4k); the constraint flips its choice to
    all-gathering the (small) fsdp-sharded weight — i.e. actual FSDP.
    """
    if mesh is None:
        return lambda x: x
    bax = S.batch_axes(mesh)
    spec = bax if len(bax) > 1 else bax[0]
    sh = NamedSharding(mesh, P(spec, None, None))
    return lambda x: jax.lax.with_sharding_constraint(x, sh)


def grow_cache(cache: dict, max_seq: int, window: int = 0) -> dict:
    """Pad the kv seq dim of a prefill-built cache to ``max_seq``.

    Ring ('@swa') groups grow only to min(window, max_seq); padding a ring
    that prefilled fewer than ``window`` positions keeps residue alignment
    because slot i == position i while p < ring size.
    """
    out = {}
    for g, sub in cache.items():
        if g == "mamba2":
            out[g] = sub
            continue
        target = min(window, max_seq) if (g.endswith("@swa") and window) \
            else max_seq
        if g.endswith("@swa") and not window:
            target = sub["k"].shape[2]  # leave ring untouched

        def pad(a, t=target):
            s = a.shape[2]
            if s >= t:
                return a
            padding = [(0, 0)] * a.ndim
            padding[2] = (0, t - s)
            return jnp.pad(a, padding)

        out[g] = {k: pad(v) for k, v in sub.items()}
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _attn_layer_fwd(p, cfg: ArchConfig, x, positions, spec: AttnSpec,
                    kv=None, pos=None, build_cache=False):
    """One attention(+MLP/MoE) layer. Returns (x, aux, new_kv).

    Train/prefill: new_kv is the full-sequence {k, v} when build_cache
    (for populating the decode cache after prefill), else None.
    """
    h = L.rms_norm(x, p["norm_attn"], cfg.norm_eps)
    if kv is None:
        attn, k_full, v_full = attention_block(p, cfg, h, positions, spec)
        new_kv = {"k": k_full, "v": v_full} if build_cache else None
    else:
        attn, k_new, v_new = decode_attention_block(
            p, cfg, h, pos, kv["k"], kv["v"], spec)
        new_kv = {"k": k_new, "v": v_new}
    x = x + attn
    h = L.rms_norm(x, p["norm_mlp"], cfg.norm_eps)
    if cfg.moe is not None:
        mlp, aux = moe_block(p, cfg, h)
    else:
        mlp = L.swiglu(h, p["w_gate"], p["w_in"], p["w_out"])
        aux = jnp.float32(0.0)
    return x + mlp, aux, new_kv


def _mamba_layer_fwd(p, cfg: ArchConfig, x, state=None, decode=False):
    h = L.rms_norm(x, p["norm_in"], cfg.norm_eps)
    ssm_state = state["ssm"] if state is not None else None
    conv_state = state["conv"] if state is not None else None
    out, (new_ssm, new_conv) = mamba2_block(
        p, cfg, h, ssm_state, conv_state, decode=decode)
    return x + out, {"ssm": new_ssm, "conv": new_conv}


def forward(params: dict, cfg: ArchConfig, inputs: jnp.ndarray, *,
            positions: Optional[jnp.ndarray] = None,
            cache: Optional[dict] = None,
            decode_pos: Optional[jnp.ndarray] = None,
            remat: bool = True,
            build_cache: bool = False,
            skip_head: bool = False,
            mesh: Optional[Mesh] = None,
            remat_segments: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                   Optional[dict]]:
    """Run the model.

    Train: inputs [B, S] int tokens (or [B, S, F] frontend embeds),
      cache None -> (logits [B, S, V], aux, None).
    Prefill: as train with build_cache=True -> third output is a cache
      whose kv seq dim covers the prefill length (pad via
      ``grow_cache`` before decoding).
    Decode: inputs [B, 1], cache from ``make_cache``, decode_pos [B] ->
      (logits [B, 1, V], aux, new_cache).
    """
    decode = cache is not None
    if inputs.ndim == 3:  # modality frontend stub: precomputed embeddings
        x = jnp.einsum("bsf,fd->bsd", inputs.astype(jnp.dtype(cfg.dtype)),
                       params["frontend_proj"])
    else:
        x = params["embedding"][inputs]
    b, s = x.shape[:2]
    if positions is None:
        if decode:
            positions = decode_pos[:, None]
        else:
            # [1, S], NOT [B, S]: batch-replicated position tensors make
            # every rope cos/sin (and anything derived) materialise at
            # GLOBAL batch per chip under GSPMD (measured 14 GiB/chip).
            positions = jnp.arange(s)[None]

    segments, _ = build_plan(cfg)
    constrain = _residual_constraint(mesh)
    x = constrain(x)
    aux_total = jnp.float32(0.0)
    new_cache: Dict[str, Any] = {g: {} for g in
                                 (cache or {})} if decode else None

    def slice_tree(tree, start, length):
        return jax.tree.map(
            lambda a: jax.lax.slice_in_dim(a, start, start + length, axis=0),
            tree)

    # collect per-group cache updates as lists of (cache_start, subtree)
    cache_updates: Dict[str, list] = {}

    for seg in segments:
        if seg.group == "mamba2":
            p_seg = slice_tree(params["blocks"]["mamba2"],
                               seg.start, seg.length)
            if decode:
                c_seg = slice_tree(cache["mamba2"],
                                   seg.cache_start, seg.length)

                def mbody(xc, inp):
                    pl, cl = inp
                    xo, st = _mamba_layer_fwd(pl, cfg, xc, cl, decode=True)
                    return xo, st

                x, new_states = jax.lax.scan(mbody, x, (p_seg, c_seg))
                cache_updates.setdefault("mamba2", []).append(
                    (seg.cache_start, new_states))
            else:
                def mbody_t(xc, pl):
                    def run(pp, xx):
                        xo, st = _mamba_layer_fwd(pp, cfg, constrain(xx))
                        xo = constrain(xo)
                        return xo, (st if build_cache else None)
                    if remat:
                        run = jax.checkpoint(run)
                    return run(pl, xc)

                def mseg(ps_, xc):
                    return jax.lax.scan(mbody_t, xc, ps_)

                if remat_segments and not build_cache:
                    # hierarchical remat: save one residual per SEGMENT
                    # instead of per layer (81 -> 14 saves on zamba2);
                    # backward re-runs the segment forward once
                    mseg = jax.checkpoint(mseg)
                x, sts = mseg(p_seg, x)
                if build_cache:
                    cache_updates.setdefault("mamba2", []).append(
                        (seg.cache_start, sts))

        elif seg.group == "attention":
            p_seg = slice_tree(params["blocks"]["attention"],
                               seg.start, seg.length)
            spec = seg.spec
            if decode:
                c_seg = slice_tree(cache[seg.cache_group],
                                   seg.cache_start, seg.length)

                def abody(xc, inp):
                    pl, cl = inp
                    xo, aux, kv = _attn_layer_fwd(
                        pl, cfg, xc, None, spec, kv=cl, pos=decode_pos)
                    return xo, (kv, aux)

                x, (new_kv, auxs) = jax.lax.scan(abody, x, (p_seg, c_seg))
                aux_total = aux_total + jnp.sum(auxs)
                cache_updates.setdefault(seg.cache_group, []).append(
                    (seg.cache_start, new_kv))
            else:
                def abody_t(xc, pl):
                    def run(pp, xx):
                        xo, aux, kv = _attn_layer_fwd(
                            pp, cfg, constrain(xx), positions, spec,
                            build_cache=build_cache)
                        return constrain(xo), (aux, kv)
                    if remat:
                        run = jax.checkpoint(run)
                    xo, (aux, kv) = run(pl, xc)
                    return xo, (aux, kv)

                def aseg(ps_, xc):
                    return jax.lax.scan(abody_t, xc, ps_)

                if remat_segments and not build_cache:
                    aseg = jax.checkpoint(aseg)
                x, (auxs, kvs) = aseg(p_seg, x)
                aux_total = aux_total + jnp.sum(auxs)
                if build_cache:
                    if seg.cache_group.endswith("@swa"):
                        kvs = jax.tree.map(
                            lambda a: ring_pack(a, cfg.sliding_window,
                                                seq_axis=2), kvs)
                    cache_updates.setdefault(seg.cache_group, []).append(
                        (seg.cache_start, kvs))

        elif seg.group == "shared_attention":
            p_sh = params["blocks"]["shared_attention"]
            spec = seg.spec
            if decode:
                c_seg = slice_tree(cache[seg.cache_group],
                                   seg.cache_start, seg.length)
                c_one = jax.tree.map(lambda a: a[0], c_seg)
                x, aux, kv = _attn_layer_fwd(
                    p_sh, cfg, x, None, spec, kv=c_one, pos=decode_pos)
                aux_total = aux_total + aux
                cache_updates.setdefault(seg.cache_group, []).append(
                    (seg.cache_start,
                     jax.tree.map(lambda a: a[None], kv)))
            else:
                def run_sh(pp, xx):
                    xo, aux, kv = _attn_layer_fwd(
                        pp, cfg, constrain(xx), positions, spec,
                        build_cache=build_cache)
                    return constrain(xo), (aux, kv)
                if remat:
                    x, (aux, kv) = jax.checkpoint(run_sh)(p_sh, x)
                else:
                    x, (aux, kv) = run_sh(p_sh, x)
                aux_total = aux_total + aux
                if build_cache:
                    if seg.cache_group.endswith("@swa"):
                        kv = jax.tree.map(
                            lambda a: ring_pack(a, cfg.sliding_window,
                                                seq_axis=1), kv)
                    cache_updates.setdefault(seg.cache_group, []).append(
                        (seg.cache_start,
                         jax.tree.map(lambda a: a[None], kv)))
        else:
            raise ValueError(seg.group)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if skip_head:
        logits = x  # normed hidden states; caller applies a chunked head
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])

    if decode:
        for g, updates in cache_updates.items():
            full = cache[g]
            for start, sub in updates:
                full = jax.tree.map(
                    lambda a, u: jax.lax.dynamic_update_slice_in_dim(
                        a, u.astype(a.dtype), start, axis=0), full, sub)
            new_cache[g] = full
        return logits, aux_total, new_cache

    if build_cache:
        _, cache_slots = build_plan(cfg)
        prefill_cache: Dict[str, Any] = {}
        for g, updates in cache_updates.items():
            slots = cache_slots[g]
            full = jax.tree.map(
                lambda u: jnp.zeros((slots,) + u.shape[1:], u.dtype),
                updates[0][1])
            for start, sub in updates:
                full = jax.tree.map(
                    lambda a, u: jax.lax.dynamic_update_slice_in_dim(
                        a, u.astype(a.dtype), start, axis=0), full, sub)
            prefill_cache[g] = full
        return logits, aux_total, prefill_cache
    return logits, aux_total, None
