"""GQA attention: full / sliding-window / local-global, train+prefill+decode.

Memory discipline: train/prefill attention scans over query chunks so the
materialised score block is [B, H, chunk, S] instead of [B, H, S, S] —
exact softmax per chunk (a full key row is available), no online rescaling
needed. Decode attends one token against the cache; sliding-window decode
gathers only the window slice from the cache (sub-quadratic long-context
path used by long_500k).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ArchConfig, AttentionKind
from repro.models import layers as L
from repro.models.rope import apply_rope


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Static per-layer attention behaviour."""
    is_sliding: bool
    window: int


def init_attention_params(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "w_q": L.dense_init(ks[0], (d, h * hd), d, dtype),
        "w_k": L.dense_init(ks[1], (d, kv * hd), d, dtype),
        "w_v": L.dense_init(ks[2], (d, kv * hd), d, dtype),
        "w_o": L.dense_init(ks[3], (h * hd, d), h * hd, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p: dict, cfg: ArchConfig, x: jnp.ndarray,
                 positions: jnp.ndarray):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dq->bsq", x, p["w_q"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,dq->bsq", x, p["w_k"]).reshape(b, s, kv, hd)
    v = jnp.einsum("bsd,dq->bsq", x, p["w_v"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, kind=cfg.rope, theta=cfg.rope_theta)
    k = apply_rope(k, positions, kind=cfg.rope, theta=cfg.rope_theta)
    return q, k, v


def _expand_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """GQA: repeat kv heads to the full head count.

    Deliberately NOT a reshape-split of H into (G, KV): that reshape breaks
    the model-axis sharding of the head dim under GSPMD and forces an
    all-gather of heads (measured 16 GiB/chip on train_4k). ``repeat`` is a
    broadcast-like op whose output re-shards freely.
    """
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def _chunked_causal_attention(q, k, v, *, window: Optional[int],
                              chunk: int = 1024) -> jnp.ndarray:
    """Exact causal (optionally windowed) attention, scanned over q chunks.

    q: [B, S, H, hd]; k, v: [B, S, KV, hd]. Returns [B, S, H, hd].
    The materialised score block is [B, H, chunk, S] (never [B, H, S, S]);
    each chunk sees its full key row so per-chunk softmax is exact.
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    scale = hd ** -0.5
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = q.reshape(b, n_chunks, chunk, h, hd)
    k = _expand_kv(k, groups)
    v = _expand_kv(v, groups)
    kpos = jnp.arange(s)

    def one_chunk(carry, inp):
        qi, idx = inp                                   # [B, chunk, H, hd]
        qpos = idx * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bchd,bshd->bhcs", qi, k) * scale
        # additive batch-free bias [chunk, S]: a boolean mask broadcast to
        # the full logits shape would be saved for backward replicated at
        # GLOBAL batch per chip (measured 16 GiB on train_4k)
        mask = qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask = jnp.logical_and(mask, qpos[:, None] - kpos[None, :] < window)
        bias = jnp.where(mask, 0.0, -1e30).astype(jnp.float32)
        probs = jax.nn.softmax(
            logits.astype(jnp.float32) + bias[None, None], axis=-1)
        probs = probs.astype(v.dtype)
        out = jnp.einsum("bhcs,bshd->bchd", probs, v)
        return carry, out

    _, outs = jax.lax.scan(
        one_chunk, None,
        (jnp.moveaxis(qc, 1, 0), jnp.arange(n_chunks)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, n_chunks * chunk, h, hd)
    return out[:, :s]


def attention_block(p: dict, cfg: ArchConfig, x: jnp.ndarray,
                    positions: jnp.ndarray, spec: AttnSpec,
                    q_chunk: int = 1024):
    """Training / prefill self-attention.

    x: [B, S, D] -> (out [B, S, D], k [B, S, KV, hd], v [B, S, KV, hd]);
    k/v are returned so prefill can populate the decode cache.
    """
    b, s, d = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    window = spec.window if spec.is_sliding else None
    out = _chunked_causal_attention(q, k, v, window=window, chunk=q_chunk)
    w_o = p["w_o"].reshape(cfg.num_heads, cfg.resolved_head_dim, d)
    return jnp.einsum("bshq,hqd->bsd", out, w_o), k, v


def decode_attention_block(p: dict, cfg: ArchConfig, x: jnp.ndarray,
                           pos: jnp.ndarray, k_cache: jnp.ndarray,
                           v_cache: jnp.ndarray, spec: AttnSpec
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x: [B, 1, D]; caches: [B, S, KV, hd]; pos: [B] current
    position (tokens 0..pos-1 are valid cache). Returns (out, k_cache, v_cache).
    """
    b, _, d = x.shape
    s_cache = k_cache.shape[1]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    groups = h // kvh
    q, k, v = _project_qkv(p, cfg, x, pos[:, None])

    # Sliding-window layers carry a RING cache of <= window slots
    # (make_cache): slot i holds the newest absolute position p == i mod R.
    # The 512k cache is never touched by the 40/48 local layers of a
    # gemma3-style stack — this is what makes long_500k sub-quadratic in
    # traffic as well as compute.
    is_ring = spec.is_sliding and s_cache <= spec.window
    write_pos = pos % s_cache if is_ring else pos

    # point dynamic-update-slice write. (A one-hot multiply touches — and
    # under a seq-sharded cache ALL-GATHERS — the entire cache per layer:
    # measured 3.75 GiB x L of all-gather on long_500k.)
    def write(cache, new):
        def one(c, n, p_):
            return jax.lax.dynamic_update_slice_in_dim(c, n, p_, axis=0)
        return jax.vmap(one)(cache, new.astype(cache.dtype), write_pos)

    k_cache = write(k_cache, k)
    v_cache = write(v_cache, v)

    slot = jnp.arange(s_cache)
    if is_ring:
        # absolute position held by slot i: newest p <= pos with p==i (mod R)
        abs_pos = pos[:, None] - ((pos[:, None] - slot[None, :]) % s_cache)
        valid = abs_pos >= 0
    else:
        valid = slot[None, :] <= pos[:, None]
        if spec.is_sliding:  # full-size cache on a sliding layer
            valid = jnp.logical_and(
                valid, slot[None, :] > pos[:, None] - spec.window)

    # Grouped-KV einsums directly against the cache: expanding kv heads
    # (repeat) forces GSPMD to reshard the seq-sharded 512k cache against
    # the model-sharded q heads — measured 3.75 GiB x L all-gather on
    # long_500k. Reshaping tiny q instead keeps the cache sharding
    # untouched; the score/output contractions over the sharded seq dim
    # lower to partial sums + small all-reduces (distributed softmax).
    qg = q.reshape(b, 1, kvh, groups, hd)
    logits = jnp.einsum("bckgh,bskh->bkgcs", qg, k_cache) * (hd ** -0.5)
    logits = jnp.where(valid[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgcs,bskh->bckgh",
                     probs.astype(v_cache.dtype), v_cache)
    out = out.reshape(b, 1, h, hd)
    y = jnp.einsum("bshq,hqd->bsd",
                   out, p["w_o"].reshape(h, hd, d))
    return y, k_cache, v_cache


def ring_pack(kv: jnp.ndarray, window: int, seq_axis: int = 1) -> jnp.ndarray:
    """Pack full-sequence prefill kv into ring layout (slot = pos mod R).

    kv: [..., S, ...]; returns the last R = min(window, S) positions rolled
    so slot i holds the position with p % R == i.
    """
    s = kv.shape[seq_axis]
    r = min(window, s)
    tail = jax.lax.slice_in_dim(kv, s - r, s, axis=seq_axis)
    return jnp.roll(tail, shift=(s - r) % r, axis=seq_axis)


def layer_attn_spec(cfg: ArchConfig, layer_idx: int) -> AttnSpec:
    """Static attention behaviour of layer ``layer_idx``."""
    if cfg.attention_kind == AttentionKind.FULL:
        return AttnSpec(False, 0)
    if cfg.attention_kind == AttentionKind.SLIDING:
        return AttnSpec(True, cfg.sliding_window)
    if cfg.attention_kind == AttentionKind.LOCAL_GLOBAL:
        r = cfg.local_to_global_ratio
        is_global = (layer_idx % (r + 1)) == r
        return AttnSpec(not is_global, cfg.sliding_window)
    raise ValueError(cfg.attention_kind)
