"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

The dispatch machinery here is deliberately the same shape as Pyramid's
query routing (DESIGN.md §4): a router scores T tokens against E targets,
top-k targets per token are selected, and tokens move to per-target slots
bounded by a capacity factor. Experts are sharded over the ``model`` mesh
axis (expert parallelism); the dispatch/combine einsums lower to all-to-all
style collectives under GSPMD.

Load-balancing auxiliary loss follows Shazeer et al. (mean gate * mean
assignment fraction per expert).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ArchConfig
from repro.models import layers as L


def init_moe_params(key, cfg: ArchConfig, dtype) -> dict:
    moe = cfg.moe
    d, f, e = cfg.d_model, cfg.d_ff, moe.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": L.dense_init(ks[0], (d, e), d, jnp.float32),
        "e_gate": L.dense_init(ks[1], (e, d, f), d, dtype),
        "e_in": L.dense_init(ks[2], (e, d, f), d, dtype),
        "e_out": L.dense_init(ks[3], (e, f, d), f, dtype),
    }


MAX_GROUP = 4096  # tokens per dispatch group


def moe_block(p: dict, cfg: ArchConfig, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar).

    Grouped capacity dispatch (Switch/GShard style): tokens are split into
    groups of <= MAX_GROUP and dispatched within each group. A single flat
    [T, E, C] one-hot at T = 1M tokens would be ~TiB-scale; grouping keeps
    the dispatch tensor at [G, group, E, C_group] with C_group ~ group/E.
    """
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = moe.num_experts, moe.experts_per_token
    group = min(MAX_GROUP, t)
    while t % group:  # find a group size that tiles T exactly
        group //= 2
    ng = t // group
    cap = max(1, int(group * k * moe.capacity_factor / e))

    xt = x.reshape(ng, group, d)
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)                    # [G, T, E]

    topk_g, topk_e = jax.lax.top_k(gates, k)                   # [G, T, k]
    topk_g = topk_g / (jnp.sum(topk_g, axis=-1, keepdims=True) + 1e-9)

    # capacity assignment within each group's expert queue
    onehot = jax.nn.one_hot(topk_e, e, dtype=jnp.float32)      # [G, T, k, E]
    pos_in_queue = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.einsum("gtke,gtke->gtk", pos_in_queue,
                     onehot).astype(jnp.int32)
    keep = pos < cap
    gate_kept = jnp.where(keep, topk_g, 0.0)

    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * \
        keep[..., None].astype(jnp.float32)                    # [G, T, k, C]
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot, pos_oh)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", gate_kept, onehot, pos_oh)

    # move tokens to expert slots (all-to-all under expert sharding)
    ex_in = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xt)
    g_act = jax.nn.silu(jnp.einsum("gecd,edf->gecf", ex_in, p["e_gate"]))
    h = jnp.einsum("gecd,edf->gecf", ex_in, p["e_in"])
    ex_out = jnp.einsum("gecf,efd->gecd", g_act * h, p["e_out"])
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ex_out)

    # aux load-balance loss (over all tokens)
    me = jnp.mean(gates, axis=(0, 1))                          # [E]
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))        # [E]
    aux = e * jnp.sum(me * ce)
    return out.reshape(b, s, d), aux
