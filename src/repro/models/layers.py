"""Core layers: RMSNorm, SwiGLU MLP, embeddings, parameter initialisation.

Parameters are plain nested dicts. Sharding is name-based: ``spec_for``
maps (path, shape) -> a logical PartitionSpec tuple; stacked layer params
get a leading ``None`` (layer) axis. Logical names resolve through
``repro.common.sharding.logical_to_sharding``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_in: jnp.ndarray,
           w_out: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP: (silu(x W_g) * (x W_i)) W_o."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    h = jnp.einsum("...d,df->...f", x, w_in)
    return jnp.einsum("...f,fd->...d", g * h, w_out)


def dense_init(key, shape, in_axis_size, dtype) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(max(in_axis_size, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# name-based sharding rules
# ---------------------------------------------------------------------------

_RULES: Dict[str, Tuple] = {
    # attention
    "w_q": ("fsdp", "model"),
    "w_k": ("fsdp", None),
    "w_v": ("fsdp", None),
    "w_o": ("model", "fsdp"),
    "q_norm": (None,),
    "k_norm": (None,),
    # dense mlp
    "w_gate": ("fsdp", "model"),
    "w_in": ("fsdp", "model"),
    "w_out": ("model", "fsdp"),
    # moe — 'moe_ff' resolves to the model axis when the expert dim does
    # NOT divide it (e.g. grok's 8 experts on a 16-way model axis), so the
    # d_ff dim carries the tensor parallelism instead; otherwise replicated
    "router": ("fsdp", None),
    "e_gate": ("expert", "fsdp", "moe_ff"),
    "e_in": ("expert", "fsdp", "moe_ff"),
    "e_out": ("expert", "moe_ff", "fsdp"),
    # mamba2
    "in_proj": ("fsdp", "model"),
    "dt_w": ("fsdp", "model"),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "dt_bias": ("model",),
    "a_log": ("model",),
    "d_skip": ("model",),
    "ssm_norm": ("model",),
    "out_proj": ("model", "fsdp"),
    "bc_proj": ("fsdp", None),
    # embeddings / head / norms
    # vocab-dim params: V over model, D replicated. Sharding D over the
    # data axis (fsdp-style) conflicts with the batch sharding in the
    # lm_head contraction and makes GSPMD all-gather the 1M-token
    # activations instead of the weight (measured 37 GiB/chip).
    "embedding": ("model", None),
    "frontend_proj": (None, None),
    "lm_head": (None, "model"),
    "final_norm": (None,),
    "norm_attn": (None,),
    "norm_mlp": (None,),
    "norm_in": (None,),
}


def spec_for(name: str, ndim: int, stacked: bool) -> Tuple:
    """Logical partition tuple for parameter ``name`` with ``ndim`` dims."""
    base = _RULES.get(name)
    if base is None:
        raise KeyError(f"no sharding rule for param {name!r}")
    if stacked:
        base = (None,) + tuple(base)
    if len(base) != ndim:
        # rank mismatch (e.g. scalar bias): replicate trailing dims
        base = tuple(base[:ndim]) if len(base) > ndim else \
            tuple(base) + (None,) * (ndim - len(base))
    return tuple(base)


def tree_specs(params, stacked_keys=("attention", "mamba2")):
    """Mirror a param tree with logical partition tuples.

    Subtrees under blocks/attention and blocks/mamba2 are scan-stacked
    (leading layer axis); blocks/shared_attention is a SINGLE weight-tied
    block and must NOT be treated as stacked (a leading-None spec on an
    unstacked 2-D weight silently truncates to the wrong axes).
    """

    def leafify(node, path, stacked):
        out = {}
        for k, v in node.items():
            if isinstance(v, dict):
                out[k] = leafify(v, path + (k,),
                                 stacked or (path and path[-1] == "blocks"
                                             and k in stacked_keys))
            else:
                out[k] = spec_for(k, v.ndim if hasattr(v, "ndim")
                                  else len(v.shape), stacked)
        return out

    return leafify(params, (), False)
