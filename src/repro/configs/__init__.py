"""Assigned architecture configs. Importing this package registers all
architectures with the registry (``repro.common.registry``)."""
from repro.configs import (  # noqa: F401
    chatglm3_6b,
    gemma3_12b,
    grok_1_314b,
    h2o_danube_1_8b,
    internvl2_2b,
    mamba2_780m,
    musicgen_medium,
    phi35_moe_42b,
    qwen3_1_7b,
    zamba2_7b,
)
