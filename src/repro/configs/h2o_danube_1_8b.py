"""h2o-danube-1.8b — dense llama+mistral mix with sliding-window attention.

[arXiv:2401.16818] 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.
Danube uses Mistral-style SWA (window 4096 during training).
"""
from repro.common.config import ArchConfig, AttentionKind
from repro.common.registry import register_arch

CONFIG = register_arch(ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    attention_kind=AttentionKind.SLIDING,
    sliding_window=4096,
    source="[arXiv:2401.16818]",
))
