"""gemma3-12b — dense, 5:1 local(SWA):global attention pattern, 128k ctx.

[hf:google/gemma-3 family] 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144. Local layers use a 1024-token sliding window (gemma3 spec);
every 6th layer is global.
"""
from repro.common.config import ArchConfig, AttentionKind
from repro.common.registry import register_arch

CONFIG = register_arch(ArchConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=240,
    attention_kind=AttentionKind.LOCAL_GLOBAL,
    local_to_global_ratio=5,
    sliding_window=1024,
    rope_theta=1_000_000.0,
    source="[hf:google/gemma-3-1b-pt]",
))
