"""internvl2-2b — VLM: InternViT frontend (STUB) + InternLM2-1.8b decoder.

[arXiv:2404.16821] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
Per the assignment, the vision encoder + projector are a stub:
``input_specs`` provides precomputed patch embeddings [B, S, frontend_dim];
the model applies a learned projection and runs the language decoder.
"""
from repro.common.config import ArchConfig
from repro.common.registry import register_arch

CONFIG = register_arch(ArchConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision",
    frontend_dim=1024,   # InternViT-300M patch embedding width
    source="[arXiv:2404.16821]",
))
