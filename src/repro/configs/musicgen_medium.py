"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284] 48L d_model=1536 24H (GQA kv=24, i.e. MHA) d_ff=6144
vocab=2048. The EnCodec conv codec frontend is a STUB: ``input_specs``
provides precomputed frame embeddings [B, S, frontend_dim].
"""
from repro.common.config import ArchConfig
from repro.common.registry import register_arch

CONFIG = register_arch(ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    frontend="audio",
    frontend_dim=128,    # EnCodec latent width
    source="[arXiv:2306.05284]",
))
