"""mamba2-780m — attention-free SSM with state-space duality.

[arXiv:2405.21060] 48L d_model=1536 (attn-free) d_ff=0 vocab=50280,
ssm_state=128.
"""
from repro.common.config import ArchConfig, BlockKind, RoPEKind, SSMConfig
from repro.common.registry import register_arch

CONFIG = register_arch(ArchConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    block_pattern=(BlockKind.MAMBA2,),
    rope=RoPEKind.NONE,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256),
    source="[arXiv:2405.21060]",
))
