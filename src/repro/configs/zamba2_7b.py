"""zamba2-7b — hybrid: Mamba2 backbone + weight-shared attention blocks.

[arXiv:2411.15242] 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64. Pattern: 5 Mamba2 blocks then one shared
attention block (weight-tied across all its occurrences; zamba2's
per-invocation LoRA deltas are simplified away — see DESIGN.md).
"""
from repro.common.config import ArchConfig, BlockKind, SSMConfig
from repro.common.registry import register_arch

CONFIG = register_arch(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=(BlockKind.MAMBA2,) * 5 + (BlockKind.SHARED_ATTENTION,),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk_size=256),
    source="[arXiv:2411.15242]",
))
