"""grok-1-314b — 8-expert top-2 MoE.

[hf:xai-org/grok-1] 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2.
"""
from repro.common.config import ArchConfig, MoEConfig
from repro.common.registry import register_arch

CONFIG = register_arch(ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    moe=MoEConfig(num_experts=8, experts_per_token=2),
    source="[hf:xai-org/grok-1]",
))
