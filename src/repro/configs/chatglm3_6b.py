"""chatglm3-6b — dense GQA (kv=2) with GLM 2d RoPE.

[arXiv:2406.12793] 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
2d RoPE: rotary applied to the first half of each head dim.
"""
from repro.common.config import ArchConfig, RoPEKind
from repro.common.registry import register_arch

CONFIG = register_arch(ArchConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope=RoPEKind.TWO_D,
    source="[arXiv:2406.12793]",
))
