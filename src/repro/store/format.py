"""Segment serialisation for the versioned index store.

A *segment* is one ``.npz`` file holding a dict of numpy arrays (one
sub-HNSW, or the meta graph + partition labels). Integrity is tracked
with a **content checksum**: sha256 over the arrays' canonical bytes
(sorted key order; each key hashed with its name, dtype, shape, and raw
C-contiguous data). Hashing content instead of file bytes is deliberate:
``np.savez`` zip containers embed timestamps, so two bit-identical
indexes would hash to different *files* — while their content checksums
agree, which is exactly the determinism contract the parallel builder is
held to (parallel build == sequential build manifest checksums).
"""
from __future__ import annotations

import hashlib
import os
import zipfile
from typing import Dict, List

import numpy as np

from repro.core import hnsw as H


class StoreError(RuntimeError):
    """The store layout is missing or malformed."""


class StoreCorruptionError(StoreError):
    """A segment failed its checksum or could not be decoded."""


def content_checksum(arrays: Dict[str, np.ndarray]) -> str:
    """sha256 over the canonical bytes of an array dict (key-sorted)."""
    h = hashlib.sha256()
    for key in sorted(arrays):
        a = np.ascontiguousarray(arrays[key])
        h.update(key.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def graph_to_arrays(g: H.HNSWGraph) -> Dict[str, np.ndarray]:
    """Flatten one HNSW graph into a segment's array dict.

    Tag bitsets are persisted under a ``tags`` key — but only when any
    tag is non-zero: an untagged (or all-zero) graph serialises exactly
    as before this key existed, so historical segment checksums and the
    parallel-vs-sequential build determinism gate are unaffected.
    """
    out: Dict[str, np.ndarray] = {
        "data": np.ascontiguousarray(g.data, np.float32),
        "ids": np.ascontiguousarray(g.ids, np.int64),
        "levels": np.ascontiguousarray(g.levels, np.int32),
        "entry": np.asarray(g.entry, np.int64),
        "num_levels": np.asarray(len(g.neighbors), np.int64),
    }
    if g.tags is not None and np.any(np.asarray(g.tags)):
        out["tags"] = np.ascontiguousarray(g.tags, np.int64)
    for lvl, adj in enumerate(g.neighbors):
        out[f"nbr_{lvl}"] = np.ascontiguousarray(adj, np.int32)
    return out


def graph_from_arrays(arrays: Dict[str, np.ndarray],
                      metric: str) -> H.HNSWGraph:
    """Inverse of :func:`graph_to_arrays` (metric rides in the
    manifest, not the segment; a missing ``tags`` key means untagged)."""
    num_levels = int(arrays["num_levels"])
    neighbors: List[np.ndarray] = [
        arrays[f"nbr_{lvl}"] for lvl in range(num_levels)]
    tags = arrays.get("tags")
    return H.HNSWGraph(
        data=arrays["data"], ids=arrays["ids"], neighbors=neighbors,
        levels=arrays["levels"], entry=int(arrays["entry"]),
        metric=metric,
        tags=None if tags is None else np.asarray(tags, np.int64))


def write_segment(path: str, arrays: Dict[str, np.ndarray], *,
                  fsync: bool = True) -> str:
    """Write one segment and return its content checksum. Callers write
    into a not-yet-published tmpdir, so no in-place atomicity is needed
    here — the version-level rename is the publish barrier."""
    checksum = content_checksum(arrays)
    with open(path, "wb") as f:
        np.savez(f, **arrays)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    return checksum


def read_segment(path: str, expected_checksum: str = "",
                 ) -> Dict[str, np.ndarray]:
    """Load one segment, verifying its content checksum when given."""
    try:
        with np.load(path) as z:
            arrays = {k: z[k] for k in z.files}
    except (zipfile.BadZipFile, ValueError, KeyError, OSError,
            EOFError) as e:
        raise StoreCorruptionError(
            f"segment {path} could not be decoded: {e!r}") from e
    if expected_checksum:
        got = content_checksum(arrays)
        if got != expected_checksum:
            raise StoreCorruptionError(
                f"segment {path} checksum mismatch: manifest "
                f"{expected_checksum[:12]}.., file {got[:12]}..")
    return arrays
