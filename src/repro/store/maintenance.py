"""Online index maintenance: delta-log compaction + shard rebalancing.

The delta log (``repro.store.store.DeltaLog``) makes updates durable but
grows forever, and recovery replay cost grows with it; shard assignment
is frozen at build time, so sustained writes skew sub-dataset sizes and
drift the data away from the routing centroids. :class:`Compactor` is
the background loop (maxtext-checkpointer style: all I/O off the
serving path, the serving threads only bump a counter) that fixes both:

  * **compaction** — fold the committed log into a freshly *published*
    store version, then truncate the log. The version-directory rename
    inside :meth:`IndexStore.publish` is the single commit point:
    ``IndexStore.latest`` is newest-wins, so a crash at ANY step —
    before the publish (nothing changed), between publish and truncate
    (new version wins, stale log belongs to the old version and is
    never replayed), between truncate and the ``CURRENT`` flip, or mid
    hot-swap — recovers to the identical logical state with every
    record applied exactly once;
  * **rebalance** — at most one shard split/merge per cycle when size
    or per-shard latency skew crosses a threshold
    (:func:`repro.build.planner.plan_rebalance`), plus periodic
    meta-HNSW centroid refresh through the kmeans++ path
    (:func:`repro.core.router.refresh_centroids`);
  * **hot-swap** — the folded candidate replaces the serving engine via
    ``Brokers.replace_index`` (new engine up before the old comes
    down), which is also when writes applied since the last swap become
    visible to queries.

Writes route through :meth:`Compactor.add_items` /
:meth:`Compactor.remove_items`: a short write lock excludes them only
from the final catch-up + publish window — the bulk fold runs from a
store snapshot, concurrent with serving AND writing.

Scheduling is step-based, never wall-clock: the compactor registers a
drain hook on the engine (the same batch-drain boundary the
``FaultSchedule`` ticks on), and tests drive :meth:`run_once` directly
— fully deterministic, no sleeps. ``start()`` adds the production
background thread on top of the same ``run_once``.
"""
from __future__ import annotations

import itertools
import logging
import threading
from typing import Callable, List, Optional

import numpy as np

from repro.core.meta_index import PyramidIndex
from repro.obs import NULL_TRACER, MetricsRegistry
from repro.store.store import IndexStore

logger = logging.getLogger(__name__)


class Compactor:
    """Background delta-log compaction + shard maintenance for one
    store-attached index.

    Args:
      store: the :class:`IndexStore` the index was loaded from /
        published to.
      index: the live (serving) index, attached to the current
        version's delta log.
      brokers, name: when given, each cycle hot-swaps the serving
        engine via ``brokers.replace_index(name, candidate)``.
      on_swap: alternative swap callback ``(candidate) -> engine|None``
        for callers not using :class:`repro.core.api.Brokers`.
      threshold_records: fold once this many records were journaled
        through this compactor since the last cycle (``run_once`` with
        ``force=True`` ignores it).
      rebalance: enable split/merge planning (one op per cycle).
      split_factor / merge_factor / latency_factor: skew thresholds,
        see :func:`repro.build.planner.plan_rebalance`.
      refresh_every: run the kmeans++ centroid refresh every N cycles
        (0 disables — it is a full routing rebuild).
      gc_keep: run ``store.gc(keep=...)`` after a successful cycle
        (``None`` leaves old versions for crash forensics).
      fault_hook: test seam — called with the step name at every commit
        boundary (``"fold"``, ``"publish"``, ``"truncate"``, ``"flip"``,
        ``"swap"``); raising inside it simulates a kill at exactly that
        point.
      poll_s: background-thread wakeup period (thread mode only).
    """

    _STEPS = ("fold", "publish", "truncate", "flip", "swap")

    def __init__(self, store: IndexStore, index: PyramidIndex, *,
                 brokers=None, name: Optional[str] = None,
                 on_swap: Optional[Callable] = None,
                 threshold_records: int = 64,
                 rebalance: bool = True,
                 split_factor: float = 4.0, merge_factor: float = 0.25,
                 latency_factor: float = 4.0,
                 refresh_every: int = 0,
                 gc_keep: Optional[int] = None,
                 catchup_rounds: int = 4,
                 fault_hook: Optional[Callable[[str], None]] = None,
                 poll_s: float = 1.0,
                 registry: Optional[MetricsRegistry] = None,
                 tracer=None):
        self.store = store
        self.index = index
        self.brokers = brokers
        self.name = name
        self.on_swap = on_swap
        self.threshold_records = threshold_records
        self.rebalance = rebalance
        self.split_factor = split_factor
        self.merge_factor = merge_factor
        self.latency_factor = latency_factor
        self.refresh_every = refresh_every
        self.gc_keep = gc_keep
        self.catchup_rounds = catchup_rounds
        self.fault_hook = fault_hook
        self.poll_s = poll_s

        # write lock: writers hold it per update; the compactor holds it
        # only across the final catch-up + publish + truncate + flip +
        # swap window (the bulk fold runs lock-free from the store)
        self._write_lock = threading.Lock()
        self._cycle_lock = threading.Lock()   # one cycle at a time
        self._since_fold = 0    # records journaled through this object
        self._wake = threading.Event()
        self._installed_engine = None   # last engine install()ed on
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self._active = False    # a cycle is in flight (stats)

        # counter-backed bookkeeping (pass the engine's registry — what
        # Brokers.attach_maintenance does — and one /metrics scrape
        # covers serving + maintenance; swap counts stay monotonic
        # across the hot-swaps this very loop performs)
        self.obs = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        m = self.obs
        self._m_cycles = m.counter(
            "pyramid_maintenance_cycles_total",
            "completed compaction cycles")
        self._m_folded = m.counter(
            "pyramid_maintenance_folded_records_total",
            "delta-log records folded into published versions")
        self._m_truncated = m.counter(
            "pyramid_maintenance_truncated_records_total",
            "delta-log records truncated after publish")
        self._m_swaps = m.counter(
            "pyramid_maintenance_swaps_total",
            "serving-engine hot-swaps performed")
        m.gauge("pyramid_maintenance_pending_records",
                "records journaled since the last fold",
                fn=lambda: self._since_fold)
        self.rebalance_ops: List[tuple] = []
        self.refreshes = 0
        self.last_version: Optional[str] = None
        self.last_error: Optional[str] = None

    # counter-backed views (the Prometheus series are the bookkeeping)
    @property
    def cycles(self) -> int:
        return int(self._m_cycles.value)

    @property
    def folded_records(self) -> int:
        return int(self._m_folded.value)

    @property
    def truncated_records(self) -> int:
        return int(self._m_truncated.value)

    @property
    def swaps(self) -> int:
        return int(self._m_swaps.value)

    # -- write path ---------------------------------------------------------

    def add_items(self, vectors: np.ndarray,
                  ids: Optional[np.ndarray] = None, *,
                  tags: Optional[np.ndarray] = None) -> PyramidIndex:
        """Journaled insert into the live index (excluded only from the
        compactor's brief publish window by the write lock)."""
        from repro.core.updates import add_items
        with self._write_lock:
            out = add_items(self.index, vectors, ids, tags=tags)
            self._since_fold += 1
            return out

    def set_item_tags(self, ids: np.ndarray,
                      tags: np.ndarray) -> PyramidIndex:
        """Journaled tag assignment on the live index (folded and
        replayed like inserts, so tags survive compaction)."""
        from repro.core.updates import set_item_tags
        with self._write_lock:
            out = set_item_tags(self.index, ids, tags)
            self._since_fold += 1
            return out

    def remove_items(self, ids: np.ndarray) -> PyramidIndex:
        """Journaled (tombstoned) delete from the live index.

        Also tombstones ``ids`` on the current serving engine: the
        engine serves its construction-time arena snapshot, so without
        the filter a removed id would keep surfacing in results until
        the next hot-swap."""
        from repro.core.updates import remove_items
        with self._write_lock:
            out = remove_items(self.index, ids)
            self._since_fold += 1
        eng = self._engine()
        if eng is not None:
            eng.add_tombstones(ids)
        return out

    # -- scheduling ---------------------------------------------------------

    def install(self, engine) -> None:
        """Hook this compactor into a serving engine: a batch-drain step
        counter (the deterministic clock — no timers) and the
        ``stats()['maintenance']`` provider."""
        engine.add_drain_hook(self._on_drain)
        engine.set_maintenance_stats(self.stats)
        self._installed_engine = engine

    def _on_drain(self, actor: str) -> None:
        # executor thread: never do I/O here — just wake the worker
        if self._running and self._since_fold >= self.threshold_records:
            self._wake.set()

    def due(self) -> bool:
        return self._since_fold >= self.threshold_records

    def tick(self) -> Optional[str]:
        """Deterministic driver: run one cycle if the journaled-record
        threshold is crossed (tests and storm drivers call this at their
        own step boundaries)."""
        if self.due():
            return self.run_once(force=True)
        return None

    def start(self) -> "Compactor":
        """Production mode: a daemon thread that folds whenever woken by
        the drain hook (or every ``poll_s`` as a fallback)."""
        if self._thread is not None:
            return self
        self._running = True
        self._thread = threading.Thread(
            target=self._loop, name="compactor", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._running = False
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=30.0)
            self._thread = None

    def _loop(self) -> None:
        while self._running:
            self._wake.wait(timeout=self.poll_s)
            self._wake.clear()
            if not self._running:
                return
            try:
                if self.due():
                    self.run_once(force=True)
            except Exception as e:   # keep the loop alive; surface in
                self.last_error = repr(e)       # stats, not a dead thread
                logger.exception("compaction cycle failed")

    # -- the cycle ----------------------------------------------------------

    def _fault(self, step: str) -> None:
        if self.fault_hook is not None:
            self.fault_hook(step)

    def _apply(self, index: PyramidIndex, records) -> int:
        from repro.core.updates import (add_items, remove_items,
                                        set_item_tags)
        n = 0
        for op, vectors, ids, tags in records:
            if op == "remove":
                remove_items(index, ids, log_delta=False)
            elif op == "tags":
                set_item_tags(index, ids, tags, log_delta=False)
            else:
                add_items(index, vectors, ids, tags=tags,
                          log_delta=False)
            n += 1
        return n

    def _plan_op(self):
        if not self.rebalance:
            return None
        from repro.build.planner import plan_rebalance
        stats = None
        eng = self._engine()
        if eng is not None:
            try:
                stats = eng.stats()
            except Exception:
                stats = None
        return plan_rebalance(
            self.index, engine_stats=stats,
            split_factor=self.split_factor,
            merge_factor=self.merge_factor,
            latency_factor=self.latency_factor)

    def _engine(self):
        if self.brokers is not None and self.name is not None:
            try:
                return self.brokers.get_engine(self.name)
            except KeyError:
                return None
        return self._installed_engine

    def run_once(self, *, force: bool = False) -> Optional[str]:
        """One full maintenance cycle. Returns the new version id, or
        ``None`` when below threshold with nothing to rebalance.

        Sequence (commit boundaries in CAPS; a crash anywhere replays
        to the identical state — the RENAME is the one commit point):

          1. fold: load the current version fresh from the store and
             replay its committed log prefix (lock-free; serving and
             writers keep going);
          2. rebalance the candidate (split/merge/centroid refresh);
          3. catch-up rounds: replay the tail the storm appended while
             we folded (still lock-free);
          4. under the write lock: drain the final tail, PUBLISH the
             candidate (rename = commit), truncate the old log, flip
             ``CURRENT``, hot-swap the serving engine, and make the
             candidate the live write target (its fresh, empty log now
             takes the journal — "delta-log length returns to 0").
        """
        with self._cycle_lock:
            log = self.index.delta_log()
            if log is None:
                raise ValueError(
                    "compactor needs a store-attached index "
                    "(IndexStore.publish/load attach the delta log)")
            plan_op = self._plan_op()
            refresh_due = bool(
                self.refresh_every
                and (self.cycles + 1) % self.refresh_every == 0)
            if (not force and self._since_fold < self.threshold_records
                    and plan_op is None and not refresh_due):
                return None
            self._active = True
            try:
                return self._cycle(plan_op, refresh_due)
            finally:
                self._active = False

    def _cycle(self, plan_op, refresh_due: bool) -> str:
        store = self.store
        old_vid = store.latest()
        if old_vid is None:
            raise ValueError(f"no published version under {store.root}")
        old_log = store.reader(old_vid).delta_log()

        with self.tracer.span("compaction.cycle", version_from=old_vid,
                              rebalance=bool(plan_op)) as cyc:
            # 1. bulk fold from a snapshot — bounded by the count
            # observed NOW so a record committing mid-replay stays in
            # the tail
            snapshot = len(old_log)
            candidate = store.load(version=old_vid, replay_delta=False,
                                   attach_delta=False)
            with self.tracer.span("compaction.fold", records=snapshot):
                applied = self._apply(candidate, itertools.islice(
                    old_log.replay(), snapshot))

            # 2. shard maintenance on the candidate (never the serving
            # index): split/merge by skew, periodic centroid refresh
            if plan_op is not None:
                from repro.build.planner import merge_shards, split_shard
                with self.tracer.span("compaction.rebalance",
                                      op=list(plan_op)):
                    if plan_op[0] == "split":
                        split_shard(candidate, plan_op[1])
                    else:
                        merge_shards(candidate, plan_op[1], plan_op[2])
                self.rebalance_ops.append(plan_op)
            if refresh_due:
                from repro.core.router import refresh_centroids
                with self.tracer.span("compaction.refresh_centroids"):
                    refresh_centroids(candidate)
                self.refreshes += 1

            # 3. lock-free catch-up: drain writers' concurrent appends
            with self.tracer.span("compaction.catchup"):
                for _ in range(self.catchup_rounds):
                    n = self._apply(candidate,
                                    old_log.replay(start=applied))
                    applied += n
                    if n == 0:
                        break

            # 4. the commit window: writers excluded, queries flowing
            with self.tracer.span("compaction.commit"):
                with self._write_lock:
                    applied += self._apply(candidate,
                                           old_log.replay(start=applied))
                    self._fault("fold")
                    vid = store.publish(candidate, set_current=False)
                    self._fault("publish")  # <- RENAME landed: committed
                    self._m_truncated.inc(old_log.truncate())
                    self._fault("truncate")
                    store.set_current(vid)
                    self._fault("flip")
                    self._fault("swap")
                    new_engine = None
                    if self.brokers is not None and self.name is not None:
                        new_engine = self.brokers.replace_index(
                            self.name, candidate)
                    elif self.on_swap is not None:
                        new_engine = self.on_swap(candidate)
                    if new_engine is not None:
                        self._m_swaps.inc()
                        self.tracer.instant("maintenance.swap",
                                            version=vid)
                    self.index = candidate  # new live write target, its
                    self._since_fold = 0    # empty log takes the journal
            if new_engine is not None:
                self.install(new_engine)
            self._m_cycles.inc()
            self._m_folded.inc(applied)
            self.last_version = vid
            cyc.set(version_to=vid, folded=applied)
        if self.gc_keep is not None:
            store.gc(keep=self.gc_keep)
        return vid

    # -- observability ------------------------------------------------------

    def stats(self) -> dict:
        return {
            "cycles": self.cycles,
            "swaps": self.swaps,
            "active": self._active,
            "pending_records": self._since_fold,
            "threshold_records": self.threshold_records,
            "folded_records": self.folded_records,
            "truncated_records": self.truncated_records,
            "rebalance_ops": [list(op) for op in self.rebalance_ops],
            "centroid_refreshes": self.refreshes,
            "last_version": self.last_version,
            "last_error": self.last_error,
        }
