"""Versioned on-disk index store (the paper's HDFS persistence layer).

Replaces the seed-era monolithic ``pickle.dump`` with a publishable
format a cluster can actually serve from:

  * per-shard ``.npz`` segments + a meta segment + ``manifest.json``
    (config, shard list, content checksums, version id);
  * crash-safe atomic publish: segments are written to a tmpdir and the
    whole version appears with one ``rename`` — readers never observe a
    half-written version;
  * lazy per-shard loading (:meth:`IndexStore.reader`) so an engine
    executor can fetch only its shard;
  * an append-only delta log that ``repro.core.updates.add_items``
    writes through, replayed on load — inserts survive restarts;
  * GC of superseded versions (:meth:`IndexStore.gc`).

    from repro.store import IndexStore
    store = IndexStore("/data/pyramid/wiki")
    vid = store.publish(index)          # atomic; attaches the delta log
    index = store.load()                # latest version + delta replay
"""
from repro.store.format import (StoreCorruptionError, StoreError,
                                content_checksum, graph_from_arrays,
                                graph_to_arrays, read_segment,
                                write_segment)
from repro.store.maintenance import Compactor
from repro.store.store import DeltaLog, IndexStore, StoreReader

__all__ = [
    "Compactor", "DeltaLog", "IndexStore", "StoreReader",
    "StoreCorruptionError", "StoreError",
    "content_checksum", "graph_from_arrays", "graph_to_arrays",
    "read_segment", "write_segment",
]
