"""The versioned index store: atomic publish, lazy reads, delta log, GC.

On-disk layout (one store root per dataset name)::

    root/
      CURRENT                     # text file: id of the published version
      versions/
        v0000001/
          manifest.json           # config, shard list, content checksums
          meta.npz                # meta-HNSW + part_of_center
          shard-0000.npz ...      # one segment per sub-HNSW
          delta/
            LOG                   # append-only jsonl of update records
            d000001.npz ...       # one per add_items / remove_items call

Crash-safety invariants:

  * a version is written to ``root/.tmp-<uuid>/`` and appears only via
    one atomic ``rename`` into ``versions/`` — readers can never observe
    a partial version, and a crashed publish leaves only a ``.tmp-``
    orphan that the next GC sweeps;
  * the version id is *claimed by the rename itself*: two concurrent
    publishers race on ``rename`` and the loser simply retries with the
    next id, so both end up with distinct, complete versions;
  * ``CURRENT`` is updated by write-tmp + ``os.replace`` (atomic on
    POSIX); if the process dies between the version rename and the
    ``CURRENT`` flip, :meth:`IndexStore.latest` falls back to the newest
    complete version on disk, so the publish still lands;
  * a delta record is two steps — write the ``.npz``, then append one
    jsonl line to ``LOG`` — and only the ``LOG`` line makes it real: a
    crash mid-append leaves an orphan file that replay ignores.
"""
from __future__ import annotations

import dataclasses
import errno
import fcntl
import json
import os
import shutil
import time
import uuid
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.common.config import PyramidConfig
from repro.core import hnsw as H
from repro.core.meta_index import PyramidIndex
from repro.store.format import (StoreError, graph_from_arrays,
                                graph_to_arrays, read_segment,
                                write_segment)

FORMAT_VERSION = 1
_MANIFEST = "manifest.json"
_META_SEG = "meta.npz"
_CURRENT = "CURRENT"


def _jsonable(obj):
    """Coerce build stats (numpy scalars/arrays inside) to plain JSON."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def _fsync_dir(path: str) -> None:
    try:   # best effort: not all filesystems allow dir fds
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
    except OSError:
        pass


class DeltaLog:
    """Append-only update journal of one published version.

    Each :func:`repro.core.updates.add_items` call appends one insert
    record (the *raw* vectors plus their resolved global ids), each
    ``remove_items`` call one tombstone record (ids only, LOG line
    tagged ``"op": "remove"`` — insert lines carry no ``op`` key, so an
    insert-only log is byte-identical to the pre-tombstone format), and
    each ``set_item_tags`` call one tag record (ids + tag bitsets, LOG
    line tagged ``"op": "tags"``).
    Replay applies records in journal order back through
    ``add_items``/``remove_items`` themselves, so the rebuilt shards are
    bit-identical to the pre-crash in-memory index. The jsonl ``LOG``
    line, written and fsynced *after* the record file, is the commit
    point.
    """

    def __init__(self, directory: str):
        self.dir = directory
        self.log_path = os.path.join(directory, "LOG")
        self._count: Optional[int] = None   # committed records (cached)
        self._log_size: int = -1            # LOG size when cached

    def _entries(self) -> List[dict]:
        try:
            with open(self.log_path, "rb") as f:
                body = f.read()
        except OSError:
            return []
        # the trailing newline IS the commit point (append fsyncs the
        # line and its newline together): a tail without one is an
        # uncommitted torn write — the exact bytes _heal_tail truncates
        # before the next append, so reader and writer agree on what
        # committed even when the torn tail happens to parse as JSON
        body = body[: body.rfind(b"\n") + 1]
        entries = []
        for line in body.decode("utf-8", "replace").splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                entries.append(json.loads(line))
            except json.JSONDecodeError:
                # torn mid-file line (should not happen given the
                # commit rule): treat everything after it as torn too
                break
        return entries

    def __len__(self) -> int:
        return len(self._entries())

    def ensure_writable(self) -> None:
        """Raise unless the owning version still exists. Journaling into
        a GC'd version would silently makedirs a ghost delta dir no
        restart path can ever find or replay; ``add_items`` calls this
        BEFORE mutating the index so the failure is clean."""
        vdir = os.path.dirname(os.path.abspath(self.dir))
        if not os.path.exists(os.path.join(vdir, _MANIFEST)):
            raise StoreError(
                f"delta log's version at {vdir} is gone (superseded and "
                "GC'd?); publish a new version before journaling inserts")

    def _heal_tail(self) -> None:
        """Truncate a torn final line (crash mid-append). Replay already
        ignores the fragment, but appending after it would glue the next
        — fully committed — record onto the same physical line and lose
        it on every future replay."""
        try:
            size = os.path.getsize(self.log_path)
        except OSError:
            return
        if size == 0:
            return
        with open(self.log_path, "rb+") as f:
            f.seek(-1, os.SEEK_END)
            if f.read(1) == b"\n":
                return
            f.seek(0)
            body = f.read()
            keep = body.rfind(b"\n") + 1   # 0 when no complete line
            f.truncate(keep)
            f.flush()
            os.fsync(f.fileno())

    def append(self, vectors: np.ndarray, ids: np.ndarray, *,
               tags: Optional[np.ndarray] = None) -> str:
        """Commit one insert record.

        Safe against concurrent writers *on the same host*: the whole
        append runs under an advisory ``flock`` and the record file is
        claimed with ``O_EXCL``, so two attached indexes journaling into
        the same version cannot clobber each other's records or
        interleave LOG lines (cross-host writers on network filesystems
        without flock semantics are out of scope).

        ``tags`` (optional [m] int64 bitsets) ride in the record under a
        ``tags`` array — included only when any tag is non-zero, so
        untagged insert records stay byte-identical to the pre-tag
        format."""
        arrays = {"vectors": np.ascontiguousarray(vectors, np.float32),
                  "ids": np.ascontiguousarray(ids, np.int64)}
        if tags is not None and np.any(np.asarray(tags)):
            arrays["tags"] = np.ascontiguousarray(tags, np.int64)
        return self._commit(arrays, {})

    def append_remove(self, ids: np.ndarray) -> str:
        """Commit one tombstone record (ids only; the LOG line carries
        ``"op": "remove"`` — insert lines stay untagged, keeping
        insert-only logs byte-identical to the pre-tombstone format)."""
        return self._commit(
            {"ids": np.ascontiguousarray(ids, np.int64)},
            {"op": "remove"})

    def append_tags(self, ids: np.ndarray, tags: np.ndarray) -> str:
        """Commit one tag-assignment record (``op: "tags"``): replay
        routes it through ``set_item_tags`` so metadata writes survive
        restart and compaction like inserts and removals do."""
        return self._commit(
            {"ids": np.ascontiguousarray(ids, np.int64),
             "tags": np.ascontiguousarray(tags, np.int64)},
            {"op": "tags"})

    def _commit(self, arrays: Dict[str, np.ndarray], extra: dict) -> str:
        self.ensure_writable()
        os.makedirs(self.dir, exist_ok=True)
        with open(os.path.join(self.dir, ".lock"), "w") as lk:
            fcntl.flock(lk, fcntl.LOCK_EX)
            self._heal_tail()
            try:
                size = os.path.getsize(self.log_path)
            except OSError:
                size = 0
            if self._count is None or size != self._log_size:
                # first append, or another writer grew the LOG since we
                # cached: rescan (the common single-writer path stays
                # one initial scan + O(1) per append)
                self._count = len(self._entries())
            seq = self._count + 1
            while True:   # crashed-append orphans may occupy the name;
                fname = f"d{seq:06d}.npz"   # O_EXCL claims atomically
                fpath = os.path.join(self.dir, fname)
                try:
                    os.close(os.open(
                        fpath, os.O_WRONLY | os.O_CREAT | os.O_EXCL))
                    break
                except FileExistsError:
                    seq += 1
            checksum = write_segment(fpath, arrays)
            # persist the record's DIRECTORY ENTRY before committing the
            # LOG line: fsyncing the file alone does not survive a power
            # loss, and a committed line pointing at a missing file
            # would turn every future replay into StoreCorruptionError
            _fsync_dir(self.dir)
            line = json.dumps(dict(
                {"file": fname, "checksum": checksum,
                 "n": int(arrays["ids"].shape[0]),
                 "t": time.time()}, **extra))
            with open(self.log_path, "a") as f:
                f.write(line + "\n")
                f.flush()
                os.fsync(f.fileno())
            self._count += 1
            self._log_size = os.path.getsize(self.log_path)
        return fname

    def replay(self, *, verify: bool = True, start: int = 0
               ) -> Iterator[Tuple[str, Optional[np.ndarray], np.ndarray,
                                   Optional[np.ndarray]]]:
        """Yield committed ``(op, vectors, ids, tags)`` records in
        append order — ``op`` is ``"insert"`` (vectors present),
        ``"remove"`` (tombstone, vectors ``None``) or ``"tags"`` (tag
        assignment: ids + tags, vectors ``None``); ``tags`` is ``None``
        for untagged inserts and removals. ``start`` skips the first
        ``start`` records (the compactor's catch-up reads only the tail
        appended after its fold snapshot)."""
        for entry in self._entries()[start:]:
            arrays = read_segment(
                os.path.join(self.dir, entry["file"]),
                entry["checksum"] if verify else "")
            op = entry.get("op", "insert")
            yield (op, arrays.get("vectors"), arrays["ids"],
                   arrays.get("tags"))

    def truncate(self) -> int:
        """Drop every committed record (the compactor calls this once
        the log's contents are folded into a *newer published version*
        — after that rename the records are dead weight: recovery loads
        the newer version, never this log). Removes the record files and
        empties ``LOG`` under the same advisory lock appends take.
        Returns the number of records dropped."""
        if not os.path.isdir(self.dir):
            return 0   # never appended to: nothing to drop
        with open(os.path.join(self.dir, ".lock"), "w") as lk:
            fcntl.flock(lk, fcntl.LOCK_EX)
            entries = self._entries()
            # empty LOG first: a crash mid-truncate must not leave
            # committed lines pointing at deleted record files
            with open(self.log_path, "w") as f:
                f.flush()
                os.fsync(f.fileno())
            for entry in entries:
                try:
                    os.remove(os.path.join(self.dir, entry["file"]))
                except OSError:
                    pass
            _fsync_dir(self.dir)
            self._count = 0
            self._log_size = 0
        return len(entries)


class StoreReader:
    """Lazy, checksum-verified view of ONE published version.

    Loads the manifest eagerly and segments on demand —
    :meth:`load_shard` reads exactly one ``.npz``, which is how an
    engine executor fetches only the shard it serves instead of paying
    for the whole index.
    """

    def __init__(self, version_dir: str, *, verify: bool = True):
        self.dir = version_dir
        self.verify = verify
        mpath = os.path.join(version_dir, _MANIFEST)
        try:
            with open(mpath) as f:
                self.manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise StoreError(
                f"unreadable manifest at {mpath}: {e!r}") from e

    @property
    def version(self) -> str:
        return self.manifest["version"]

    @property
    def num_shards(self) -> int:
        return len(self.manifest["shards"])

    @property
    def config(self) -> PyramidConfig:
        return PyramidConfig(**self.manifest["config"])

    @property
    def metric(self) -> str:
        return self.manifest["metric"]

    def _read(self, entry: dict) -> Dict[str, np.ndarray]:
        return read_segment(
            os.path.join(self.dir, entry["file"]),
            entry["checksum"] if self.verify else "")

    def load_meta(self) -> Tuple[H.HNSWGraph, np.ndarray]:
        arrays = self._read(self.manifest["meta"])
        part = arrays.pop("part_of_center")
        return (graph_from_arrays(arrays, self.metric),
                part.astype(np.int32))

    def load_shard(self, i: int) -> H.HNSWGraph:
        """Read one sub-HNSW segment (lazy: touches only its file)."""
        return graph_from_arrays(
            self._read(self.manifest["shards"][i]), self.metric)

    def delta_log(self) -> DeltaLog:
        return DeltaLog(os.path.join(self.dir, "delta"))


class IndexStore:
    """Versioned store for one dataset's Pyramid indexes."""

    # gc() sweeps .tmp-/.trash- orphans only once they are older than
    # this — a younger tmpdir may belong to a publish still in flight
    ORPHAN_GRACE_S = 3600.0

    def __init__(self, root: str):
        self.root = str(root)
        self.versions_dir = os.path.join(self.root, "versions")

    # -- version bookkeeping ----------------------------------------------

    def versions(self) -> List[str]:
        """Complete (manifest-bearing) versions, oldest first."""
        if not os.path.isdir(self.versions_dir):
            return []
        return sorted(
            v for v in os.listdir(self.versions_dir)
            if os.path.exists(
                os.path.join(self.versions_dir, v, _MANIFEST)))

    def latest(self) -> Optional[str]:
        """The published version id — newest-wins between a valid
        ``CURRENT`` and the newest complete version on disk. The rename
        that lands a version IS its commit point: a crash between the
        rename and the ``CURRENT`` flip (a normal publish, or the
        compactor dying between its publish/truncate and flip steps)
        must still recover to the newer version, or the compactor's
        already-truncated delta records would be lost. ``_set_current``
        is newest-wins too, so ``CURRENT`` never legitimately points
        behind the newest complete version."""
        cur = None
        try:
            with open(os.path.join(self.root, _CURRENT)) as f:
                vid = f.read().strip()
            if vid and os.path.exists(
                    os.path.join(self.versions_dir, vid, _MANIFEST)):
                cur = vid
        except OSError:
            pass
        vs = self.versions()
        newest = vs[-1] if vs else None
        if self._vnum(newest) > self._vnum(cur):
            return newest
        return cur

    def version_dir(self, vid: str) -> str:
        return os.path.join(self.versions_dir, vid)

    def version_bytes(self, vid: str) -> int:
        total = 0
        for base, _, files in os.walk(self.version_dir(vid)):
            total += sum(
                os.path.getsize(os.path.join(base, f)) for f in files)
        return total

    # -- publish -----------------------------------------------------------

    def publish(self, index: PyramidIndex, *,
                keep: Optional[int] = None,
                set_current: bool = True) -> str:
        """Write ``index`` as a new version and flip ``CURRENT`` to it.

        Returns the version id. The index object is attached to the new
        version's (empty) delta log, so subsequent ``add_items`` calls
        are journaled against what was just published. ``keep`` runs
        :meth:`gc` afterwards. ``set_current=False`` skips the
        ``CURRENT`` flip (the compactor sequences truncate between the
        rename and the flip; the rename alone already commits — see
        :meth:`latest`).
        """
        os.makedirs(self.versions_dir, exist_ok=True)
        tmp = os.path.join(self.root, f".tmp-{uuid.uuid4().hex[:12]}")
        os.makedirs(tmp)
        try:
            meta_arrays = graph_to_arrays(index.meta)
            meta_arrays["part_of_center"] = np.ascontiguousarray(
                index.part_of_center, np.int32)
            meta_entry = {
                "file": _META_SEG,
                "checksum": write_segment(
                    os.path.join(tmp, _META_SEG), meta_arrays),
                "n": index.meta.n,
            }
            shard_entries = []
            for i, g in enumerate(index.subs):
                fname = f"shard-{i:04d}.npz"
                checksum = write_segment(
                    os.path.join(tmp, fname), graph_to_arrays(g))
                shard_entries.append(
                    {"file": fname, "checksum": checksum, "n": g.n})
            os.makedirs(os.path.join(tmp, "delta"))
            metric = ("ip" if index.config.is_mips
                      else index.config.metric)
            manifest = {
                "format_version": FORMAT_VERSION,
                "created_at": time.time(),
                "config": _jsonable(dataclasses.asdict(index.config)),
                "metric": metric,
                "build_stats": _jsonable(index.build_stats),
                "meta": meta_entry,
                "shards": shard_entries,
            }
            # persist the frozen int8 grid on every publish of a
            # non-empty index: a reopened index must requantize on the
            # IDENTICAL grid (not re-derive one from post-replay data),
            # or its codes would drift from the pre-restart engine's.
            # Deriving here also freezes the live index's grid at
            # publish time, so an engine that turns quantize=True on
            # later (pre- or post-crash) lands on the same grid as its
            # recovery path. Cost: one min/max pass over data publish
            # already reads in full for checksums. An all-empty index
            # (every shard zero items) has nothing to quantize — skip
            # rather than fail the publish.
            if any(g.n for g in index.subs):
                manifest["quant"] = index.quant_params().to_manifest()
            # segment dir entries must be durable BEFORE the rename
            # makes the version discoverable (a complete-looking
            # manifest must never reference files lost to power loss)
            _fsync_dir(tmp)
            # claim a version id with the rename itself: a concurrent
            # publisher that wins the id makes our rename fail, and we
            # retry with the next one — both publishes land, atomically
            for _ in range(10_000):
                vs = self.versions()
                nxt = 1 + max(
                    (int(v[1:]) for v in vs
                     if v.startswith("v") and v[1:].isdigit()),
                    default=0)
                vid = f"v{nxt:07d}"
                manifest["version"] = vid
                with open(os.path.join(tmp, _MANIFEST), "w") as f:
                    json.dump(manifest, f, indent=1, sort_keys=True)
                    f.flush()
                    os.fsync(f.fileno())
                try:
                    os.rename(tmp, self.version_dir(vid))
                    break
                except OSError as e:
                    # only an id collision is retryable; a permission /
                    # quota / IO failure would spin the full retry
                    # budget and then hide the real errno
                    if e.errno not in (errno.EEXIST, errno.ENOTEMPTY):
                        raise
                    continue   # id already claimed: recompute and retry
            else:
                raise StoreError(
                    f"could not claim a version id under "
                    f"{self.versions_dir}")
            _fsync_dir(self.versions_dir)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        if set_current:
            self._set_current(vid)
        index.attach_delta_log(
            DeltaLog(os.path.join(self.version_dir(vid), "delta")))
        if keep is not None:
            self.gc(keep=keep)
        return vid

    @staticmethod
    def _vnum(vid: Optional[str]) -> int:
        if vid and vid.startswith("v") and vid[1:].isdigit():
            return int(vid[1:])
        return -1

    def set_current(self, vid: str) -> None:
        """Publicly flip ``CURRENT`` (newest-wins; see
        :meth:`_set_current`) — the compactor's final metadata step
        after publishing with ``set_current=False`` and truncating."""
        self._set_current(vid)

    def _set_current(self, vid: str) -> None:
        """Flip ``CURRENT`` to ``vid`` — newest-wins under an advisory
        lock: a descheduled publisher resuming late must not flip
        ``CURRENT`` back onto its (older) version after a newer publish
        already landed (the classic lost-update)."""
        with open(os.path.join(self.root, ".current.lock"), "w") as lk:
            fcntl.flock(lk, fcntl.LOCK_EX)
            try:
                with open(os.path.join(self.root, _CURRENT)) as f:
                    cur = f.read().strip()
            except OSError:
                cur = None
            if self._vnum(cur) >= self._vnum(vid):
                return   # a newer (or same) publish already flipped it
            tmp = os.path.join(self.root,
                               f".{_CURRENT}.{uuid.uuid4().hex[:8]}")
            with open(tmp, "w") as f:
                f.write(vid + "\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, os.path.join(self.root, _CURRENT))
            _fsync_dir(self.root)

    # -- load --------------------------------------------------------------

    def reader(self, version: Optional[str] = None, *,
               verify: bool = True) -> StoreReader:
        vid = version or self.latest()
        if vid is None:
            raise StoreError(
                f"no published index versions under {self.root}")
        return StoreReader(self.version_dir(vid), verify=verify)

    def load(self, version: Optional[str] = None, *, verify: bool = True,
             replay_delta: bool = True,
             attach_delta: bool = True) -> PyramidIndex:
        """Materialise a full :class:`PyramidIndex` from a version.

        Checksums are verified (``verify=False`` skips), the version's
        delta log is replayed in journal order through
        ``add_items``/``remove_items`` (same rebuild path, same
        ``shard_seed`` — bit-identical to the pre-restart index, and
        tombstones guarantee deleted vectors stay deleted), and the
        index is attached to that log so further updates keep
        journaling.
        """
        reader = self.reader(version, verify=verify)
        meta, part_of_center = reader.load_meta()
        subs = [reader.load_shard(i) for i in range(reader.num_shards)]
        index = PyramidIndex(
            config=reader.config, meta=meta,
            part_of_center=part_of_center, subs=subs,
            build_stats=dict(reader.manifest.get("build_stats", {})))
        if "quant" in reader.manifest:
            # attach BEFORE delta replay: replayed inserts requantize
            # through the same frozen grid as the live engine did, so
            # the rebuilt int8 arena is bit-identical to the pre-crash
            # one (tests/test_quant.py asserts the codes)
            from repro.core.quant import QuantParams
            index.attach_quant_params(
                QuantParams.from_manifest(reader.manifest["quant"]))
        delta = reader.delta_log()
        if replay_delta:
            from repro.core.updates import (add_items, remove_items,
                                            set_item_tags)
            for op, vectors, ids, tags in delta.replay(verify=verify):
                if op == "remove":
                    remove_items(index, ids, log_delta=False)
                elif op == "tags":
                    set_item_tags(index, ids, tags, log_delta=False)
                else:
                    add_items(index, vectors, ids, tags=tags,
                              log_delta=False)
        if attach_delta:
            index.attach_delta_log(delta)
        return index

    # -- GC ----------------------------------------------------------------

    def gc(self, keep: int = 2) -> List[str]:
        """Delete superseded versions, keeping the newest ``keep`` plus
        whatever ``CURRENT`` points at; also sweeps ``.tmp-`` orphans
        from crashed publishes. Returns the removed version ids."""
        if keep < 1:
            raise ValueError(f"gc keep must be >= 1, got {keep}")
        vs = self.versions()
        protect = set(vs[-keep:])
        cur = self.latest()
        if cur is not None:
            protect.add(cur)
        removed = []
        for vid in vs:
            if vid in protect:
                continue
            # rename-then-delete: the version disappears atomically, so
            # a concurrent reader either opened it in time or never sees
            # a half-deleted directory
            trash = os.path.join(
                self.root, f".trash-{vid}-{uuid.uuid4().hex[:8]}")
            try:
                os.rename(self.version_dir(vid), trash)
            except OSError:
                continue   # raced another GC
            shutil.rmtree(trash, ignore_errors=True)
            removed.append(vid)
        # sweep crash orphans — but only STALE ones: a fresh .tmp- dir
        # may be a concurrent publisher still writing its segments (and
        # a fresh .CURRENT.* a flip about to happen); deleting either
        # out from under its owner would fail their publish
        now = time.time()
        for name in os.listdir(self.root):
            if not name.startswith((".tmp-", ".trash-", f".{_CURRENT}.")):
                continue
            path = os.path.join(self.root, name)
            try:
                age = now - os.path.getmtime(path)
            except OSError:
                continue   # already gone (raced its owner or another GC)
            if age > self.ORPHAN_GRACE_S:
                if os.path.isdir(path):
                    shutil.rmtree(path, ignore_errors=True)
                else:
                    try:
                        os.remove(path)
                    except OSError:
                        pass
        return removed

    # -- misc --------------------------------------------------------------

    def exists(self) -> bool:
        return bool(self.versions())

    def __repr__(self) -> str:
        return (f"IndexStore({self.root!r}, versions={self.versions()}, "
                f"current={self.latest()!r})")
