"""Version-compat shims for the jax API surface this repo uses.

The container pins an older jax than some call sites were written
against; importing through here keeps the version juggling in one
place.

  * ``shard_map`` moved from ``jax.experimental.shard_map`` to the top
    level in jax 0.5, and its replication-check kwarg was renamed
    ``check_rep`` -> ``check_vma``. Call sites use the new spelling.
  * pallas-TPU compiler params were renamed ``TPUCompilerParams`` ->
    ``CompilerParams``; kernels import ``CompilerParams`` from here.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.5 exports shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams",
                         getattr(_pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # pragma: no cover - future jax renames
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; extend repro.common.jax_compat for this jax")

if "check_vma" in inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:
    def shard_map(*args, check_vma=None, **kwargs):
        """Accepts the jax >= 0.5 kwarg name on older jax."""
        if check_vma is not None:
            kwargs["check_rep"] = check_vma
        return _shard_map(*args, **kwargs)
