"""Architecture registry.

``src/repro/configs/<arch>.py`` modules register themselves at import; the
registry lazily imports the configs package on first lookup so that
``get_arch("qwen3-1.7b")`` works from anywhere.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.common.config import ArchConfig

_REGISTRY: Dict[str, ArchConfig] = {}
_LOADED = False


def register_arch(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch registration: {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def _ensure_loaded() -> None:
    global _LOADED
    if not _LOADED:
        importlib.import_module("repro.configs")
        _LOADED = True


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)
