"""Common substrate: configs, registry, sharding helpers."""
from repro.common.config import (
    ArchConfig,
    AttentionKind,
    BlockKind,
    InputShape,
    MoEConfig,
    PyramidConfig,
    SSMConfig,
    INPUT_SHAPES,
)
from repro.common.registry import get_arch, list_archs, register_arch

__all__ = [
    "ArchConfig",
    "AttentionKind",
    "BlockKind",
    "InputShape",
    "MoEConfig",
    "PyramidConfig",
    "SSMConfig",
    "INPUT_SHAPES",
    "get_arch",
    "list_archs",
    "register_arch",
]
