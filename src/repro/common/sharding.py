"""Sharding helpers shared by train/serve/dry-run.

Axis conventions (see DESIGN.md §6):
  data  — batch / FSDP axis (16 per pod)
  model — tensor / expert / shard axis (16)
  pod   — optional leading data-parallel axis across pods (2)
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DATA_AXIS = "data"
MODEL_AXIS = "model"
POD_AXIS = "pod"


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch is sharded over (pod+data when multi-pod)."""
    if POD_AXIS in mesh.axis_names:
        return (POD_AXIS, DATA_AXIS)
    return (DATA_AXIS,)


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes parameters are FSDP-sharded over (same as batch axes)."""
    return batch_axes(mesh)


def ns(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def data_sharding(mesh: Mesh, rank: int) -> NamedSharding:
    """Shard leading (batch) dim over the batch axes, replicate the rest."""
    spec = [batch_axes(mesh)] + [None] * (rank - 1)
    return ns(mesh, *spec)


def replicated(mesh: Mesh) -> NamedSharding:
    return ns(mesh)


def logical_to_sharding(mesh: Mesh, logical: Sequence[Optional[str]]) -> NamedSharding:
    """Map logical axis names to mesh axes.

    Logical names:
      'batch'   -> (pod, data)
      'fsdp'    -> (pod, data)   (parameter shard dim)
      'model'   -> model         (tensor-parallel dim)
      'expert'  -> model         (expert-parallel dim)
      'shard'   -> model         (Pyramid sub-HNSW dim)
      None      -> replicated dim
    """
    out = []
    for name in logical:
        if name is None:
            out.append(None)
        elif name in ("batch", "fsdp"):
            ax = batch_axes(mesh)
            out.append(ax if len(ax) > 1 else ax[0])
        elif name in ("model", "expert", "shard"):
            out.append(MODEL_AXIS)
        else:
            raise ValueError(f"unknown logical axis {name!r}")
    return ns(mesh, *out)


def logical_to_sharding_shaped(mesh: Mesh, logical: Sequence[Optional[str]],
                               shape: Sequence[int]) -> NamedSharding:
    """Like ``logical_to_sharding`` but shape-aware:

    * drops the sharding of any dim whose size does not divide its mesh
      axes (pjit rejects uneven shardings; e.g. vocab 50280 over 16);
    * resolves the special 'moe_ff' logical axis: model axis iff the
      'expert' dim was dropped (expert count < model axis, e.g. grok 8e),
      so tensor parallelism moves from the expert dim to d_ff.
    """
    expert_dropped = False
    fixed = []
    moe_ff_dims = []
    for i, (dim, name) in enumerate(
            zip(shape, list(logical) + [None] * (len(shape) - len(logical)))):
        if name == "moe_ff":
            moe_ff_dims.append(i)
            fixed.append(None)
            continue
        if name is None:
            fixed.append(None)
            continue
        single = logical_to_sharding(mesh, (name,)).spec[0]
        axes = single if isinstance(single, tuple) else (single,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if dim % n == 0:
            fixed.append(single)
        else:
            fixed.append(None)
            if name == "expert":
                expert_dropped = True
    for i in moe_ff_dims:
        if expert_dropped and shape[i] % mesh.shape[MODEL_AXIS] == 0:
            fixed[i] = MODEL_AXIS
    return ns(mesh, *fixed)


def count_devices(mesh: Mesh) -> int:
    return mesh.devices.size
