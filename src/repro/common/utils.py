"""Small shared utilities."""
from __future__ import annotations

import math
import time
from contextlib import contextmanager
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


def tree_size_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_param_count(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def assert_no_nans(tree, where: str = "") -> None:
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating) and not np.all(np.isfinite(arr)):
            raise AssertionError(f"non-finite values at {where}{jax.tree_util.keystr(path)}")


@contextmanager
def timed(label: str, sink=None) -> Iterator[None]:
    t0 = time.perf_counter()
    yield
    dt = time.perf_counter() - t0
    msg = f"[timed] {label}: {dt*1e3:.2f} ms"
    (sink or print)(msg)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def l2_normalize(x: jnp.ndarray, axis: int = -1, eps: float = 1e-12) -> jnp.ndarray:
    return x / (jnp.linalg.norm(x, axis=axis, keepdims=True) + eps)


def nearest_rank(sorted_xs, q: float) -> float:
    """q-th percentile (0..100) of an already-sorted sample, nearest-rank
    (index ``ceil(q/100 * n) - 1``, so q=50 over [a, b] reports ``a``) —
    the ONE quantile definition shared by the serving engine's hedge
    deadlines (``LatencyTracker``) and the benchmark latency reports, so
    the two never silently diverge."""
    n = len(sorted_xs)
    return sorted_xs[max(0, min(n - 1, math.ceil(q / 100.0 * n) - 1))]
