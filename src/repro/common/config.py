"""Configuration dataclasses for the repro framework.

Everything is a frozen dataclass so configs hash and can key jit caches.
Architecture configs describe the transformer (or SSM) backbone exactly as
assigned; ``reduced()`` derives the CPU smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class BlockKind(str, enum.Enum):
    """Layer-block kinds a model stack can interleave."""

    ATTENTION = "attention"
    MAMBA2 = "mamba2"
    SHARED_ATTENTION = "shared_attention"  # zamba2: weight-tied attention block


class AttentionKind(str, enum.Enum):
    FULL = "full"
    SLIDING = "sliding"           # sliding-window attention (SWA)
    LOCAL_GLOBAL = "local_global"  # gemma3: ratio of local SWA to global layers


class RoPEKind(str, enum.Enum):
    NONE = "none"
    STANDARD = "standard"
    TWO_D = "2d"  # chatglm3: rotary applied to half the head dim


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    # capacity factor for dense one-hot dispatch; tokens beyond capacity drop
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""

    state_dim: int = 128          # N: per-head SSM state size
    head_dim: int = 64            # P: channels per SSM head
    expand: int = 2               # d_inner = expand * d_model
    chunk_size: int = 256         # SSD chunk length (matmul-friendly)
    conv_width: int = 4           # causal depthwise conv width


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture.

    ``block_pattern`` describes one period of the layer stack; it is tiled to
    ``num_layers``. Dense models are just ``(ATTENTION,)``.
    """

    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    block_pattern: Tuple[BlockKind, ...] = (BlockKind.ATTENTION,)
    attention_kind: AttentionKind = AttentionKind.FULL
    sliding_window: int = 4096              # for SWA kinds
    local_to_global_ratio: int = 0          # gemma3: 5 local per 1 global
    rope: RoPEKind = RoPEKind.STANDARD
    rope_theta: float = 10_000.0
    qk_norm: bool = False                   # qwen3
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    tie_embeddings: bool = False
    # Modality frontend stub: if set, inputs are precomputed embeddings of
    # shape [batch, seq, frontend_dim] instead of token ids.
    frontend: Optional[str] = None          # None | "vision" | "audio"
    frontend_dim: int = 0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    source: str = ""                        # citation bracket from assignment

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        if self.num_heads == 0:
            return 0
        return self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return all(b == BlockKind.MAMBA2 for b in self.block_pattern)

    @property
    def supports_long_context(self) -> bool:
        """True if decode over a 512k cache is sub-quadratic / windowed."""
        if any(b == BlockKind.MAMBA2 for b in self.block_pattern):
            return True
        return self.attention_kind in (AttentionKind.SLIDING, AttentionKind.LOCAL_GLOBAL)

    def layer_kinds(self) -> Tuple[BlockKind, ...]:
        """The full, tiled layer stack (length == num_layers)."""
        reps = -(-self.num_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.num_layers]

    def reduced(self) -> "ArchConfig":
        """A tiny same-family variant for CPU smoke tests."""
        d_model = min(self.d_model, 128)
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = max(1, min(self.num_kv_heads, heads)) if heads else 0
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=min(4, self.moe.num_experts),
                experts_per_token=min(2, self.moe.experts_per_token))
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(
                self.ssm, state_dim=16, head_dim=16, chunk_size=32)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=(d_model // heads) if heads else None,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64),
            moe=moe,
            ssm=ssm,
            frontend_dim=min(self.frontend_dim, 64) if self.frontend else 0,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # token embedding
        if not self.tie_embeddings:
            total += v * d  # lm head
        hd = self.resolved_head_dim
        for kind in self.layer_kinds():
            if kind in (BlockKind.ATTENTION, BlockKind.SHARED_ATTENTION):
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                attn = q + kv + o
                if self.moe is not None:
                    mlp = self.moe.num_experts * 3 * d * self.d_ff
                    mlp += d * self.moe.num_experts  # router
                else:
                    mlp = 3 * d * self.d_ff
                total += attn + mlp + 2 * d  # two RMSNorm scales
            elif kind == BlockKind.MAMBA2:
                s = self.ssm or SSMConfig()
                d_in = s.expand * d
                nheads = d_in // s.head_dim
                total += d * (2 * d_in + 2 * nheads * s.state_dim)  # in_proj-ish
                total += d_in * d  # out_proj
                total += 2 * nheads + d  # A, dt bias, norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        dead_experts = self.moe.num_experts - self.moe.experts_per_token
        per_layer_dead = dead_experts * 3 * d * self.d_ff
        n_moe_layers = sum(
            1 for k in self.layer_kinds()
            if k in (BlockKind.ATTENTION, BlockKind.SHARED_ATTENTION))
        return full - n_moe_layers * per_layer_dead


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class PyramidConfig:
    """Configuration of the paper's index (Alg. 3 / Alg. 5)."""

    metric: str = "l2"            # l2 | ip | angular
    num_shards: int = 16          # w: number of sub-HNSWs
    meta_size: int = 1_000        # m: k-means centers / meta-HNSW vertices
    sample_size: int = 20_000     # n': sample for k-means
    branching_factor: int = 4     # K: meta neighbours used for routing
    # HNSW parameters (paper defaults: M=32 bottom, 16 upper, ef=100)
    max_degree: int = 32
    max_degree_upper: int = 16
    ef_construction: int = 100
    ef_search: int = 100
    # MIPS norm-replication (Alg. 5)
    replication_r: int = 0        # r: top-r MIPS neighbours per meta vertex
    # capacity factor for distributed dispatch (queries per shard slot)
    capacity_factor: float = 2.0
    kmeans_iters: int = 12
    seed: int = 0

    @property
    def is_mips(self) -> bool:
        return self.metric == "ip"
