"""Streaming retrieval-decode engine: prefill / insert / generate_step
serving over the Pyramid search engine (JetStream-style).

This is the ROADMAP's "millions of users" workload made concrete: a
continuous-batching LM decode loop in which EVERY decode step is a
batched similarity query — kNN-LM (Khandelwal et al., the paper's
citation [10]) over a Pyramid-sharded datastore of (hidden state ->
next token) memories. The engine composes five PRs of machinery rather
than re-implementing any of it:

  * lookups go through :class:`~repro.core.client.PyramidClient`
    futures against a :class:`~repro.serving.engine.ServingEngine`
    (int8 ``QuantizedShardArena`` when the datastore client is opened
    with ``quantize=True``), so hedging, supervised recovery, and the
    exact-rerank path all run under sustained decode traffic;
  * slot scheduling generalises :class:`~repro.serving.batcher.
    ContinuousBatcher` (whose cache-scatter helper it shares);
  * sampling reuses :mod:`repro.serving.sampler` (numpy twin).

API (explicit, JetStream-shaped)::

    with StreamEngine(params, cfg, datastore=ds, num_slots=8,
                      max_seq=64) as eng:
        sess = eng.prefill(Request(0, prompt, max_new_tokens=16))
        eng.insert(sess)                  # queued; admitted into a slot
        while ...:
            emitted = eng.generate_step() # [(request_id, token), ...]
        done = eng.done                   # Completion records

Retrieval/decode overlap (``overlap=True``, the default) is
double-buffered across two slot *groups*: while group A's decode step
runs on the device, group B's ``SearchFuture``s resolve inside the
search engine's executor threads, and vice versa — a group's lookups
have one full counter-group turn to complete before its sampler needs
them. Per-session semantics are EXACT kNN-LM either way: a session
lives in one group, and its own timeline is always
``forward -> retrieve -> interpolate -> sample``; ``overlap=False``
(the serialized baseline the benchmark compares against) awaits each
step's futures immediately and produces bit-identical tokens, just
without hiding the retrieval latency.

Backpressure: admission is bounded (``max_queue``; :class:`
BackpressureError` on overflow) and decode can never run ahead of the
search engine by more than one step per group — the sampler blocks on
``gather_arrays`` (bounded by ``retrieval_timeout_s``) before the next
dispatch, so a lagging engine throttles token emission instead of
accumulating unresolved futures.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.common.config import ArchConfig
from repro.common.utils import nearest_rank
from repro.core.client import PyramidClient, gather_arrays
from repro.obs import NULL_TRACER, MetricsRegistry
from repro.models.transformer import forward, grow_cache, make_cache
from repro.serving.batcher import Completion, Request, scatter_slot
from repro.serving.retrieval import (Datastore, interpolate,
                                     knn_vocab_probs,
                                     open_datastore_client)
from repro.serving.sampler import SamplerConfig, sample_np

import jax


class BackpressureError(RuntimeError):
    """``insert`` refused a session: the admission queue is full
    (``max_queue``). Callers should back off and retry — completing
    sessions free queue capacity every ``generate_step``."""


# one jitted decode step per ArchConfig: every StreamEngine over the
# same config shares the compile (jit re-specialises per batch width
# automatically, so engines with different group sizes still share the
# function). Keyed by id() with the config kept alive in the value so a
# recycled id can never alias a different config.
_DECODE_JIT: Dict[int, Tuple[ArchConfig, object]] = {}


def _decode_fn(cfg: ArchConfig):
    hit = _DECODE_JIT.get(id(cfg))
    if hit is not None:
        return hit[1]

    def step(params, cache, tokens, pos):
        # one trunk pass yields BOTH the kNN-LM query key (the normed
        # hidden state, via skip_head) and the LM logits (head applied
        # explicitly) — no second forward to drift out of sync
        hid, _, new_cache = forward(params, cfg, tokens, cache=cache,
                                    decode_pos=pos, skip_head=True)
        h = hid[:, 0].astype(jnp.float32)
        if cfg.tie_embeddings:
            logits = h @ params["embedding"].astype(jnp.float32).T
        else:
            logits = h @ params["lm_head"].astype(jnp.float32)
        return logits, h, new_cache

    fn = jax.jit(step)
    _DECODE_JIT[id(cfg)] = (cfg, fn)
    return fn


@dataclasses.dataclass
class Session:
    """One request's lifecycle through the engine:
    ``prefilled -> queued -> active -> done``. Created by
    :meth:`StreamEngine.prefill`, which stores the prompt's grown cache
    plus the last prompt position's LM logits and hidden state (the
    first token's interpolation inputs)."""
    request: Request
    lm_logits: Optional[np.ndarray] = None     # [V] last-prompt-pos
    hidden: Optional[np.ndarray] = None        # [D] kNN query key
    pcache: Optional[object] = None            # grown prefill cache
    future: Optional[object] = None            # first-token SearchFuture
    submitted_at: float = 0.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    state: str = "prefilled"

    @property
    def request_id(self) -> int:
        return self.request.request_id


@dataclasses.dataclass
class _Inflight:
    """One dispatched decode step awaiting its sample phase."""
    logits: np.ndarray            # [L, V] live-slot LM logits
    slots: List[int]              # live slot index per row
    futures: Optional[List]       # per-row SearchFutures (None: LM-only)
    submitted_at: float


class _SlotGroup:
    """One of the engine's two decode microbatches (static shapes =>
    one compiled decode step per group width)."""

    def __init__(self, cfg: ArchConfig, slots: int, max_seq: int):
        self.cache = make_cache(cfg, slots, max_seq)
        self.pos = np.zeros(slots, np.int64)       # next write position
        self.last = np.zeros(slots, np.int64)      # last sampled token
        self.sessions: List[Optional[Session]] = [None] * slots
        self.inflight: Optional[_Inflight] = None


class StreamEngine:
    """Continuous-batching retrieval-augmented decode over a Pyramid
    datastore (or plain LM decode with ``datastore=None``).

    Parameters
    ----------
    num_slots : total decode slots, split over two double-buffer groups
        (rounded up to even). More slots = more concurrent sessions per
        decode step.
    datastore / client : a kNN-LM :class:`Datastore` and (optionally) an
        already-open :class:`PyramidClient` session serving its index.
        Without ``client`` the engine opens one itself (engine kwargs
        pass through — ``quantize=True, rerank_factor=4`` serves the
        int8 arena) and shuts it down on :meth:`close`.
    knn_k / lam / knn_temperature / branching_factor : kNN-LM knobs —
        neighbours per lookup, interpolation weight, kNN softmax
        temperature, and the Pyramid routing fan-out.
    overlap : double-buffer retrieval behind the counter-group's decode
        step (default). ``False`` = serialized await-every-step baseline
        (identical tokens, no latency hiding).
    max_queue / retrieval_timeout_s : backpressure knobs — admission
        bound (``insert`` raises :class:`BackpressureError` beyond it)
        and the per-step bound on waiting for the search engine.
    """

    def __init__(self, params, cfg: ArchConfig, *, num_slots: int = 8,
                 max_seq: int = 64,
                 datastore: Optional[Datastore] = None,
                 client: Optional[PyramidClient] = None,
                 knn_k: int = 8, lam: float = 0.25,
                 knn_temperature: float = 10.0,
                 branching_factor: Optional[int] = None,
                 sampler: SamplerConfig = SamplerConfig(greedy=True),
                 seed: int = 0, overlap: bool = True,
                 max_queue: int = 64, retrieval_timeout_s: float = 30.0,
                 stats_window: int = 4096,
                 registry: Optional[MetricsRegistry] = None,
                 tracer=None, **engine_kw):
        if datastore is None and client is not None:
            raise ValueError("client= needs the datastore= it serves")
        self.params = params
        self.cfg = cfg
        self.max_seq = max_seq
        self.datastore = datastore
        self.knn_k = knn_k
        self.lam = lam
        self.knn_temperature = knn_temperature
        self.branching_factor = branching_factor
        self.sampler = sampler
        self.overlap = overlap
        self.max_queue = max_queue
        self.retrieval_timeout_s = retrieval_timeout_s

        # shared observability plane: the owned datastore client's
        # serving engine joins this registry/tracer (unless engine_kw
        # overrides), so one scrape / one trace covers decode steps AND
        # the shard searches they fan out to
        self.obs = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER

        self._owns_client = False
        self._client = client
        if datastore is not None and client is None:
            engine_kw.setdefault("registry", self.obs)
            engine_kw.setdefault("tracer", self.tracer)
            self._client = open_datastore_client(datastore, **engine_kw)
            self._owns_client = True
        elif engine_kw:
            raise ValueError(
                f"engine kwargs {sorted(engine_kw)} only apply when the "
                "engine opens its own datastore client")

        self.slots_per_group = max(1, (num_slots + 1) // 2)
        self.num_slots = 2 * self.slots_per_group
        self.groups = [_SlotGroup(cfg, self.slots_per_group, max_seq)
                       for _ in range(2)]
        self._turn = 0
        self._decode = _decode_fn(cfg)
        self._rng = np.random.default_rng(seed)

        self.queue: collections.deque = collections.deque()
        self.done: List[Completion] = []
        self._closed = False
        self._t0: Optional[float] = None
        # counter-backed bookkeeping (same objects /metrics renders, so
        # the Prometheus endpoint and stats() can never disagree); the
        # deques stay for exact windowed percentiles
        m = self.obs
        self._m_steps = m.counter(
            "pyramid_stream_steps_total", "decode steps dispatched")
        self._m_tokens = m.counter(
            "pyramid_stream_tokens_total", "tokens emitted")
        self._m_admitted = m.counter(
            "pyramid_stream_admitted_total", "sessions admitted to slots")
        self._m_rejected = m.counter(
            "pyramid_stream_rejected_total",
            "sessions refused by backpressure")
        self._m_lookups = m.counter(
            "pyramid_stream_lookups_total", "kNN lookups resolved")
        self._m_knn_hits = m.counter(
            "pyramid_stream_knn_hits_total",
            "tokens whose retrieved memories contained them")
        self._m_knn_tokens = m.counter(
            "pyramid_stream_knn_tokens_total",
            "tokens scored against retrieved memories")
        self._m_hedges = m.counter(
            "pyramid_stream_hedges_total",
            "hedge re-dispatches observed on resolved lookups")
        self._h_ret_wait = m.histogram(
            "pyramid_stream_retrieval_wait_seconds",
            "sampler block time per resolve (non-overlapped remainder)")
        self._h_ret_lat = m.histogram(
            "pyramid_stream_retrieval_latency_seconds",
            "lookup submit-to-resolve latency")
        m.gauge("pyramid_stream_queued_sessions", "admission queue depth",
                fn=lambda: len(self.queue))
        m.gauge("pyramid_stream_active_sessions", "occupied decode slots",
                fn=lambda: sum(s is not None for grp in self.groups
                               for s in grp.sessions))
        self._ret_wait = collections.deque(maxlen=stats_window)
        self._ret_lat = collections.deque(maxlen=stats_window)

    # -- lifecycle ---------------------------------------------------------

    @property
    def client(self) -> Optional[PyramidClient]:
        return self._client

    def close(self) -> None:
        """Tear down the engine; shuts down the datastore client's
        serving engine iff this engine opened it."""
        if self._closed:
            return
        self._closed = True
        if self._owns_client and self._client is not None:
            self._client.shutdown()

    def __enter__(self) -> "StreamEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- prefill / insert --------------------------------------------------

    def prefill(self, request: Request) -> Session:
        """Run the prompt through the model (batch=1, un-jitted — prompt
        lengths vary); returns a ``prefilled`` :class:`Session` holding
        the grown cache and the first token's interpolation inputs. The
        session is NOT serving yet — :meth:`insert` it."""
        prompt = np.asarray(request.prompt)
        if len(prompt) >= self.max_seq:
            raise ValueError(
                f"prompt length {len(prompt)} >= max_seq {self.max_seq}")
        with self.tracer.span("stream.prefill",
                              request_id=request.request_id,
                              prompt_len=len(prompt)):
            toks = jnp.asarray(prompt[None, :], jnp.int32)
            hid, _, pcache = forward(self.params, self.cfg, toks,
                                     build_cache=True, skip_head=True)
        pcache = grow_cache(pcache, self.max_seq,
                            window=self.cfg.sliding_window)
        h = hid[:, -1].astype(jnp.float32)
        if self.cfg.tie_embeddings:     # same head application as the
            logits = h @ self.params["embedding"].astype(jnp.float32).T
        else:                           # jitted decode step
            logits = h @ self.params["lm_head"].astype(jnp.float32)
        return Session(request=request,
                       lm_logits=np.asarray(logits[0]),
                       hidden=np.asarray(h[0], np.float32),
                       pcache=pcache)

    def insert(self, session: Session) -> None:
        """Queue a prefilled session for slot admission. Issues its
        first-token kNN lookup immediately, so the retrieval overlaps
        the queue wait. Raises :class:`BackpressureError` when the
        admission queue is at ``max_queue``."""
        if session.state != "prefilled":
            raise ValueError(f"session {session.request_id} is "
                             f"{session.state}, expected 'prefilled'")
        if len(self.queue) >= self.max_queue:
            self._m_rejected.inc()
            raise BackpressureError(
                f"admission queue full ({self.max_queue}); retry after "
                "generate_step frees capacity")
        if self._client is not None:
            session.future = self._client.search(
                session.hidden, self.knn_k,
                branching_factor=self.branching_factor)
            session.submitted_at = time.monotonic()
        session.state = "queued"
        self.queue.append(session)

    def submit(self, request: Request) -> Session:
        """Convenience: ``insert(prefill(request))``."""
        sess = self.prefill(request)
        self.insert(sess)
        return sess

    # -- decode loop -------------------------------------------------------

    def generate_step(self) -> List[Tuple[int, int]]:
        """One scheduler turn: finish the turn group's previous decode
        step (resolve retrieval, interpolate, sample, evict), admit
        queued sessions into freed slots, dispatch the group's next
        decode step and its batched kNN lookup. Returns the
        ``(request_id, token)`` pairs emitted this turn.

        With ``overlap=True`` the dispatched step is left in flight —
        its futures resolve while the OTHER group takes its turn; with
        ``overlap=False`` it is finished (awaited) before returning.
        """
        if self._t0 is None:
            self._t0 = time.monotonic()
        g = self.groups[self._turn]
        with self.tracer.span("stream.generate_step",
                              group=self._turn) as step_span:
            self._turn = 1 - self._turn
            emitted: List[Tuple[int, int]] = []
            self._finish(g, emitted)
            self._admit(g, emitted)
            self._dispatch(g)
            if not self.overlap:
                self._finish(g, emitted)
            step_span.set(emitted=len(emitted))
        return emitted

    def has_work(self) -> bool:
        return bool(self.queue
                    or any(s is not None for grp in self.groups
                           for s in grp.sessions)
                    or any(grp.inflight is not None
                           for grp in self.groups))

    def run_until_drained(self, max_steps: int = 100_000
                          ) -> List[Completion]:
        steps = 0
        while self.has_work() and steps < max_steps:
            self.generate_step()
            steps += 1
        return self.done

    # -- internals ---------------------------------------------------------

    def _knn_logprobs(self, lm_logits: np.ndarray, ids: np.ndarray,
                      scores: np.ndarray) -> np.ndarray:
        knn = knn_vocab_probs(self.datastore.values, ids, scores,
                              vocab_size=self.cfg.vocab_size,
                              temperature=self.knn_temperature)
        return interpolate(lm_logits, knn, lam=self.lam)

    def _count_hits(self, ids: np.ndarray, toks: np.ndarray) -> None:
        """Per-token kNN hit: the sampled token appeared among the
        retrieved memories' values (the benchmark's recall-equivalent)."""
        vals = np.where(ids >= 0, self.datastore.values[
            np.where(ids >= 0, ids, 0)], -1)
        self._m_knn_hits.inc(
            int((vals == toks[:, None]).any(axis=1).sum()))
        self._m_knn_tokens.inc(len(toks))

    def _finish(self, g: _SlotGroup, emitted: List) -> None:
        inf = g.inflight
        if inf is None:
            return
        g.inflight = None
        if inf.futures is not None:
            with self.tracer.span("stream.gather",
                                  n=len(inf.futures)):
                t0 = time.monotonic()
                ids, scores = gather_arrays(inf.futures, self.knn_k,
                                            self.retrieval_timeout_s)
                now = time.monotonic()
            self._ret_wait.append(now - t0)
            self._ret_lat.append(now - inf.submitted_at)
            self._h_ret_wait.observe(now - t0)
            self._h_ret_lat.observe(now - inf.submitted_at)
            self._m_lookups.inc(len(inf.futures))
            self._m_hedges.inc(sum(f.hedges for f in inf.futures))
            logp = self._knn_logprobs(inf.logits, ids, scores)
        else:
            logp = inf.logits
        toks = sample_np(logp, self._rng, self.sampler)
        if inf.futures is not None:
            self._count_hits(ids, toks)
        for row, slot in enumerate(inf.slots):
            sess = g.sessions[slot]
            tok = int(toks[row])
            sess.tokens.append(tok)
            g.pos[slot] += 1
            g.last[slot] = tok
            emitted.append((sess.request_id, tok))
            self._m_tokens.inc()
            if self._finished(sess, int(g.pos[slot])):
                self._complete(sess)
                g.sessions[slot] = None

    def _finished(self, sess: Session, pos: int) -> bool:
        req = sess.request
        hit_eos = (req.eos_id is not None and sess.tokens
                   and sess.tokens[-1] == req.eos_id)
        return (len(sess.tokens) >= req.max_new_tokens or hit_eos
                or pos >= self.max_seq - 1)

    def _complete(self, sess: Session) -> None:
        sess.state = "done"
        self.done.append(Completion(
            sess.request_id, sess.tokens, len(sess.request.prompt),
            len(sess.tokens)))

    def _admit(self, g: _SlotGroup, emitted: List) -> None:
        """Fill free slots from the admission queue. A session's first
        token is sampled HERE (prefill logits x its insert-time lookup,
        which has been resolving since ``insert``), so the slot enters
        the next dispatch with a valid last token — no garbage decode
        step ever touches the cache (ring or recurrent state).

        With ``overlap=True`` admission is BALANCED across the two slot
        groups (this group only admits up to its fair share of the
        queue): an empty peer group leaves nothing to hide retrieval
        behind. Serialized mode packs one group densely instead — each
        group's decode op is padded to full width regardless of
        occupancy, so splitting a small load across groups would just
        double the op count for nothing."""
        budget = self.slots_per_group
        if self.overlap:
            peer = self.groups[1] if g is self.groups[0] else self.groups[0]
            peer_active = sum(s is not None for s in peer.sessions)
            this_active = sum(s is not None for s in g.sessions)
            fair = peer_active + max(1, (len(self.queue) + 1) // 2)
            budget = max(0, fair - this_active)
        for slot in range(self.slots_per_group):
            if budget <= 0:
                break
            if g.sessions[slot] is not None:
                continue
            while self.queue:
                sess = self.queue.popleft()
                tok = self._first_token(sess)
                emitted.append((sess.request_id, tok))
                self._m_tokens.inc()
                pos = len(sess.request.prompt)
                if self._finished(sess, pos):
                    self._complete(sess)   # done at token 1: the slot
                    continue               # stays free for the next in line
                g.cache = scatter_slot(g.cache, sess.pcache, slot)
                sess.pcache = None         # freed: the slot owns it now
                sess.state = "active"
                g.sessions[slot] = sess
                g.pos[slot] = pos
                g.last[slot] = tok
                self._m_admitted.inc()
                budget -= 1
                break

    def _first_token(self, sess: Session) -> int:
        ids = None
        if sess.future is not None:
            t0 = time.monotonic()
            ids, scores = gather_arrays([sess.future], self.knn_k,
                                        self.retrieval_timeout_s)
            now = time.monotonic()
            self._ret_wait.append(now - t0)
            self._h_ret_wait.observe(now - t0)
            # no _ret_lat sample: this lookup was issued at insert() and
            # may have sat behind the admission queue for many steps —
            # that residency is queueing, not retrieval latency, and
            # would swamp the per-step p99
            self._m_lookups.inc()
            self._m_hedges.inc(sess.future.hedges)
            sess.future = None
            logp = self._knn_logprobs(sess.lm_logits[None], ids, scores)
        else:
            logp = sess.lm_logits[None]
        tok = sample_np(logp, self._rng, self.sampler)
        if ids is not None:
            self._count_hits(ids, np.asarray(tok))
        tok = int(tok[0])
        sess.tokens.append(tok)
        return tok

    def _dispatch(self, g: _SlotGroup) -> None:
        live = [s for s in range(self.slots_per_group)
                if g.sessions[s] is not None]
        if not live:
            return
        with self.tracer.span("stream.dispatch", n=len(live)):
            tokens = jnp.asarray(g.last[:, None], jnp.int32)
            pos = jnp.asarray(g.pos, jnp.int32)
            logits_d, hidden_d, g.cache = self._decode(
                self.params, g.cache, tokens, pos)
            # blocking on the transfer IS the overlap window for the
            # other group: while this group's decode finishes on device,
            # the counter-group's lookups resolve in engine threads
            logits = np.asarray(logits_d)[live]
            hidden = np.asarray(hidden_d, np.float32)[live]
            futures = None
            submitted = time.monotonic()
            if self._client is not None:
                futures = self._client.search_batch(
                    hidden, self.knn_k,
                    branching_factor=self.branching_factor)
        g.inflight = _Inflight(logits, live, futures, submitted)
        self._m_steps.inc()

    # -- observability -----------------------------------------------------

    def stats(self) -> dict:
        """Engine snapshot: scheduler state, throughput, and per-step
        retrieval latency percentiles (``latency`` = submit->resolved,
        the engine-side service time; ``wait`` = time the sampler
        actually blocked, i.e. the NON-overlapped remainder)."""
        lat = sorted(self._ret_lat)
        wait = sorted(self._ret_wait)
        active = sum(s is not None for grp in self.groups
                     for s in grp.sessions)
        dt = (time.monotonic() - self._t0) if self._t0 else float("nan")

        def pct(xs, q):
            return nearest_rank(xs, q) if xs else float("nan")

        # counter-backed (the same objects /metrics renders)
        tokens = int(self._m_tokens.value)
        knn_tokens = int(self._m_knn_tokens.value)
        return {
            "num_slots": self.num_slots,
            "slots_per_group": self.slots_per_group,
            "overlap": self.overlap,
            "steps": int(self._m_steps.value),
            "tokens_emitted": tokens,
            "tokens_per_s": (tokens / dt if dt and dt > 0
                             else float("nan")),
            "sessions": {"queued": len(self.queue), "active": active,
                         "admitted": int(self._m_admitted.value),
                         "completed": len(self.done),
                         "rejected": int(self._m_rejected.value)},
            "retrieval": {
                "enabled": self._client is not None,
                "knn_k": self.knn_k, "lam": self.lam,
                "lookups": int(self._m_lookups.value),
                "hedges": int(self._m_hedges.value),
                "latency_p50_s": pct(lat, 50),
                "latency_p99_s": pct(lat, 99),
                "wait_p50_s": pct(wait, 50),
                "wait_p99_s": pct(wait, 99),
                "knn_hit_rate": (int(self._m_knn_hits.value) / knn_tokens
                                 if knn_tokens else float("nan")),
            },
        }
