"""Token samplers for the decode loop: greedy, temperature, top-k, top-p.

All operate on [B, V] logits and are jit-able (static config, PRNG key
threaded explicitly). :func:`sample_np` is the numpy twin for host-side
sampling loops (the streaming engine samples on the host after
interpolating retrieval probabilities — same masking semantics, numpy
RNG instead of a jax key).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 1.0
    top_k: int = 0          # 0 = off
    top_p: float = 1.0      # 1.0 = off
    greedy: bool = False


@functools.partial(jax.jit, static_argnames=("cfg",))
def sample(logits: jnp.ndarray, key, cfg: SamplerConfig) -> jnp.ndarray:
    """logits [B, V] -> token ids [B] int32."""
    logits = logits.astype(jnp.float32)
    if cfg.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    logits = logits / jnp.maximum(cfg.temperature, 1e-6)

    if cfg.top_k > 0 and cfg.top_k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)

    if cfg.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative mass >= top_p (always keep best)
        cutoff_idx = jnp.sum(cum < cfg.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)

    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_np(logits: np.ndarray, rng: np.random.Generator,
              cfg: SamplerConfig) -> np.ndarray:
    """Numpy twin of :func:`sample` for host-side decode loops.

    Identical temperature / top-k / top-p masking; the categorical draw
    uses the Gumbel-max trick on ``rng`` (numpy) instead of a jax key,
    so stochastic draws are reproducible per engine seed but not
    bit-aligned with the jitted sampler. Greedy is exactly argmax in
    both. logits [B, V] -> token ids [B] int64.
    """
    logits = np.asarray(logits, np.float32)
    if cfg.greedy:
        return np.argmax(logits, axis=-1)

    logits = logits / max(cfg.temperature, 1e-6)

    if cfg.top_k > 0 and cfg.top_k < logits.shape[-1]:
        kth = np.sort(logits, axis=-1)[..., -cfg.top_k][..., None]
        logits = np.where(logits < kth, -np.inf, logits)

    if cfg.top_p < 1.0:
        sorted_logits = np.sort(logits, axis=-1)[..., ::-1]
        x = np.exp(sorted_logits - sorted_logits[..., :1])
        probs = x / x.sum(-1, keepdims=True)
        cum = np.cumsum(probs, axis=-1)
        # smallest set with cumulative mass >= top_p (always keep best)
        cutoff_idx = np.sum(cum < cfg.top_p, axis=-1, keepdims=True)
        cutoff = np.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = np.where(logits < cutoff, -np.inf, logits)

    gumbel = -np.log(-np.log(
        rng.uniform(low=np.finfo(np.float32).tiny, size=logits.shape)))
    masked = np.where(np.isfinite(logits), logits + gumbel, -np.inf)
    return np.argmax(masked, axis=-1)
