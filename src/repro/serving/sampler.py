"""Token samplers for the decode loop: greedy, temperature, top-k, top-p.

All operate on [B, V] logits and are jit-able (static config, PRNG key
threaded explicitly).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 1.0
    top_k: int = 0          # 0 = off
    top_p: float = 1.0      # 1.0 = off
    greedy: bool = False


@functools.partial(jax.jit, static_argnames=("cfg",))
def sample(logits: jnp.ndarray, key, cfg: SamplerConfig) -> jnp.ndarray:
    """logits [B, V] -> token ids [B] int32."""
    logits = logits.astype(jnp.float32)
    if cfg.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    logits = logits / jnp.maximum(cfg.temperature, 1e-6)

    if cfg.top_k > 0 and cfg.top_k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)

    if cfg.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative mass >= top_p (always keep best)
        cutoff_idx = jnp.sum(cum < cfg.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)

    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
