"""Multi-tenant namespaces over one device-memory budget.

A :class:`TenantManager` multiplexes many named Pyramid indexes
("tenants") onto one accelerator without letting their arenas
collectively exceed an HBM budget:

  * **admission control** — every tenant's arena footprint is estimated
    *before* any device allocation (same arithmetic as
    ``ShardArena.from_index``'s stacking: ``w * n_pad * d`` elements at
    the storage dtype) and charged against ``budget_bytes``. Once an
    engine is live, the estimate is trued up to the engine's actual
    ``arena_vector_bytes``. A tenant that cannot fit even after evicting
    every other idle tenant is refused with :class:`AdmissionError` —
    the device is never oversubscribed;
  * **LRU eviction** — admitting a new (or re-activating a cold) tenant
    evicts least-recently-accessed live tenants first: their engine is
    drained and shut down and the index's device cache is dropped
    (``invalidate_device_cache``), but the *host* index object is
    retained — and any store-attached mutations were already journaled —
    so eviction never loses data;
  * **transparent re-pinning** — every tenant-scoped call
    (``submit`` / ``client`` / ``scale`` / ``stats`` /
    ``attach_maintenance``) touches the tenant's LRU clock and lazily
    re-admits it if it was evicted. A caller holding a
    :class:`~repro.core.client.PyramidClient` from :meth:`client` keeps
    working across an evict/re-pin cycle: the client resolves its engine
    through the manager on every call;
  * **replica arbitration** — :meth:`arbitrate` splits a global replica
    budget across tenants proportionally to their observed access rate
    and installs the shares as each tenant autoscaler's
    ``max_replicas`` (attach one per tenant with
    :meth:`attach_autoscaler`), so a hot tenant can grow only into
    headroom the cold tenants are not using.

Engines are registered in a :class:`repro.core.api.Brokers` under the
tenant name, so everything built on brokers (hot-swap via
``replace_index``, the maintenance compactor, ``open_client``) works
per-tenant unchanged.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.api import Brokers
from repro.core.client import PyramidClient
from repro.core.meta_index import PyramidIndex
from repro.obs import MetricsRegistry

logger = logging.getLogger(__name__)


class AdmissionError(RuntimeError):
    """The tenant's arena cannot fit in the device-memory budget, even
    after evicting every other evictable tenant."""


def estimate_arena_bytes(index: PyramidIndex, *,
                         quantize: bool = False) -> int:
    """Predicted vector-payload HBM footprint of ``index``'s arena,
    WITHOUT building it — mirrors ``ShardArena.from_index`` stacking:
    ``w`` shards equal-padded to the largest shard's item count.
    Quantized arenas store int8 codes plus the per-shard f32 grid."""
    subs = index.subs
    if not subs:
        return 0
    w = len(subs)
    n_pad = max(1, max(g.n for g in subs))
    d = subs[0].d
    if quantize:
        return w * n_pad * d + 2 * w * d * 4   # codes + scale/zero grid
    return w * n_pad * d * 4


@dataclasses.dataclass
class _Tenant:
    """Manager-side state for one namespace."""
    name: str
    index: PyramidIndex
    engine_kw: dict
    bytes_admitted: int = 0
    live: bool = False
    pinned: bool = False          # live and not evictable (mid-call)
    last_access: float = 0.0
    accesses: int = 0             # total tenant-scoped calls (LRU + rate)
    evictions: int = 0
    autoscaler: object = None
    autoscaler_cfg: object = None


class TenantManager:
    """Admission-controlled registry of named Pyramid tenants sharing
    one device-memory budget (see module docstring).

    ``budget_bytes`` bounds the sum of live tenants' arena vector
    payloads. ``brokers`` defaults to a private :class:`Brokers`; pass a
    shared one to co-host tenants next to other engines (their HBM is
    then NOT accounted here). Usable as a context manager — exit shuts
    down every live engine.
    """

    def __init__(self, budget_bytes: int, *,
                 brokers: Optional[Brokers] = None,
                 registry: Optional[MetricsRegistry] = None):
        if budget_bytes <= 0:
            raise ValueError(
                f"budget_bytes must be > 0, got {budget_bytes}")
        self.budget_bytes = int(budget_bytes)
        self.brokers = brokers if brokers is not None else Brokers()
        self._tenants: Dict[str, _Tenant] = {}
        self._lock = threading.RLock()
        self._shutdown = False
        self.obs = registry if registry is not None else MetricsRegistry()
        m = self.obs
        self._m_admissions = m.counter(
            "pyramid_tenant_admissions_total",
            "tenant arenas admitted to device memory",
            labelnames=("tenant",))
        self._m_evictions = m.counter(
            "pyramid_tenant_evictions_total",
            "tenant arenas evicted to make room",
            labelnames=("tenant",))
        self._m_rejections = m.counter(
            "pyramid_tenant_rejections_total",
            "admissions refused (AdmissionError)")
        self._m_accesses = m.counter(
            "pyramid_tenant_accesses_total",
            "tenant-scoped calls served", labelnames=("tenant",))
        m.gauge("pyramid_tenant_live", "1 if the tenant's arena is on "
                "device", labelnames=("tenant",),
                fn=lambda: {(t.name,): 1.0 if t.live else 0.0
                            for t in list(self._tenants.values())})
        m.gauge("pyramid_tenant_bytes",
                "admitted arena vector bytes per tenant",
                labelnames=("tenant",),
                fn=lambda: {(t.name,): float(t.bytes_admitted)
                            for t in list(self._tenants.values())})
        m.gauge("pyramid_tenant_budget_bytes",
                "device-memory budget shared by all tenants",
                fn=lambda: float(self.budget_bytes))
        m.gauge("pyramid_tenant_used_bytes",
                "admitted bytes summed over live tenants",
                fn=lambda: float(self._used_locked()))

    # -- accounting ---------------------------------------------------------

    def _used_locked(self) -> int:
        return sum(t.bytes_admitted for t in self._tenants.values()
                   if t.live)

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return self._used_locked()

    # -- registry -----------------------------------------------------------

    def create(self, name: str, index: PyramidIndex, *,
               activate: bool = True, **engine_kw) -> "TenantManager":
        """Register a tenant. ``activate=True`` (default) admits and
        spawns its engine immediately — raising :class:`AdmissionError`
        up front if it can never fit; ``False`` defers both to the first
        tenant-scoped call. ``engine_kw`` (``replicas=``,
        ``quantize=``, ...) is remembered and reapplied on every
        re-pin after an eviction."""
        with self._lock:
            self._check_open()
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already exists")
            est = estimate_arena_bytes(
                index, quantize=bool(engine_kw.get("quantize")))
            if est > self.budget_bytes:
                self._m_rejections.inc()
                raise AdmissionError(
                    f"tenant {name!r} needs ~{est} arena bytes, over "
                    f"the total budget of {self.budget_bytes}")
            self._tenants[name] = _Tenant(
                name=name, index=index, engine_kw=dict(engine_kw),
                bytes_admitted=est)
        if activate:
            self._ensure_live(name)
        return self

    def drop(self, name: str) -> None:
        """Remove a tenant entirely: evict if live, forget its state."""
        with self._lock:
            t = self._tenants.pop(name, None)
        if t is None:
            return
        self._teardown(t)

    def tenants(self) -> List[str]:
        with self._lock:
            return sorted(self._tenants)

    # -- admission / eviction ----------------------------------------------

    def _check_open(self) -> None:
        if self._shutdown:
            raise RuntimeError("tenant manager is shut down")

    def _get(self, name: str) -> _Tenant:
        t = self._tenants.get(name)
        if t is None:
            raise KeyError(
                f"unknown tenant {name!r} (known: {sorted(self._tenants)})")
        return t

    def _ensure_live(self, name: str):
        """Touch the tenant's LRU clock and return its live engine,
        admitting (and evicting colder tenants) if necessary."""
        evict: List[_Tenant] = []
        with self._lock:
            self._check_open()
            t = self._get(name)
            t.last_access = time.monotonic()
            t.accesses += 1
            self._m_accesses.labels(tenant=name).inc()
            if t.live:
                return self.brokers.get_engine(name)
            est = estimate_arena_bytes(
                t.index, quantize=bool(t.engine_kw.get("quantize")))
            t.bytes_admitted = est
            if est > self.budget_bytes:
                self._m_rejections.inc()
                raise AdmissionError(
                    f"tenant {name!r} needs ~{est} arena bytes, over "
                    f"the total budget of {self.budget_bytes}")
            # evict coldest-first until the newcomer fits (<= budget:
            # an arena exactly at the remaining budget is admitted)
            victims = sorted(
                (v for v in self._tenants.values()
                 if v.live and not v.pinned and v.name != name),
                key=lambda v: v.last_access)
            freed = 0
            while (self._used_locked() - freed + est > self.budget_bytes
                   and victims):
                v = victims.pop(0)
                evict.append(v)
                freed += v.bytes_admitted
            if self._used_locked() - freed + est > self.budget_bytes:
                self._m_rejections.inc()
                raise AdmissionError(
                    f"tenant {name!r} needs ~{est} arena bytes; only "
                    f"{self.budget_bytes - self._used_locked()} of "
                    f"{self.budget_bytes} free and no evictable tenant "
                    "frees enough")
            for v in evict:
                v.live = False   # claim under the lock; teardown below
            t.live = True        # claim the budget before releasing
            t.pinned = True      # don't let a racing admit evict us
        try:
            for v in evict:
                self._evict(v)
            engine = self.brokers.engine_for(name, t.index,
                                             **t.engine_kw)
            # true-up: the engine knows its actual payload
            with self._lock:
                t.bytes_admitted = int(
                    engine.stats()["arena_vector_bytes"])
            self._m_admissions.labels(tenant=name).inc()
            if t.autoscaler_cfg is not None and t.autoscaler is None:
                self._attach_autoscaler_locked(t, engine)
            return engine
        except BaseException:
            with self._lock:   # failed spawn must not leak budget
                t.live = False
            raise
        finally:
            with self._lock:
                t.pinned = False

    def _evict(self, t: _Tenant) -> None:
        """Off-device a tenant: stop its autoscaler, drain + shut down
        its engine, drop the index's device cache. Host state (graphs,
        tags, delta-log attachment) is untouched — a re-pin rebuilds the
        arena from it bit-identically."""
        logger.info("tenancy: evicting tenant %s (%d bytes)",
                    t.name, t.bytes_admitted)
        self._m_evictions.labels(tenant=t.name).inc()
        t.evictions += 1
        if t.autoscaler is not None:
            try:
                t.autoscaler.stop()
            except Exception:
                logger.exception("autoscaler stop failed for %s", t.name)
            t.autoscaler = None
        self.brokers.close_engine(t.name)
        t.index.invalidate_device_cache()

    def _teardown(self, t: _Tenant) -> None:
        if t.autoscaler is not None:
            try:
                t.autoscaler.stop()
            except Exception:
                pass
            t.autoscaler = None
        self.brokers.close_engine(t.name)
        t.live = False

    def evict(self, name: str) -> bool:
        """Explicitly off-device one tenant (it re-pins lazily on its
        next call). Returns whether it was live."""
        with self._lock:
            t = self._get(name)
            if not t.live or t.pinned:
                return False
            t.live = False
        self._evict(t)
        return True

    # -- tenant-scoped serving surface --------------------------------------

    def engine(self, name: str):
        """The tenant's live engine (admitting / re-pinning first)."""
        return self._ensure_live(name)

    def client(self, name: str) -> PyramidClient:
        """A :class:`PyramidClient` session that follows the tenant
        across evictions, re-pins, and ``replace_index`` hot-swaps."""
        with self._lock:
            self._get(name)   # fail fast on unknown tenants
        return PyramidClient(
            engine_resolver=lambda: self._ensure_live(name), name=name)

    def submit(self, name: str, vectors: np.ndarray, k: int = 10,
               **kw):
        """Tenant-scoped :meth:`ServingEngine.submit` (``filter_tags=``
        and ``branching_factor=`` pass through)."""
        return self._ensure_live(name).submit(vectors, k=k, **kw)

    def scale(self, name: str, shard: int, n_replicas: int):
        return self._ensure_live(name).scale(shard, n_replicas)

    def replace_index(self, name: str, index) -> None:
        """Hot-swap the tenant onto a new index (store path or built
        :class:`PyramidIndex`) through the brokers, then refresh the
        byte accounting from the replacement's actual arena."""
        with self._lock:
            t = self._get(name)
        engine = self._ensure_live(name)
        new = self.brokers.replace_index(name, index)
        if new is None:
            return
        with self._lock:
            t.index = new.index
            t.bytes_admitted = int(new.stats()["arena_vector_bytes"])
        del engine

    def attach_maintenance(self, name: str, store, **opts):
        """Tenant-scoped :meth:`Brokers.attach_maintenance` (delta-log
        compaction + hot-swap for this tenant's store)."""
        self._ensure_live(name)
        return self.brokers.attach_maintenance(name, store, **opts)

    # -- autoscaling arbitration --------------------------------------------

    def attach_autoscaler(self, name: str, config=None):
        """Create (and remember) a per-tenant
        :class:`repro.serving.autoscaler.Autoscaler`; recreated
        automatically after evict/re-pin cycles. Returns the live
        autoscaler."""
        from repro.serving.autoscaler import AutoscalerConfig
        engine = self._ensure_live(name)
        with self._lock:
            t = self._get(name)
            t.autoscaler_cfg = config or AutoscalerConfig()
            self._attach_autoscaler_locked(t, engine)
            return t.autoscaler

    def _attach_autoscaler_locked(self, t: _Tenant, engine) -> None:
        from repro.serving.autoscaler import Autoscaler
        t.autoscaler = Autoscaler(engine, t.autoscaler_cfg,
                                  registry=self.obs)

    def arbitrate(self, total_replicas: int) -> Dict[str, int]:
        """Split a global replica budget across tenants by access-rate
        share (largest-remainder rounding, floor 1 each) and install the
        shares as each attached autoscaler's ``max_replicas``. Returns
        ``{tenant: max_replicas}`` for every registered tenant — a
        tenant without an autoscaler still gets its share reported."""
        with self._lock:
            ts = list(self._tenants.values())
            if not ts:
                return {}
            total = max(total_replicas, len(ts))   # floor: 1 per tenant
            counts = np.asarray([t.accesses for t in ts], np.float64)
            if counts.sum() <= 0:
                counts = np.ones(len(ts))
            share = counts / counts.sum()
            raw = share * (total - len(ts))       # floor of 1 pre-paid
            alloc = np.ones(len(ts), np.int64) + raw.astype(np.int64)
            rem = total - int(alloc.sum())
            for i in np.argsort(-(raw - raw.astype(np.int64)))[:rem]:
                alloc[i] += 1
            out: Dict[str, int] = {}
            for t, n in zip(ts, alloc.tolist()):
                out[t.name] = int(n)
                if t.autoscaler is not None:
                    t.autoscaler.config.max_replicas = int(n)
            return out

    # -- introspection / lifecycle ------------------------------------------

    def stats(self, name: Optional[str] = None) -> dict:
        """Manager-level snapshot, or (with ``name``) that tenant's
        engine ``stats()`` extended with its tenancy state."""
        if name is not None:
            engine = self._ensure_live(name)
            s = engine.stats()
            with self._lock:
                t = self._get(name)
                s["tenancy"] = {
                    "live": t.live, "bytes": t.bytes_admitted,
                    "accesses": t.accesses, "evictions": t.evictions}
            return s
        with self._lock:
            return {
                "budget_bytes": self.budget_bytes,
                "used_bytes": self._used_locked(),
                "tenants": {
                    t.name: {"live": t.live, "bytes": t.bytes_admitted,
                             "accesses": t.accesses,
                             "evictions": t.evictions}
                    for t in self._tenants.values()},
            }

    def shutdown(self) -> None:
        with self._lock:
            if self._shutdown:
                return
            self._shutdown = True
            ts = list(self._tenants.values())
        for t in ts:
            self._teardown(t)
        self.brokers.shutdown()

    def __enter__(self) -> "TenantManager":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
