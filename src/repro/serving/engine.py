"""Coordinator/executor serving engine — the paper's Sec. IV system layer.

Faithful *policy* reproduction of Fig. 4 with Python threads standing in
for the machine cluster (DESIGN.md §3):

  * one work queue per sub-HNSW = a Kafka *topic*;
  * executors subscribe to topics; several executors on the same topic form
    a replica group (the paper's replication for straggler/failure
    robustness). Queue semantics give Kafka's rebalancing for free: a slow
    executor simply drains fewer items, the rest are picked up by its
    replica peers;
  * coordinators search the (replicated) meta-HNSW, enqueue per-topic
    requests, and merge partial results returned over a direct result
    queue (the paper routes partials over bare connections, not Kafka —
    same here). Merged results are delivered into a per-query
    ``SearchFuture`` (``repro.core.client``) keyed by query id, so any
    number of callers can share one engine without seeing each other's
    results;
  * a Monitor thread is the Zookeeper/Master analogue — and a real
    *supervisor*, not just a detector: on a dead or stuck executor it
    re-enqueues that executor's in-flight batch items and respawns the
    replica (bounded restarts with exponential backoff), recording a
    recovery timeline exposed via ``stats()``.

Active robustness (Fig. 12 / Fig. 13 mechanisms):

  * **hedged dispatch** — a per-shard :class:`LatencyTracker` streams
    p50/p99 over completed partials; the merger thread re-enqueues a
    query's shard-work once it has waited longer than a deadline derived
    from the tracked percentile (``hedge_factor * p99``), so a replica
    peer races the straggler. Duplicate partials are resolved
    first-result-wins in ``_merge_loop`` — the same dedup that makes the
    at-least-once requeue paths safe;
  * **automatic failure recovery** — executors publish their drained
    batch as ``inflight``; whichever of (the dying executor itself, the
    Monitor) gets there first re-enqueues the items, so a killed,
    crashed, or hung executor loses nothing.

Fault injection is scripted, not slept: a
:class:`repro.serving.faults.FaultSchedule` fires kill / restart /
cpu_share events at deterministic batch-drain boundaries, which is what
the Fig. 12/13 benchmarks and ``tests/test_faults.py`` replay.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import queue
import threading
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.common.utils import nearest_rank
from repro.core import hnsw as H
from repro.core import metrics as M
from repro.core.arena import ShardArena
from repro.core.client import (EngineShutdownError, QueryExpiredError,
                               SearchFuture)
from repro.core.meta_index import PyramidIndex
from repro.core.quant import exact_rerank_np
from repro.core.router import effective_ef, route_queries
from repro.kernels.merge_topk import merge_topk_np
from repro.obs import NULL_TRACER, MetricsRegistry
from repro.serving.faults import FaultSchedule

logger = logging.getLogger(__name__)


# the engine's base meta-search beam for routing; route_queries raises
# it to K when a caller's branching_factor is larger (stats()['routing']
# surfaces that raise)
_ROUTING_EF = 64


@dataclasses.dataclass
class QueryRequest:
    query_id: int
    vector: np.ndarray
    k: int
    num_topics: int           # how many partial results to expect
    submitted_at: float = 0.0  # for topic copies: this dispatch's enqueue time
    shard: int = -1           # which topic this copy was enqueued to
    attempt: int = 0          # 0 = primary dispatch, >0 = hedge/redispatch
    span_id: Optional[int] = None   # the query's root trace span, if any
    filter_tags: int = 0      # metadata filter bitset (0 = unfiltered)
    fetch_k: int = 0          # selectivity-inflated per-shard fetch width


@dataclasses.dataclass
class PartialResult:
    query_id: int
    ids: np.ndarray
    scores: np.ndarray
    shard: int = -1
    attempt: int = 0
    enqueued_at: float = 0.0  # dispatch time of the request copy served
    # the two latency views of this partial (they differ under queueing,
    # throttling, and hedging — conflating them was the old skew bug):
    service_s: float = 0.0    # executor-side: batch drain -> results posted
    e2e_s: float = 0.0        # merger-side: dispatch enqueue -> merge arrival


@dataclasses.dataclass
class QueryResult:
    query_id: int
    ids: np.ndarray
    scores: np.ndarray
    latency_s: float
    hedges: int = 0           # hedge re-dispatches issued for this query


@dataclasses.dataclass
class _Pending:
    """Coordinator-side state for one in-flight query."""
    req: QueryRequest
    fut: SearchFuture
    expected: Tuple[int, ...]             # shard ids awaited
    parts: Dict[int, PartialResult]       # shard -> first-arrived partial
    dispatched: Dict[int, float]          # shard -> last dispatch time
    attempts: Dict[int, int]              # shard -> dispatch count
    hedges: int = 0
    span: object = None                   # open root trace span (or None)


class LatencyTracker:
    """Streaming per-shard latency percentiles over completed partials.

    Bounded window per shard (default 256 newest observations); p50/p99
    are exact over the window. ``quantile`` returns ``None`` until a
    shard has ``min_samples`` observations so a cold engine does not
    hedge off noise.
    """

    def __init__(self, window: int = 256, min_samples: int = 8):
        self.min_samples = min_samples
        self._lat: Dict[int, collections.deque] = collections.defaultdict(
            lambda: collections.deque(maxlen=window))
        self._lock = threading.Lock()

    def observe(self, shard: int, latency_s: float) -> None:
        with self._lock:
            self._lat[shard].append(latency_s)

    def quantile(self, shard: int, q: float) -> Optional[float]:
        """Exact q-th percentile (0..100) over the window, or None."""
        with self._lock:
            xs = sorted(self._lat.get(shard, ()))
        if len(xs) < self.min_samples:
            return None
        return nearest_rank(xs, q)

    def snapshot(self) -> Dict[int, Dict[str, float]]:
        with self._lock:
            data = {s: sorted(d) for s, d in self._lat.items()}
        return {s: {"n": len(xs), "p50": nearest_rank(xs, 50),
                    "p99": nearest_rank(xs, 99)}
                for s, xs in data.items() if xs}


class Executor(threading.Thread):
    """Serves one sub-HNSW replica; pulls from its topic queue."""

    def __init__(self, name: str, topic: "queue.Queue", shard_id: int,
                 arena: ShardArena, metric: str, ef: int,
                 result_bus: "queue.Queue", heartbeat: Dict[str, float],
                 batch_max: int = 32, warm_k: int = 10,
                 fault_tick=None, redispatch=None, k_factor: int = 1,
                 linger_s: float = 0.0, net_delay_s: float = 0.0,
                 tag_words=None, tracer=NULL_TRACER):
        super().__init__(name=name, daemon=True)
        self.topic = topic
        self.shard_id = shard_id
        self.arena = arena
        # this shard's device tag bitsets ([n_pad, 2] int32 word pairs,
        # repro.core.filters) for metadata-filtered requests; None on an
        # untagged engine keeps the unfiltered trace untouched
        self.tag_words = tag_words
        # shared memoised view: every replica of every shard reads the
        # one engine-wide arena (equal shapes => one jit compile serves
        # all executors; one HBM copy per engine, not per executor).
        # A quantized engine hands every executor an int8 view — the
        # per-engine HBM vector payload is the compressed one.
        self.graph = arena.shard_view(shard_id)
        self.metric = metric
        self.ef = ef
        self.result_bus = result_bus
        self.heartbeat = heartbeat
        self.batch_max = batch_max
        self.warm_k = warm_k
        # >1 on a quantized engine: partials carry k_factor * k
        # candidates so the coordinator can exact-rerank the merged list
        self.k_factor = k_factor
        self.fault_tick = fault_tick   # engine hook: batch-drain boundary
        self.redispatch = redispatch   # engine hook: bookkept requeue
        # Kafka linger.ms analogue: after the first drained item, wait
        # up to this long for the rest of its burst before searching.
        # Every search op costs the full padded batch_max regardless of
        # fill, so a burst fragmented across two drains doubles the
        # shard's compute — which happens routinely when the submitting
        # thread is preempted mid-batch (single-core hosts, GIL). 0
        # preserves drain-what-is-there semantics.
        self.linger_s = linger_s
        # remote-deployment emulation: in the paper's architecture every
        # executor is a shard SERVER on another machine, so the client
        # sees an RPC round-trip on top of the search. In this
        # single-process reproduction that latency is emulated as a
        # per-batch sleep before the partials post — it consumes no CPU
        # (unlike cpu_share's throttle it neither scales with work nor
        # shrinks the fetch budget), which is exactly what makes it
        # hideable by a client that overlaps retrieval with decode.
        self.net_delay_s = net_delay_s
        self.tracer = tracer
        self.cpu_share = 1.0        # straggler injection: <1 adds sleep
        self.alive = True
        self.warmed = False         # past jit warmup (monitor grace gate)
        self.busy_since = 0.0       # >0 while blocked inside _search
        self.processed = 0
        self._inflight: List[QueryRequest] = []
        self._inflight_lock = threading.Lock()

    def kill(self) -> None:
        self.alive = False

    # -- in-flight handoff (at-least-once) ---------------------------------

    def _set_inflight(self, batch: List[QueryRequest]) -> None:
        with self._inflight_lock:
            self._inflight = list(batch)

    def take_inflight(self) -> List[QueryRequest]:
        """Atomically claim the drained-but-unfinished batch. Called by
        the dying executor itself AND by the supervising Monitor — the
        pop guarantees the items are re-enqueued exactly once."""
        with self._inflight_lock:
            items, self._inflight = self._inflight, []
            return items

    def has_inflight(self) -> bool:
        with self._inflight_lock:
            return bool(self._inflight)

    # -- search ------------------------------------------------------------

    def _warmup(self) -> None:
        """Populate the jit cache before claiming work."""
        dummy = [QueryRequest(-1, np.zeros(self.graph.data.shape[1],
                                           np.float32), self.warm_k, 0)]
        self._search(dummy)

    def _search(self, batch):
        """Fixed-size padded search, engine-wide jit cache (arena views
        share shapes across shards).

        A drained batch may mix requests with different ``k``: search
        once at ``max(k)`` — rounded up to a power of two so arbitrary
        caller k values cannot trigger unbounded mid-serving jit
        compiles — and trim per request, so mixed-k callers sharing the
        engine each get their own result width.
        Returns ``[(ids [r.k * k_factor], scores [...]) for r in batch]``
        (``k_factor > 1`` on quantized engines: the wider partial feeds
        the coordinator's exact rerank).

        ``hnsw_search`` defaults to the fused beam-walk op
        (``repro.kernels.beam_search`` — Pallas kernel on TPU, batched
        oracle elsewhere), so every executor batch, including
        ``StreamEngine``'s per-decode-step lookups, rides it.

        Filtered requests (``r.filter_tags != 0``) search at their
        selectivity-inflated ``fetch_k`` with this shard's tag bitsets
        masked in on device (post-walk, pre-top-k — never a host-side
        post-filter that could under-fill); mixed batches work because
        filter word 0 means unfiltered per query.
        """
        k = max(max(r.k, r.fetch_k) for r in batch) * self.k_factor
        k = 1 << (k - 1).bit_length()   # bucket: log-many compiles total
        vecs = np.stack([r.vector for r in batch])
        if len(batch) < self.batch_max:  # pad to the compiled shape
            pad = np.repeat(vecs[:1], self.batch_max - len(batch), axis=0)
            vecs = np.concatenate([vecs, pad], axis=0)
        filt_kw = {}
        filt = np.asarray([r.filter_tags for r in batch], np.int64)
        if self.tag_words is not None and np.any(filt):
            from repro.core import filters as F
            fp = np.zeros(self.batch_max, np.int64)
            fp[: len(batch)] = filt   # pad rows: word 0 = unfiltered
            filt_kw = dict(tag_words=self.tag_words,
                           filter_words=jnp.asarray(F.filter_words(fp)))
        with self.tracer.span("kernel.beam_walk", shard=self.shard_id,
                              k=k, batch=len(batch)):
            ids, scores = H.hnsw_search(
                self.graph, jnp.asarray(vecs), metric=self.metric,
                k=k, ef=max(self.ef, k), **filt_kw)
        ids = np.asarray(ids)
        scores = np.asarray(scores)
        return [(ids[i, : max(r.k, r.fetch_k) * self.k_factor],
                 scores[i, : max(r.k, r.fetch_k) * self.k_factor])
                for i, r in enumerate(batch)]

    def _throttle(self, busy_s: float) -> None:
        """CPU-limit tool analogue: sleep off the lost share in small
        slices so a heavily throttled executor still heartbeats and
        still reacts to ``kill()`` promptly."""
        self._sleep(busy_s * (1.0 / self.cpu_share - 1.0))

    def _sleep(self, duration_s: float) -> None:
        """Heartbeating, kill-responsive sleep."""
        end = time.monotonic() + duration_s
        while self.alive:
            now = time.monotonic()
            if now >= end:
                break
            self.heartbeat[self.name] = now
            time.sleep(min(0.05, end - now))

    def run(self) -> None:
        try:
            self._warmup()
            self.warmed = True
            self.heartbeat[self.name] = time.monotonic()
            while self.alive:
                self.heartbeat[self.name] = time.monotonic()
                try:
                    first: QueryRequest = self.topic.get(timeout=0.05)
                except queue.Empty:
                    continue
                # fetch budget shrinks with cpu share (Kafka
                # max.poll.records semantics): a throttled consumer must
                # not hoard the queue — its unfetched records stay
                # available to replica peers. Quadratic, not linear: a
                # straggler's padded-batch search takes ~T/share end to
                # end no matter how few items it drained, so the budget
                # controls how MANY items suffer that delay — share**2
                # keeps the expected straggler-added latency per item
                # roughly constant (paper Fig. 12: throughput stable
                # until the straggler is extremely slow)
                budget = max(1, int(self.batch_max * self.cpu_share ** 2))
                batch = [first]
                deadline = time.monotonic() + self.linger_s
                while len(batch) < budget:
                    try:
                        batch.append(self.topic.get_nowait())
                    except queue.Empty:
                        # linger for the rest of the burst (releases the
                        # GIL, letting the submitter finish enqueueing)
                        wait = deadline - time.monotonic()
                        if wait <= 0:
                            break
                        try:
                            batch.append(self.topic.get(timeout=wait))
                        except queue.Empty:
                            break   # linger window expired, still empty
                self._set_inflight(batch)
                if self.fault_tick is not None:
                    self.fault_tick(self.name)   # drain boundary: a kill
                if not self.alive:      # event lands mid-batch, items
                    return              # in hand (finally re-enqueues)
                with self.tracer.span(
                        "executor.batch", executor=self.name,
                        shard=self.shard_id, n=len(batch),
                        queries=[r.query_id for r in batch]):
                    t0 = time.monotonic()
                    # a thread blocked in XLA cannot heartbeat: flag the
                    # window so the monitor judges it on search_grace_s,
                    # not the loop-idle timeout
                    self.heartbeat[self.name] = t0
                    self.busy_since = t0
                    outs = self._search(batch)
                    # refresh the beat BEFORE dropping the busy flag: the
                    # instant busy_since clears, the monitor judges us on
                    # the short idle timeout again, and the pre-search
                    # heartbeat may already be older than that
                    self.heartbeat[self.name] = time.monotonic()
                    self.busy_since = 0.0
                    if self.cpu_share < 1.0:
                        self._throttle(time.monotonic() - t0)
                    if self.net_delay_s > 0.0:  # emulated RPC round-trip:
                        self._sleep(self.net_delay_s)  # no CPU consumed
                    if not self.alive:  # killed during search/throttle:
                        return          # a dead machine returns nothing
                    service_s = time.monotonic() - t0
                    for r, (ids_r, scores_r) in zip(batch, outs):
                        self.result_bus.put(PartialResult(
                            r.query_id, ids_r, scores_r,
                            shard=self.shard_id, attempt=r.attempt,
                            enqueued_at=r.submitted_at,
                            service_s=service_s))
                    self.processed += len(batch)
                    self._set_inflight([])
        finally:
            # crash, kill, or normal exit: nothing may die holding work.
            # Route through the engine's redispatch so the bookkeeping
            # (dispatch clocks, attempts, the ``redispatched`` counter,
            # completed-query filtering) matches the Monitor's path —
            # and the queued-behind-a-dead-executor time never pollutes
            # the latency tracker the hedge deadline is derived from
            self.alive = False
            if self.redispatch is not None:
                self.redispatch(self)
            else:   # engine-less executor (unit tests): raw requeue
                now = time.monotonic()
                for r in self.take_inflight():
                    self.topic.put(
                        dataclasses.replace(r, submitted_at=now))


class Monitor(threading.Thread):
    """Zookeeper/Master analogue, promoted to supervisor: detect dead or
    stuck executors, re-enqueue their in-flight work, and respawn them
    under bounded restarts with exponential backoff. Every action is
    appended to a recovery timeline surfaced by ``engine.stats()``.
    """

    def __init__(self, engine: "ServingEngine", timeout_s: float = 3.0,
                 period_s: float = 0.1, max_restarts: int = 5,
                 backoff_base_s: float = 0.05, backoff_cap_s: float = 2.0,
                 warmup_grace_s: float = 30.0, search_grace_s: float = 30.0,
                 restart_reset_s: float = 30.0, timeline_cap: int = 200):
        super().__init__(name="monitor", daemon=True)
        self.engine = engine
        self.timeout_s = timeout_s
        self.period_s = period_s
        self.max_restarts = max_restarts
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.warmup_grace_s = warmup_grace_s
        # a thread blocked inside one hnsw_search call cannot heartbeat,
        # so a long-but-healthy search must not be declared stuck on the
        # loop-idle timeout; it gets this (much longer) grace instead
        self.search_grace_s = search_grace_s
        # the restart budget decays after this much continuous health —
        # max_restarts bounds crash *loops*, not lifetime failures
        self.restart_reset_s = restart_reset_s
        self.running = True
        self._timeline: collections.deque = collections.deque(
            maxlen=timeline_cap)
        self._timeline_lock = threading.Lock()
        self._restart_counts: Dict[str, int] = {}
        self._next_allowed: Dict[str, float] = {}
        self._last_restart: Dict[str, float] = {}
        self._gave_up: Dict[str, bool] = {}
        self._suspected: set = set()

    @property
    def restarts(self) -> int:
        """Respawns actually performed. Counter-backed: the Prometheus
        ``pyramid_executor_restarts_total`` series IS the bookkeeping
        (reads 0 under a disabled registry, like all migrated stats)."""
        return int(self.engine._m_restarts.value)

    def _record(self, name: str, event: str, detail: str) -> None:
        with self._timeline_lock:
            self._timeline.append({
                "t": round(time.monotonic() - self.engine._t0, 4),
                "executor": name, "event": event, "detail": detail})

    def timeline_snapshot(self) -> List[dict]:
        with self._timeline_lock:
            return list(self._timeline)

    def run(self) -> None:
        while self.running:
            time.sleep(self.period_s)
            now = time.monotonic()
            for name, ex in list(self.engine.executors.items()):
                dead = not ex.is_alive() or not ex.alive
                if not dead:
                    # heartbeat is seeded at spawn time, so an executor
                    # that hangs before its first beat is *not* treated
                    # as live forever (the pre-seed bug); warmup and
                    # in-search windows get longer graces because a
                    # thread inside one jit/XLA call cannot beat
                    hb = self.engine.heartbeat.get(name, 0.0)
                    grace = (self.warmup_grace_s if not ex.warmed
                             else self.search_grace_s if ex.busy_since
                             else self.timeout_s)
                    if now - hb > grace:
                        if self.engine.auto_restart:
                            ex.kill()   # fence the hung thread off
                            self._record(name, "stuck",
                                         f"no heartbeat for "
                                         f"{now - hb:.2f}s")
                            dead = True
                        elif name not in self._suspected:
                            # detector mode: killing a replica we will
                            # not respawn only makes things worse
                            self._suspected.add(name)
                            self._record(name, "stuck",
                                         f"no heartbeat for {now - hb:.2f}"
                                         "s (not fenced: auto_restart "
                                         "off)")
                    else:
                        self._suspected.discard(name)
                if not dead:
                    # healthy: decay the restart budget after sustained
                    # health so max_restarts bounds crash loops, not the
                    # executor's lifetime (scale() also reuses names)
                    if (name in self._restart_counts
                            and now - self._last_restart.get(name, 0.0)
                            > self.restart_reset_s):
                        self._restart_counts.pop(name, None)
                        self._next_allowed.pop(name, None)
                        self._gave_up.pop(name, None)
                    continue
                with self.engine.tracer.span("monitor.recover",
                                             executor=name):
                    self._recover(name, ex, now)

    def _recover(self, name: str, ex: Executor, now: float) -> None:
        """One supervision action for a dead executor: re-enqueue its
        in-flight work, then (maybe) respawn it. Runs inside a
        ``monitor.recover`` span; the redispatch and respawn instants it
        emits nest under that span, so a trace shows exactly which
        recovery handled which death."""
        # supervisor step 1: a dead executor's drained batch must
        # not be lost — re-enqueue whatever it still held (the
        # executor's own finally-requeue races us; take_inflight
        # is an atomic pop, so items go back exactly once)
        n = self.engine._redispatch_inflight(ex)
        if n:
            self._record(name, "redispatch",
                         f"re-enqueued {n} in-flight items")
            self.engine.tracer.instant("monitor.redispatch",
                                       executor=name, items=n)
        # supervisor step 2: respawn, bounded with backoff
        if not self.engine.auto_restart:
            return
        if now < self._next_allowed.get(name, 0.0):
            return
        count = self._restart_counts.get(name, 0)
        if count >= self.max_restarts:
            if not self._gave_up.get(name):
                self._gave_up[name] = True
                self._record(name, "gave_up",
                             f"max_restarts={self.max_restarts} "
                             "exhausted")
            return
        if self.engine.restart_executor(name):
            self.engine._m_restarts.inc()
            self._restart_counts[name] = count + 1
            self._last_restart[name] = now
            backoff = min(self.backoff_cap_s,
                          self.backoff_base_s * (2 ** count))
            self._next_allowed[name] = now + backoff
            self._record(name, "restart",
                         f"attempt {count + 1}/{self.max_restarts},"
                         f" next backoff {backoff:.2f}s")
            self.engine.tracer.instant("executor.respawn", executor=name,
                                       attempt=count + 1)


class ServingEngine:
    """The full Fig. 4 topology for one PyramidIndex."""

    def __init__(self, index: PyramidIndex, *, replicas: int = 1,
                 ef: Optional[int] = None, auto_restart: bool = True,
                 executor_batch: int = 16, warm_k: int = 10,
                 linger_s: float = 0.0, net_delay_s: float = 0.0,
                 pending_deadline_s: Optional[float] = 300.0,
                 quantize: bool = False, rerank_factor: int = 4,
                 hedge: bool = True,
                 hedge_deadline_s: Optional[float] = None,
                 hedge_percentile: float = 99.0,
                 hedge_factor: float = 3.0,
                 hedge_min_s: float = 0.05,
                 hedge_cold_s: float = 1.0,
                 hedge_max_attempts: int = 2,
                 fault_schedule: Optional[FaultSchedule] = None,
                 monitor_opts: Optional[dict] = None,
                 registry: Optional[MetricsRegistry] = None,
                 tracer=None):
        self.index = index
        self.cfg = index.config
        self.metric = "ip" if self.cfg.is_mips else self.cfg.metric
        self.ef = ef or self.cfg.ef_search
        self.w = index.num_shards
        self.auto_restart = auto_restart
        self.executor_batch = executor_batch
        self.warm_k = warm_k
        # executor-side burst coalescing (Kafka linger.ms) and remote
        # shard-server RPC emulation: see Executor
        self.linger_s = linger_s
        self.net_delay_s = net_delay_s
        # a pending query whose shard lost every live replica would leak
        # forever (its partials can never arrive); after this deadline it
        # is failed with QueryExpiredError. None disables expiry.
        self.pending_deadline_s = pending_deadline_s
        # quantized serving: executors search the int8 arena and return
        # rerank_factor * k candidates per shard; the merger exact-
        # reranks the merged list against the host-side float32 table
        self.quantize = quantize
        self.rerank_factor = rerank_factor if quantize else 1
        # hedged dispatch: once a (query, shard) dispatch has waited
        # past hedge_factor * tracked p{hedge_percentile} (or the fixed
        # hedge_deadline_s override), re-enqueue it so a replica peer
        # races the straggler; at most hedge_max_attempts hedges per
        # (query, shard). First result wins, duplicates are dropped.
        self.hedge = hedge
        self.hedge_deadline_s = hedge_deadline_s
        self.hedge_percentile = hedge_percentile
        self.hedge_factor = hedge_factor
        self.hedge_min_s = hedge_min_s
        self.hedge_cold_s = hedge_cold_s
        self.hedge_max_attempts = hedge_max_attempts
        # hedging keeps its exact-percentile window (the deadline needs
        # an exact p99 over recent samples, which fixed-bucket histogram
        # quantiles cannot give); the registry histograms below are fed
        # at the same merge-loop site for exposition
        self.tracker = LatencyTracker()
        self.faults = fault_schedule
        # -- observability: the registry counters ARE the engine's
        # bookkeeping (stats() reads them back, so the Prometheus
        # endpoint and stats() can never disagree). Default is a fresh
        # private registry so per-engine stats stay per-engine; pass a
        # shared one to aggregate (Brokers.replace_index hands the old
        # engine's registry to its replacement so counters stay
        # monotonic across hot-swaps — registration is idempotent).
        # Caveat: under a disabled registry the migrated stats counters
        # read 0 (that is the documented cost of "free when off").
        self.obs = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        m = self.obs
        self._m_submitted = m.counter(
            "pyramid_queries_submitted_total",
            "queries accepted by submit()")
        self._m_expired = m.counter(
            "pyramid_queries_expired_total",
            "pending queries failed by the expiry sweep")
        self._m_hedged = m.counter(
            "pyramid_queries_hedged_total",
            "queries hedged at least once")
        self._m_redispatched = m.counter(
            "pyramid_redispatched_total",
            "shard-work re-enqueues (hedge + recovery)")
        self._m_restarts = m.counter(
            "pyramid_executor_restarts_total",
            "executor respawns performed by the monitor")
        self._m_partials = m.counter(
            "pyramid_partials_total",
            "winning partial results merged", labelnames=("shard",))
        self._h_service = m.histogram(
            "pyramid_shard_service_seconds",
            "executor-side batch service time (drain -> results posted)",
            labelnames=("shard",))
        self._h_e2e = m.histogram(
            "pyramid_shard_e2e_seconds",
            "dispatch-to-merge latency per winning partial "
            "(what hedge deadlines are derived from)",
            labelnames=("shard",))
        self._h_query = m.histogram(
            "pyramid_query_latency_seconds",
            "submit-to-resolve latency per completed query")
        # pre-bound per-shard children: the merge loop is the hot path
        shards = [str(s) for s in range(self.w)]
        self._m_partials_by = [self._m_partials.labels(shard=s)
                               for s in shards]
        self._h_service_by = [self._h_service.labels(shard=s)
                              for s in shards]
        self._h_e2e_by = [self._h_e2e.labels(shard=s) for s in shards]
        # lazy gauges: evaluated at scrape time, no poller thread
        m.gauge("pyramid_pending_queries", "in-flight queries",
                fn=lambda: len(self._pending))
        m.gauge("pyramid_queue_depth", "topic queue depth",
                labelnames=("shard",),
                fn=lambda: {(str(s),): self.topics[s].qsize()
                            for s in range(self.w)})
        m.gauge("pyramid_replicas_live", "live replicas per shard",
                labelnames=("shard",),
                fn=lambda: {(str(s),): self.replica_count(s)
                            for s in range(self.w)})
        m.gauge("pyramid_executor_heartbeat_staleness_seconds",
                "seconds since each executor's last heartbeat",
                labelnames=("executor",),
                fn=lambda: {(name,): time.monotonic() - hb
                            for name, hb in list(self.heartbeat.items())})
        # maintenance observability: a background compactor
        # (repro.store.maintenance) registers a stats provider here and
        # hooks into the batch-drain tick — same deterministic step
        # clock the fault schedule uses, never a timer
        self._drain_hooks: List = []
        self._maintenance_stats = None
        # serving-layer delete filter (see add_tombstones): ids removed
        # from the live index after this engine snapshotted its arena
        self._tombstones = np.zeros((0,), np.int64)

        self.meta_arrays = index.meta_arrays()
        self.part_of_center = jnp.asarray(index.part_of_center)
        # one device arena per engine; int8 when quantized (the HBM
        # vector payload shrinks ~4x — see index.arena docs)
        self.arena = index.arena("int8" if quantize else "float32")
        # metadata-filter state, snapshotted with the arena: host tags
        # drive submit-time selectivity estimates, the device word pairs
        # feed the executors' on-device alive mask. Untagged indexes get
        # None — the unfiltered jit trace is untouched, and a filtered
        # query against an untagged engine short-circuits to empty in
        # submit() (selectivity 0)
        self._tags_host = index.tags_host()
        self._tags_arena = (index.tags_arena()
                            if self._tags_host.any() else None)
        if quantize:   # host-side full-precision copy for exact rerank
            self._rerank_table = index.rerank_table()
        # Fig. 5 routing observability: running access-rate accumulators
        # (shard hits / (queries * w)) and the branching factor the last
        # submit routed with (a caller override changes what the meta
        # search actually ran). The engine's base meta-search beam is
        # _ROUTING_EF; routing raises it to K when K is larger — stats()
        # reports both so the raise is observable.
        self._routed_hits = 0
        self._routed_queries = 0
        # per-shard dispatch counts: stats()['access_rate_per_shard'] is
        # the load signal the autoscaler reads (hot shards get replicas)
        self._routed_per_shard = np.zeros(self.w, np.int64)
        self._routing_kb = self.cfg.branching_factor

        self.topics: List[queue.Queue] = [queue.Queue()
                                          for _ in range(self.w)]
        self.result_bus: "queue.Queue" = queue.Queue()
        self.heartbeat: Dict[str, float] = {}
        self.executors: Dict[str, Executor] = {}
        self.replicas = replicas          # configured replicas per shard
        self._qid = 0
        self._pending: Dict[int, _Pending] = {}
        self._lock = threading.Lock()
        self._scale_lock = threading.Lock()
        self._shutdown = False
        self._t0 = time.monotonic()

        for s in range(self.w):
            for r in range(replicas):
                self._spawn(s, r)
        self.monitor = Monitor(self, **(monitor_opts or {}))
        self.monitor.start()
        self._merger = threading.Thread(target=self._merge_loop, daemon=True)
        self._merger_running = True
        self._merger.start()

    # -- lifecycle ---------------------------------------------------------

    @classmethod
    def from_store(cls, store_path: str, *, version: Optional[str] = None,
                   replay_delta: bool = True, **engine_kw
                   ) -> "ServingEngine":
        """Recover an engine from a published :class:`repro.store.
        IndexStore` version (default: the latest).

        This is the crash-recovery path: an engine lost with its host
        reopens the last *published* index and replays the version's
        append-only delta log, so every ``add_items`` that happened
        after the publish is served again — the recovered engine answers
        within the usual recall tolerance of the pre-crash one (see
        ``tests/test_store.py``). ``quantize=True`` (via ``engine_kw``)
        reopens onto the manifest's frozen int8 grid — no re-derivation,
        and replayed inserts requantize bit-identically
        (``tests/test_quant.py``).
        """
        from repro.store import IndexStore
        index = IndexStore(store_path).load(
            version=version, replay_delta=replay_delta)
        return cls(index, **engine_kw)

    def _spawn(self, shard: int, replica: int) -> Executor:
        name = f"exec-s{shard}-r{replica}"
        ex = Executor(name, self.topics[shard], shard,
                      self.arena, self.metric, self.ef,
                      self.result_bus, self.heartbeat,
                      batch_max=self.executor_batch, warm_k=self.warm_k,
                      fault_tick=self._fault_tick,
                      redispatch=self._redispatch_inflight,
                      k_factor=self.rerank_factor,
                      linger_s=self.linger_s,
                      net_delay_s=self.net_delay_s,
                      tag_words=(None if self._tags_arena is None
                                 else self._tags_arena[shard]),
                      tracer=self.tracer)
        # seed the heartbeat BEFORE the thread runs: an executor that
        # dies or hangs before its first beat must look stale, not
        # fresh-forever (the old ``heartbeat.get(name, now)`` bug)
        self.heartbeat[name] = time.monotonic()
        self.executors[name] = ex
        ex.start()
        return ex

    def restart_executor(self, name: str) -> bool:
        """Respawn a dead executor under its name; returns whether a
        respawn actually happened (the monitor counts only those)."""
        with self._lock:     # serialize against shutdown(): a respawn
            if self._shutdown:   # landing after its kill snapshot would
                return False     # leak a forever-running thread
            old = self.executors.get(name)
            if old is None:  # retired by scale() since the monitor's scan
                return False
            self._spawn(old.shard_id, self._replica_slot(name))
            return True

    def kill_executor(self, name: str) -> None:
        """Failure injection: the monitor may restart the executor."""
        self.executors[name].kill()

    def set_cpu_share(self, name: str, share: float) -> None:
        self.executors[name].cpu_share = share

    def install_fault_schedule(self, schedule: FaultSchedule) -> None:
        """Arm a (new) fault script; steps count from this engine's next
        batch drain. Replaces any previous schedule."""
        self.faults = schedule

    def _fault_tick(self, actor: str = "") -> None:
        fs = self.faults
        if fs is not None:
            fs.tick(self, actor)
        for hook in list(self._drain_hooks):
            try:
                hook(actor)
            except Exception:   # a maintenance hook must never be able
                logger.exception("drain hook failed")   # to kill serving

    def add_drain_hook(self, hook) -> None:
        """Register ``hook(actor)`` to run at every executor batch-drain
        boundary — the engine's deterministic step clock (exactly where
        ``FaultSchedule.tick`` fires). The maintenance compactor uses
        this to count work/poll cycles without wall-clock sleeps; hooks
        run on executor threads and must not block."""
        self._drain_hooks.append(hook)

    def remove_drain_hook(self, hook) -> None:
        try:
            self._drain_hooks.remove(hook)
        except ValueError:
            pass

    def set_maintenance_stats(self, provider) -> None:
        """Attach a zero-arg callable returning the maintenance
        subsystem's stats dict; surfaced as ``stats()['maintenance']``."""
        self._maintenance_stats = provider

    def add_tombstones(self, ids) -> None:
        """Hide ``ids`` from every future result of this engine.

        The engine serves the arena it snapshotted at construction, so a
        ``remove_items`` applied to the live index stays visible here
        until the next maintenance hot-swap publishes a folded index.
        The maintenance write path calls this to close that gap: merged
        results drop tombstoned ids immediately.  The set dies with the
        engine — by the time a compaction cycle swaps in a new engine,
        every journaled removal has been folded into its index.
        """
        ids = np.asarray(ids, dtype=np.int64).ravel()
        if not ids.size:
            return
        with self._lock:
            self._tombstones = np.unique(
                np.concatenate([self._tombstones, ids]))

    @staticmethod
    def _replica_slot(name: str) -> int:
        """Slot number from an ``exec-s{shard}-r{slot}`` executor name."""
        return int(name.split("-r")[1])

    def replica_count(self, shard: int) -> int:
        """Live replicas currently serving ``shard``'s topic."""
        return len(self._live_replicas(shard))

    def _live_replicas(self, shard: int) -> List[str]:
        return sorted(
            (name for name, ex in list(self.executors.items())
             if ex.shard_id == shard and ex.alive),
            key=self._replica_slot)   # numeric: r10 sorts after r2

    def scale(self, shard: int, n_replicas: int) -> List[str]:
        """Elastic scaling (paper Sec. IV-B): resize ``shard``'s replica
        group to exactly ``n_replicas`` live executors.

        Scale-down retires the highest-numbered replicas *intentionally*
        (deregistered before the kill so the monitor does not resurrect
        them); scale-up spawns fresh replicas on unused slots. Returns
        the live replica names after the resize.
        """
        if not 0 <= shard < self.w:
            raise ValueError(f"shard {shard} out of range [0, {self.w})")
        if n_replicas < 1:
            # zero consumers would strand every query routed to this
            # topic: futures that never complete
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        with self._scale_lock, self._lock:
            # _lock serializes the registry mutation against shutdown():
            # either this resize lands before the kill snapshot (and is
            # torn down with the rest) or it observes _shutdown and stops
            if self._shutdown:
                raise EngineShutdownError("engine is shut down")
            # deregister this shard's dead-but-registered executors
            # (failure-injected crashes): scale is the authoritative
            # resize, so the monitor must not resurrect them afterwards
            for name, ex in list(self.executors.items()):
                if ex.shard_id == shard and not ex.alive:
                    self.executors.pop(name)
                    self.heartbeat.pop(name, None)
            live = self._live_replicas(shard)
            for name in reversed(live[n_replicas:]):   # retire extras
                ex = self.executors.pop(name)
                self.heartbeat.pop(name, None)
                ex.kill()
            used = {self._replica_slot(n)
                    for n, ex in list(self.executors.items())
                    if ex.shard_id == shard}
            r = 0
            for _ in range(n_replicas - len(live)):    # grow the group
                while r in used:
                    r += 1
                used.add(r)
                self._spawn(shard, r)
            live_after = self._live_replicas(shard)
            self.tracer.instant("engine.scale", shard=shard,
                                replicas=len(live_after))
            return live_after

    def drain(self, timeout: float = 30.0) -> bool:
        """Wait until every in-flight future has resolved; returns
        ``False`` on timeout (stragglers then fail at ``shutdown``).

        The hot-swap path (``Brokers.replace_index``) calls this on the
        outgoing engine *after* installing its replacement: nothing new
        arrives here, the executors are still alive, so queries
        submitted before the swap complete normally instead of dying
        with ``EngineShutdownError`` — hot-swaps are invisible to
        callers holding futures."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if not self._pending:
                    return True
            time.sleep(0.005)
        with self._lock:
            return not self._pending

    def stats(self) -> dict:
        """Public snapshot of engine state — replaces poking at
        ``engine.executors`` / ``engine._pending`` internals."""
        with self._lock:
            pending = len(self._pending)
            routed_hits = self._routed_hits
            routed_queries = self._routed_queries
            routed_per_shard = self._routed_per_shard.copy()
            routing_kb = self._routing_kb
        # counter-backed (same objects the Prometheus endpoint renders,
        # so /metrics and stats() can never disagree)
        hedged = int(self._m_hedged.value)
        redispatched = int(self._m_redispatched.value)
        execs = {
            name: {"shard": ex.shard_id, "alive": ex.alive,
                   "processed": ex.processed, "cpu_share": ex.cpu_share}
            for name, ex in sorted(list(self.executors.items()))}
        return {
            "num_shards": self.w,
            "quantized": self.quantize,
            "rerank_factor": self.rerank_factor,
            "arena_vector_bytes": self.arena.vector_nbytes,
            # Fig. 5 routing metric: mean fraction of sub-HNSWs a
            # submitted query touched (nan before any submit)
            "access_rate": (routed_hits / (routed_queries * self.w)
                            if routed_queries else float("nan")),
            # per-shard dispatch fraction (hot-shard signal for the
            # autoscaler): shard s appeared in this fraction of routes
            "access_rate_per_shard": (
                (routed_per_shard / routed_queries).tolist()
                if routed_queries else [float("nan")] * self.w),
            # what the last submit's meta routing actually searched
            # with: the engine requests a _ROUTING_EF-wide beam and the
            # router raises it to K when K is larger — requested !=
            # effective IS the observable raise
            "routing": {"requested_ef": _ROUTING_EF,
                        "branching_factor": routing_kb,
                        "effective_ef": effective_ef(
                            _ROUTING_EF, routing_kb)},
            "replicas": {s: self.replica_count(s) for s in range(self.w)},
            "executors": execs,
            "pending_queries": pending,
            # counter-backed like hedged/expired below: cumulative over
            # the registry's lifetime, so a hot-swapped engine that
            # inherited its predecessor's registry reports the
            # service-level total and /metrics parity holds exactly
            "submitted_queries": int(self._m_submitted.value),
            "expired_queries": int(self._m_expired.value),
            "hedged_queries": hedged,
            "redispatched": redispatched,
            "restarts": self.monitor.restarts,
            "monitor_restarts": self.monitor.restarts,   # legacy alias
            "recovery_timeline": self.monitor.timeline_snapshot(),
            "latency": self.tracker.snapshot(),
            "fault_step": self.faults.step if self.faults else 0,
            "queue_depths": [t.qsize() for t in self.topics],
            # background maintenance (repro.store.maintenance), when a
            # compactor is attached: cycles, folded records, rebalance
            # ops, last published version
            "maintenance": (self._maintenance_stats()
                            if self._maintenance_stats else None),
        }

    def shutdown(self) -> None:
        with self._lock:   # no submit can register futures after this
            self._shutdown = True
            pending = list(self._pending.values())
            self._pending.clear()
        self.monitor.running = False
        self._merger_running = False
        for ex in list(self.executors.values()):   # snapshot: the monitor
            ex.kill()                              # may _spawn concurrently
        for entry in pending:   # fail in-flight futures loudly
            if entry.req.span_id is not None:
                entry.span.attrs.update(shutdown=True)
                self.tracer.end(entry.span)
            entry.fut.set_exception(EngineShutdownError(
                f"engine shut down with query {entry.req.query_id} "
                "in flight"))
        # join so no thread dies inside an XLA call at interpreter
        # teardown (aborts the process with "terminate called ...").
        # One shared deadline: executors killed mid-jit-warmup can take
        # several seconds to reach their alive check, but they warm up
        # concurrently, so the total wait is ~one warmup.
        deadline = time.monotonic() + 15.0
        for ex in list(self.executors.values()):
            ex.join(timeout=max(0.0, deadline - time.monotonic()))
        self.monitor.join(timeout=max(0.1, deadline - time.monotonic()))
        self._merger.join(timeout=max(0.1, deadline - time.monotonic()))

    # -- query path --------------------------------------------------------

    def submit(self, vectors: np.ndarray, k: int = 10,
               branching_factor: Optional[int] = None,
               filter_tags=None) -> List[SearchFuture]:
        """Coordinator: route + enqueue a batch; returns one
        :class:`SearchFuture` per query, in submit order.

        Each future is keyed by its query id inside the engine, so
        concurrent callers sharing this engine each observe exactly
        their own results (there is no shared completion queue to steal
        from), and a caller that times out gets ``TimeoutError`` from
        ``future.result()`` instead of a silently short batch.

        ``filter_tags`` (scalar or per-query int64 bitsets,
        ``repro.core.filters`` semantics: 0 = unfiltered, else any-of
        bit intersection) restricts results to matching items. The
        per-shard fetch width is inflated by the estimated selectivity
        (``ceil(1/sel)``, capped) so low-selectivity filters keep their
        fill instead of being post-filtered into under-full results.
        """
        if self._shutdown:
            raise EngineShutdownError("engine is shut down")
        q = M.preprocess_queries(vectors, self.cfg.metric)
        kb = branching_factor or self.cfg.branching_factor
        filt = np.zeros(q.shape[0], np.int64)
        if filter_tags is not None:
            filt = np.broadcast_to(
                np.asarray(filter_tags, np.int64),
                (q.shape[0],)).copy()
        fetch = np.zeros(q.shape[0], np.int64)
        if filt.any():
            from repro.core import filters as F
            for f in np.unique(filt[filt != 0]):
                sel = F.selectivity_np(self._tags_host, int(f))
                fetch[filt == f] = k * F.inflation(sel)
        with self.tracer.span("coordinator.route", n=int(q.shape[0]),
                              branching_factor=kb):
            mask, _ = route_queries(
                self.meta_arrays, self.part_of_center, jnp.asarray(q),
                metric=self.metric, branching_factor=kb,
                num_shards=self.w, ef=_ROUTING_EF)
        mask = np.asarray(mask)
        futures = []
        now = time.monotonic()
        with self._lock:
            if self._shutdown:   # re-check: shutdown may have raced the
                raise EngineShutdownError(  # routing work above
                    "engine is shut down")
            # Fig. 5 metric: fraction of sub-HNSWs each query touches,
            # plus the K this batch's meta routing actually used
            self._routed_hits += int(mask.sum())
            self._routed_queries += int(mask.shape[0])
            self._routed_per_shard += mask.sum(axis=0).astype(np.int64)
            self._routing_kb = kb
            for i in range(q.shape[0]):
                qid = self._qid
                self._qid += 1
                self._m_submitted.inc()
                topics = tuple(int(s) for s in np.where(mask[i])[0])
                fut = SearchFuture(qid)
                if not topics or (filt[i] and self._tags_arena is None):
                    # router selected nothing, or a non-empty filter on
                    # an untagged engine (selectivity 0): empty result
                    fut.set_result(QueryResult(
                        qid, np.empty(0, np.int64),
                        np.empty(0, np.float32), 0.0))
                    futures.append(fut)
                    continue
                # the query's root span stays open until the future
                # resolves (merge, expiry, or shutdown); every dispatch,
                # hedge, merge, and rerank span hangs off it
                qspan = self.tracer.start("query", qid=qid, k=k,
                                          shards=list(topics))
                req = QueryRequest(qid, q[i], k, len(topics), now,
                                   span_id=qspan.span_id,
                                   filter_tags=int(filt[i]),
                                   fetch_k=int(fetch[i]))
                self._pending[qid] = _Pending(
                    req=req, fut=fut, expected=topics, parts={},
                    dispatched={s: now for s in topics},
                    attempts={s: 1 for s in topics}, span=qspan)
                for s in topics:
                    self.tracer.instant("dispatch", parent=qspan.span_id,
                                        qid=qid, shard=s, attempt=0)
                    self.topics[s].put(
                        dataclasses.replace(req, shard=s))
                futures.append(fut)
        return futures

    # -- recovery / hedging ------------------------------------------------

    def _redispatch_inflight(self, ex: Executor) -> int:
        """Supervisor path: re-enqueue a dead executor's drained batch.
        Only (query, shard) pairs still awaited are re-dispatched; the
        rest were already answered by a replica peer. Returns how many
        items went back on the topic."""
        items = ex.take_inflight()
        if not items:
            return 0
        requeue = []
        now = time.monotonic()
        with self._lock:
            for r in items:
                entry = self._pending.get(r.query_id)
                if entry is None or r.shard in entry.parts:
                    continue   # answered elsewhere: drop, don't redo
                entry.attempts[r.shard] = (
                    entry.attempts.get(r.shard, 1) + 1)
                entry.dispatched[r.shard] = now
                self._m_redispatched.inc()
                requeue.append(dataclasses.replace(
                    r, attempt=entry.attempts[r.shard] - 1,
                    submitted_at=now))
        for r in requeue:
            # child of the query's root span: the trace shows which
            # query lost which shard-work to the dead executor
            self.tracer.instant("recovery.redispatch", parent=r.span_id,
                                qid=r.query_id, shard=r.shard,
                                attempt=r.attempt, executor=ex.name)
            self.topics[r.shard].put(r)
        return len(requeue)

    def _hedge_deadline(self, shard: int) -> float:
        if self.hedge_deadline_s is not None:
            return self.hedge_deadline_s
        p = self.tracker.quantile(shard, self.hedge_percentile)
        if p is None:          # cold shard: no percentile to trust yet
            return self.hedge_cold_s
        return max(self.hedge_min_s, self.hedge_factor * p)

    def _hedge_sweep(self, now: float) -> None:
        """Merger-side straggler mitigation: re-enqueue shard-work that
        has waited past its latency-derived deadline so a replica peer
        races the original dispatch (first result wins)."""
        # deadlines are per-shard, not per-query: compute each once per
        # sweep, outside the engine lock (sorting the tracker window
        # per pending entry would stall submit/merge under load)
        deadlines = [self._hedge_deadline(s) for s in range(self.w)]
        # only hedge shards whose topic queue is EMPTY: a non-empty
        # queue means the missing partial is (or is behind) backlog the
        # replicas simply haven't reached — re-enqueueing into that
        # backlog multiplies load exactly at peak (a burst submit must
        # not become a fleet-wide hedge storm). An empty queue with an
        # overdue dispatch means some executor drained the item and is
        # sitting on it — the straggler signature hedging exists for.
        idle = [self.topics[s].qsize() == 0 for s in range(self.w)]
        actions = []
        with self._lock:
            for entry in self._pending.values():
                for s in entry.expected:
                    if s in entry.parts or not idle[s]:
                        continue
                    attempts = entry.attempts.get(s, 1)
                    if attempts > self.hedge_max_attempts:
                        continue   # give up hedging; expiry still bounds
                    if now - entry.dispatched[s] <= deadlines[s]:
                        continue
                    entry.attempts[s] = attempts + 1
                    entry.dispatched[s] = now
                    if entry.hedges == 0:
                        self._m_hedged.inc()
                    entry.hedges += 1
                    entry.fut.record_hedge()
                    self._m_redispatched.inc()
                    actions.append(dataclasses.replace(
                        entry.req, shard=s, attempt=attempts,
                        submitted_at=now))
        for r in actions:
            # child of the query's root span even though the merger
            # thread emits it — the acceptance-tested causality edge
            self.tracer.instant("hedge.redispatch", parent=r.span_id,
                                qid=r.query_id, shard=r.shard,
                                attempt=r.attempt)
            self.topics[r.shard].put(r)

    # -- merge -------------------------------------------------------------

    def _merge_loop(self) -> None:
        sweep_every = 0.25
        if self.pending_deadline_s is not None:
            sweep_every = max(0.05, min(0.25, self.pending_deadline_s / 4))
        next_sweep = time.monotonic() + sweep_every
        next_hedge = 0.0
        while self._merger_running:
            try:
                part: Optional[PartialResult] = self.result_bus.get(
                    timeout=0.05)
            except queue.Empty:
                part = None
            now = time.monotonic()
            if self.hedge and now >= next_hedge:   # bounded sweep rate:
                next_hedge = now + 0.05            # a fast result stream
                self._hedge_sweep(now)             # must not sweep per-item
            if self.pending_deadline_s is not None and now >= next_sweep:
                next_sweep = now + sweep_every
                self._expire_pending(now)
            if part is None:
                continue
            with self._lock:
                entry = self._pending.get(part.query_id)
                if entry is None or part.shard in entry.parts:
                    # late or hedged duplicate (at-least-once delivery):
                    # first result won, drop this one
                    continue
                entry.parts[part.shard] = part
                self._m_partials_by[part.shard].inc()
                # per-shard e2e latency feeds the hedge deadline —
                # WINNING partials only: a persistent straggler's losing
                # deliveries would otherwise drag the tracked p99 up to
                # its own latency and self-disable the hedging aimed at
                # it (tracker has its own lock; never takes this one).
                # e2e (dispatch enqueue -> here) and service (executor
                # drain -> post) are recorded separately on the partial:
                # the hedge threshold and the histograms now measure the
                # same explicitly-named thing instead of a mix
                if part.enqueued_at > 0:
                    part.e2e_s = now - part.enqueued_at
                    self.tracker.observe(part.shard, part.e2e_s)
                    self._h_e2e_by[part.shard].observe(part.e2e_s)
                if part.service_s > 0:
                    self._h_service_by[part.shard].observe(part.service_s)
                if len(entry.parts) < len(entry.expected):
                    continue
                del self._pending[part.query_id]
            # shared dedup-top-k merge (the same semantics the fused
            # arena pipeline runs on device via the merge_topk kernel);
            # concatenate in shard order so score ties break identically
            # no matter which replica answered first. A quantized engine
            # merges the wider rerank_factor * k candidate list, then
            # exact-reranks it against the float32 table so the caller
            # sees full-precision scores and float-path recall.
            qsid = entry.req.span_id
            with self.tracer.span("merge", parent=qsid,
                                  qid=entry.req.query_id,
                                  parts=len(entry.parts)):
                parts = [entry.parts[s] for s in sorted(entry.parts)]
                ids = np.concatenate([p.ids for p in parts])[None, :]
                scores = np.concatenate(
                    [p.scores for p in parts])[None, :]
                tomb = self._tombstones
                # serving-layer delete filter: the arena still holds a
                # removed item's row until the next maintenance hot-swap,
                # but its id must never reach a caller. Applied as an
                # alive mask INSIDE the merge (not on the merged top-k):
                # a tombstoned id cannot crowd a live candidate out of
                # the k slots, so results stay full
                alive = (~np.isin(ids, tomb)) if tomb.size else None
                top_scores, top_ids = merge_topk_np(
                    scores, ids, k=entry.req.k * self.rerank_factor,
                    alive=alive)
                if self.quantize:
                    with self.tracer.span("rerank",
                                          qid=entry.req.query_id):
                        table_ids, table_vecs = self._rerank_table
                        top_ids, top_scores = exact_rerank_np(
                            entry.req.vector[None, :], top_ids,
                            entry.req.k, table_ids=table_ids,
                            table_vecs=table_vecs, metric=self.metric)
                found = top_ids[0] >= 0
            latency_s = time.monotonic() - entry.req.submitted_at
            self._h_query.observe(latency_s)
            if qsid is not None:   # None = null span (tracing off)
                entry.span.attrs.update(hedges=entry.hedges,
                                        latency_s=round(latency_s, 6))
                self.tracer.end(entry.span)   # resolve closes the root
            entry.fut.set_result(QueryResult(
                entry.req.query_id, top_ids[0][found],
                top_scores[0][found], latency_s,
                hedges=entry.hedges))

    def _expire_pending(self, now: float) -> None:
        """Fail pending queries older than the deadline (their shard may
        have lost every live replica — the leak this bounds)."""
        expired = []
        with self._lock:
            for qid, entry in list(self._pending.items()):
                if now - entry.req.submitted_at > self.pending_deadline_s:
                    del self._pending[qid]
                    expired.append(entry)
        for entry in expired:
            self._m_expired.inc()
            if entry.req.span_id is not None:
                entry.span.attrs.update(expired=True)
                self.tracer.end(entry.span)
            entry.fut.set_exception(QueryExpiredError(
                f"query {entry.req.query_id} expired after "
                f"{self.pending_deadline_s}s with "
                f"{len(entry.parts)}/{entry.req.num_topics} "
                f"partial results (shard replicas lost or overloaded)"))
