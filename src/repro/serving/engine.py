"""Coordinator/executor serving engine — the paper's Sec. IV system layer.

Faithful *policy* reproduction of Fig. 4 with Python threads standing in
for the machine cluster (DESIGN.md §3):

  * one work queue per sub-HNSW = a Kafka *topic*;
  * executors subscribe to topics; several executors on the same topic form
    a replica group (the paper's replication for straggler/failure
    robustness). Queue semantics give Kafka's rebalancing for free: a slow
    executor simply drains fewer items, the rest are picked up by its
    replica peers;
  * coordinators search the (replicated) meta-HNSW, enqueue per-topic
    requests, and merge partial results returned over a direct result
    queue (the paper routes partials over bare connections, not Kafka —
    same here). Merged results are delivered into a per-query
    ``SearchFuture`` (``repro.core.client``) keyed by query id, so any
    number of callers can share one engine without seeing each other's
    results;
  * a Monitor thread is the Zookeeper/Master analogue: executors heartbeat
    by touching their lock timestamp; on expiry the monitor restarts the
    executor on the same "machine" (thread pool).

Straggler injection (`set_cpu_share`) and failure injection (`kill`) drive
the Fig. 12 / Fig. 13 benchmarks.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.common.config import PyramidConfig
from repro.core import hnsw as H
from repro.core import metrics as M
from repro.core.arena import ShardArena
from repro.core.client import (EngineShutdownError, QueryExpiredError,
                               SearchFuture)
from repro.core.meta_index import PyramidIndex
from repro.core.router import route_queries
from repro.kernels.merge_topk import merge_topk_np


@dataclasses.dataclass
class QueryRequest:
    query_id: int
    vector: np.ndarray
    k: int
    num_topics: int           # how many partial results to expect
    submitted_at: float = 0.0


@dataclasses.dataclass
class PartialResult:
    query_id: int
    ids: np.ndarray
    scores: np.ndarray


@dataclasses.dataclass
class QueryResult:
    query_id: int
    ids: np.ndarray
    scores: np.ndarray
    latency_s: float


class Executor(threading.Thread):
    """Serves one sub-HNSW replica; pulls from its topic queue."""

    def __init__(self, name: str, topic: "queue.Queue", shard_id: int,
                 arena: ShardArena, metric: str, ef: int,
                 result_bus: "queue.Queue", heartbeat: Dict[str, float],
                 batch_max: int = 32, warm_k: int = 10):
        super().__init__(name=name, daemon=True)
        self.topic = topic
        self.shard_id = shard_id
        self.arena = arena
        # shared memoised view: every replica of every shard reads the
        # one engine-wide arena (equal shapes => one jit compile serves
        # all executors; one HBM copy per engine, not per executor)
        self.graph = arena.shard_view(shard_id)
        self.metric = metric
        self.ef = ef
        self.result_bus = result_bus
        self.heartbeat = heartbeat
        self.batch_max = batch_max
        self.warm_k = warm_k
        self.cpu_share = 1.0        # straggler injection: <1 adds sleep
        self.alive = True
        self.processed = 0

    def kill(self) -> None:
        self.alive = False

    def _search(self, batch):
        """Fixed-size padded search, engine-wide jit cache (arena views
        share shapes across shards).

        A drained batch may mix requests with different ``k``: search
        once at ``max(k)`` — rounded up to a power of two so arbitrary
        caller k values cannot trigger unbounded mid-serving jit
        compiles — and trim per request, so mixed-k callers sharing the
        engine each get their own result width.
        Returns ``[(ids [r.k], scores [r.k]) for r in batch]``.
        """
        k = max(r.k for r in batch)
        k = 1 << (k - 1).bit_length()   # bucket: log-many compiles total
        vecs = np.stack([r.vector for r in batch])
        if len(batch) < self.batch_max:  # pad to the compiled shape
            pad = np.repeat(vecs[:1], self.batch_max - len(batch), axis=0)
            vecs = np.concatenate([vecs, pad], axis=0)
        ids, scores = H.hnsw_search(
            self.graph, jnp.asarray(vecs), metric=self.metric, k=k,
            ef=self.ef)
        ids = np.asarray(ids)
        scores = np.asarray(scores)
        return [(ids[i, : r.k], scores[i, : r.k])
                for i, r in enumerate(batch)]

    def run(self) -> None:
        # warm up the jit cache before claiming work
        dummy = [QueryRequest(-1, np.zeros(self.graph.data.shape[1],
                                           np.float32), self.warm_k, 0)]
        self._search(dummy)
        while self.alive:
            self.heartbeat[self.name] = time.monotonic()
            try:
                first: QueryRequest = self.topic.get(timeout=0.05)
            except queue.Empty:
                continue
            # fetch budget shrinks with cpu share (Kafka max.poll.records
            # semantics): a throttled consumer must not hoard the queue —
            # its unfetched records stay available to replica peers
            budget = max(1, int(self.batch_max * self.cpu_share))
            batch = [first]
            while len(batch) < budget:
                try:
                    batch.append(self.topic.get_nowait())
                except queue.Empty:
                    break
            if not self.alive:   # killed mid-drain: requeue (at-least-once)
                for r in batch:
                    self.topic.put(r)
                return
            t0 = time.monotonic()
            outs = self._search(batch)
            dt = time.monotonic() - t0
            if self.cpu_share < 1.0:  # CPU-limit tool analogue
                time.sleep(dt * (1.0 / self.cpu_share - 1.0))
            for r, (ids_r, scores_r) in zip(batch, outs):
                self.result_bus.put(
                    PartialResult(r.query_id, ids_r, scores_r))
            self.processed += len(batch)


class Monitor(threading.Thread):
    """Zookeeper/Master analogue: restart executors whose lock expired."""

    def __init__(self, engine: "ServingEngine", timeout_s: float = 0.5,
                 period_s: float = 0.1):
        super().__init__(name="monitor", daemon=True)
        self.engine = engine
        self.timeout_s = timeout_s
        self.period_s = period_s
        self.running = True
        self.restarts = 0

    def run(self) -> None:
        while self.running:
            time.sleep(self.period_s)
            now = time.monotonic()
            for name, ex in list(self.engine.executors.items()):
                hb = self.engine.heartbeat.get(name, now)
                if (not ex.is_alive() or not ex.alive or
                        now - hb > self.timeout_s):
                    if self.engine.auto_restart and not ex.alive:
                        if self.engine.restart_executor(name):
                            self.restarts += 1


class ServingEngine:
    """The full Fig. 4 topology for one PyramidIndex."""

    def __init__(self, index: PyramidIndex, *, replicas: int = 1,
                 ef: Optional[int] = None, auto_restart: bool = True,
                 executor_batch: int = 16, warm_k: int = 10,
                 pending_deadline_s: Optional[float] = 300.0):
        self.index = index
        self.cfg = index.config
        self.metric = "ip" if self.cfg.is_mips else self.cfg.metric
        self.ef = ef or self.cfg.ef_search
        self.w = index.num_shards
        self.auto_restart = auto_restart
        self.executor_batch = executor_batch
        self.warm_k = warm_k
        # a pending query whose shard lost every live replica would leak
        # forever (its partials can never arrive); after this deadline it
        # is failed with QueryExpiredError. None disables expiry.
        self.pending_deadline_s = pending_deadline_s
        self.expired = 0

        self.meta_arrays = index.meta_arrays()
        self.part_of_center = jnp.asarray(index.part_of_center)
        self.arena = index.arena()   # one device arena per engine

        self.topics: List[queue.Queue] = [queue.Queue()
                                          for _ in range(self.w)]
        self.result_bus: "queue.Queue" = queue.Queue()
        self.heartbeat: Dict[str, float] = {}
        self.executors: Dict[str, Executor] = {}
        self.replicas = replicas          # configured replicas per shard
        self._qid = 0
        self._pending: Dict[
            int, Tuple[QueryRequest, List[PartialResult], SearchFuture]] = {}
        self._lock = threading.Lock()
        self._scale_lock = threading.Lock()
        self._shutdown = False

        for s in range(self.w):
            for r in range(replicas):
                self._spawn(s, r)
        self.monitor = Monitor(self)
        self.monitor.start()
        self._merger = threading.Thread(target=self._merge_loop, daemon=True)
        self._merger_running = True
        self._merger.start()

    # -- lifecycle ---------------------------------------------------------

    def _spawn(self, shard: int, replica: int) -> Executor:
        name = f"exec-s{shard}-r{replica}"
        ex = Executor(name, self.topics[shard], shard,
                      self.arena, self.metric, self.ef,
                      self.result_bus, self.heartbeat,
                      batch_max=self.executor_batch, warm_k=self.warm_k)
        self.executors[name] = ex
        ex.start()
        return ex

    def restart_executor(self, name: str) -> bool:
        """Respawn a dead executor under its name; returns whether a
        respawn actually happened (the monitor counts only those)."""
        with self._lock:     # serialize against shutdown(): a respawn
            if self._shutdown:   # landing after its kill snapshot would
                return False     # leak a forever-running thread
            old = self.executors.get(name)
            if old is None:  # retired by scale() since the monitor's scan
                return False
            self._spawn(old.shard_id, self._replica_slot(name))
            return True

    def kill_executor(self, name: str) -> None:
        """Failure injection: the monitor may restart the executor."""
        self.executors[name].kill()

    def set_cpu_share(self, name: str, share: float) -> None:
        self.executors[name].cpu_share = share

    @staticmethod
    def _replica_slot(name: str) -> int:
        """Slot number from an ``exec-s{shard}-r{slot}`` executor name."""
        return int(name.split("-r")[1])

    def replica_count(self, shard: int) -> int:
        """Live replicas currently serving ``shard``'s topic."""
        return len(self._live_replicas(shard))

    def _live_replicas(self, shard: int) -> List[str]:
        return sorted(
            (name for name, ex in list(self.executors.items())
             if ex.shard_id == shard and ex.alive),
            key=self._replica_slot)   # numeric: r10 sorts after r2

    def scale(self, shard: int, n_replicas: int) -> List[str]:
        """Elastic scaling (paper Sec. IV-B): resize ``shard``'s replica
        group to exactly ``n_replicas`` live executors.

        Scale-down retires the highest-numbered replicas *intentionally*
        (deregistered before the kill so the monitor does not resurrect
        them); scale-up spawns fresh replicas on unused slots. Returns
        the live replica names after the resize.
        """
        if not 0 <= shard < self.w:
            raise ValueError(f"shard {shard} out of range [0, {self.w})")
        if n_replicas < 1:
            # zero consumers would strand every query routed to this
            # topic: futures that never complete
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        with self._scale_lock, self._lock:
            # _lock serializes the registry mutation against shutdown():
            # either this resize lands before the kill snapshot (and is
            # torn down with the rest) or it observes _shutdown and stops
            if self._shutdown:
                raise EngineShutdownError("engine is shut down")
            # deregister this shard's dead-but-registered executors
            # (failure-injected crashes): scale is the authoritative
            # resize, so the monitor must not resurrect them afterwards
            for name, ex in list(self.executors.items()):
                if ex.shard_id == shard and not ex.alive:
                    self.executors.pop(name)
                    self.heartbeat.pop(name, None)
            live = self._live_replicas(shard)
            for name in reversed(live[n_replicas:]):   # retire extras
                ex = self.executors.pop(name)
                self.heartbeat.pop(name, None)
                ex.kill()
            used = {self._replica_slot(n)
                    for n, ex in list(self.executors.items())
                    if ex.shard_id == shard}
            r = 0
            for _ in range(n_replicas - len(live)):    # grow the group
                while r in used:
                    r += 1
                used.add(r)
                self._spawn(shard, r)
            return self._live_replicas(shard)

    def stats(self) -> dict:
        """Public snapshot of engine state — replaces poking at
        ``engine.executors`` / ``engine._pending`` internals."""
        with self._lock:
            pending = len(self._pending)
            submitted = self._qid
        execs = {
            name: {"shard": ex.shard_id, "alive": ex.alive,
                   "processed": ex.processed, "cpu_share": ex.cpu_share}
            for name, ex in sorted(list(self.executors.items()))}
        return {
            "num_shards": self.w,
            "replicas": {s: self.replica_count(s) for s in range(self.w)},
            "executors": execs,
            "pending_queries": pending,
            "submitted_queries": submitted,
            "expired_queries": self.expired,
            "monitor_restarts": self.monitor.restarts,
            "queue_depths": [t.qsize() for t in self.topics],
        }

    def shutdown(self) -> None:
        with self._lock:   # no submit can register futures after this
            self._shutdown = True
            pending = list(self._pending.values())
            self._pending.clear()
        self.monitor.running = False
        self._merger_running = False
        for ex in list(self.executors.values()):   # snapshot: the monitor
            ex.kill()                              # may _spawn concurrently
        for req, _, fut in pending:   # fail in-flight futures loudly
            fut.set_exception(EngineShutdownError(
                f"engine shut down with query {req.query_id} in flight"))
        # join so no thread dies inside an XLA call at interpreter
        # teardown (aborts the process with "terminate called ...").
        # One shared deadline: executors killed mid-jit-warmup can take
        # several seconds to reach their alive check, but they warm up
        # concurrently, so the total wait is ~one warmup.
        deadline = time.monotonic() + 15.0
        for ex in list(self.executors.values()):
            ex.join(timeout=max(0.0, deadline - time.monotonic()))
        self.monitor.join(timeout=max(0.1, deadline - time.monotonic()))
        self._merger.join(timeout=max(0.1, deadline - time.monotonic()))

    # -- query path --------------------------------------------------------

    def submit(self, vectors: np.ndarray, k: int = 10,
               branching_factor: Optional[int] = None
               ) -> List[SearchFuture]:
        """Coordinator: route + enqueue a batch; returns one
        :class:`SearchFuture` per query, in submit order.

        Each future is keyed by its query id inside the engine, so
        concurrent callers sharing this engine each observe exactly
        their own results (there is no shared completion queue to steal
        from), and a caller that times out gets ``TimeoutError`` from
        ``future.result()`` instead of a silently short batch.
        """
        if self._shutdown:
            raise EngineShutdownError("engine is shut down")
        q = M.preprocess_queries(vectors, self.cfg.metric)
        kb = branching_factor or self.cfg.branching_factor
        mask, _ = route_queries(
            self.meta_arrays, self.part_of_center, jnp.asarray(q),
            metric=self.metric, branching_factor=kb, num_shards=self.w,
            ef=max(64, kb))
        mask = np.asarray(mask)
        futures = []
        now = time.monotonic()
        with self._lock:
            if self._shutdown:   # re-check: shutdown may have raced the
                raise EngineShutdownError(  # routing work above
                    "engine is shut down")
            for i in range(q.shape[0]):
                qid = self._qid
                self._qid += 1
                topics = np.where(mask[i])[0]
                req = QueryRequest(qid, q[i], k, len(topics), now)
                fut = SearchFuture(qid)
                self._pending[qid] = (req, [], fut)
                for s in topics:
                    self.topics[s].put(req)
                futures.append(fut)
        return futures

    def _merge_loop(self) -> None:
        sweep_every = 0.25
        if self.pending_deadline_s is not None:
            sweep_every = max(0.05, min(0.25, self.pending_deadline_s / 4))
        next_sweep = time.monotonic() + sweep_every
        while self._merger_running:
            try:
                part: Optional[PartialResult] = self.result_bus.get(
                    timeout=0.05)
            except queue.Empty:
                part = None
            now = time.monotonic()
            if self.pending_deadline_s is not None and now >= next_sweep:
                next_sweep = now + sweep_every
                self._expire_pending(now)
            if part is None:
                continue
            with self._lock:
                if part.query_id not in self._pending:
                    continue  # duplicate delivery (at-least-once): drop
                req, parts, fut = self._pending[part.query_id]
                parts.append(part)
                if len(parts) < req.num_topics:
                    continue
                del self._pending[part.query_id]
            # shared dedup-top-k merge (the same semantics the fused
            # arena pipeline runs on device via the merge_topk kernel)
            ids = np.concatenate([p.ids for p in parts])[None, :]
            scores = np.concatenate([p.scores for p in parts])[None, :]
            top_scores, top_ids = merge_topk_np(scores, ids, k=req.k)
            found = top_ids[0] >= 0
            fut.set_result(QueryResult(
                req.query_id, top_ids[0][found], top_scores[0][found],
                time.monotonic() - req.submitted_at))

    def _expire_pending(self, now: float) -> None:
        """Fail pending queries older than the deadline (their shard may
        have lost every live replica — the leak this bounds)."""
        expired = []
        with self._lock:
            for qid, (req, parts, fut) in list(self._pending.items()):
                if now - req.submitted_at > self.pending_deadline_s:
                    del self._pending[qid]
                    expired.append((req, len(parts), fut))
        for req, got, fut in expired:
            self.expired += 1
            fut.set_exception(QueryExpiredError(
                f"query {req.query_id} expired after "
                f"{self.pending_deadline_s}s with {got}/{req.num_topics} "
                f"partial results (shard replicas lost or overloaded)"))
