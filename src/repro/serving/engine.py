"""Coordinator/executor serving engine — the paper's Sec. IV system layer.

Faithful *policy* reproduction of Fig. 4 with Python threads standing in
for the machine cluster (DESIGN.md §3):

  * one work queue per sub-HNSW = a Kafka *topic*;
  * executors subscribe to topics; several executors on the same topic form
    a replica group (the paper's replication for straggler/failure
    robustness). Queue semantics give Kafka's rebalancing for free: a slow
    executor simply drains fewer items, the rest are picked up by its
    replica peers;
  * coordinators search the (replicated) meta-HNSW, enqueue per-topic
    requests, and merge partial results returned over a direct result
    queue (the paper routes partials over bare connections, not Kafka —
    same here);
  * a Monitor thread is the Zookeeper/Master analogue: executors heartbeat
    by touching their lock timestamp; on expiry the monitor restarts the
    executor on the same "machine" (thread pool).

Straggler injection (`set_cpu_share`) and failure injection (`kill`) drive
the Fig. 12 / Fig. 13 benchmarks.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.common.config import PyramidConfig
from repro.core import hnsw as H
from repro.core import metrics as M
from repro.core.meta_index import PyramidIndex
from repro.core.router import route_queries


@dataclasses.dataclass
class QueryRequest:
    query_id: int
    vector: np.ndarray
    k: int
    num_topics: int           # how many partial results to expect
    submitted_at: float = 0.0


@dataclasses.dataclass
class PartialResult:
    query_id: int
    ids: np.ndarray
    scores: np.ndarray


@dataclasses.dataclass
class QueryResult:
    query_id: int
    ids: np.ndarray
    scores: np.ndarray
    latency_s: float


class Executor(threading.Thread):
    """Serves one sub-HNSW replica; pulls from its topic queue."""

    def __init__(self, name: str, topic: "queue.Queue", shard_id: int,
                 graph_arrays: H.HNSWArrays, metric: str, ef: int,
                 result_bus: "queue.Queue", heartbeat: Dict[str, float],
                 batch_max: int = 32, warm_k: int = 10):
        super().__init__(name=name, daemon=True)
        self.topic = topic
        self.shard_id = shard_id
        self.graph = graph_arrays
        self.metric = metric
        self.ef = ef
        self.result_bus = result_bus
        self.heartbeat = heartbeat
        self.batch_max = batch_max
        self.warm_k = warm_k
        self.cpu_share = 1.0        # straggler injection: <1 adds sleep
        self.alive = True
        self.processed = 0

    def kill(self) -> None:
        self.alive = False

    def _search(self, batch):
        """Fixed-size padded search: one jit compilation per executor."""
        k = batch[0].k
        vecs = np.stack([r.vector for r in batch])
        if len(batch) < self.batch_max:  # pad to the compiled shape
            pad = np.repeat(vecs[:1], self.batch_max - len(batch), axis=0)
            vecs = np.concatenate([vecs, pad], axis=0)
        ids, scores = H.hnsw_search(
            self.graph, jnp.asarray(vecs), metric=self.metric, k=k,
            ef=self.ef)
        return np.asarray(ids)[: len(batch)], \
            np.asarray(scores)[: len(batch)]

    def run(self) -> None:
        # warm up the jit cache before claiming work
        dummy = [QueryRequest(-1, np.zeros(self.graph.data.shape[1],
                                           np.float32), self.warm_k, 0)]
        self._search(dummy)
        while self.alive:
            self.heartbeat[self.name] = time.monotonic()
            try:
                first: QueryRequest = self.topic.get(timeout=0.05)
            except queue.Empty:
                continue
            # fetch budget shrinks with cpu share (Kafka max.poll.records
            # semantics): a throttled consumer must not hoard the queue —
            # its unfetched records stay available to replica peers
            budget = max(1, int(self.batch_max * self.cpu_share))
            batch = [first]
            while len(batch) < budget:
                try:
                    batch.append(self.topic.get_nowait())
                except queue.Empty:
                    break
            if not self.alive:   # killed mid-drain: requeue (at-least-once)
                for r in batch:
                    self.topic.put(r)
                return
            t0 = time.monotonic()
            ids, scores = self._search(batch)
            dt = time.monotonic() - t0
            if self.cpu_share < 1.0:  # CPU-limit tool analogue
                time.sleep(dt * (1.0 / self.cpu_share - 1.0))
            for i, r in enumerate(batch):
                self.result_bus.put(PartialResult(r.query_id, ids[i],
                                                  scores[i]))
            self.processed += len(batch)


class Monitor(threading.Thread):
    """Zookeeper/Master analogue: restart executors whose lock expired."""

    def __init__(self, engine: "ServingEngine", timeout_s: float = 0.5,
                 period_s: float = 0.1):
        super().__init__(name="monitor", daemon=True)
        self.engine = engine
        self.timeout_s = timeout_s
        self.period_s = period_s
        self.running = True
        self.restarts = 0

    def run(self) -> None:
        while self.running:
            time.sleep(self.period_s)
            now = time.monotonic()
            for name, ex in list(self.engine.executors.items()):
                hb = self.engine.heartbeat.get(name, now)
                if (not ex.is_alive() or not ex.alive or
                        now - hb > self.timeout_s):
                    if self.engine.auto_restart and not ex.alive:
                        self.engine.restart_executor(name)
                        self.restarts += 1


class ServingEngine:
    """The full Fig. 4 topology for one PyramidIndex."""

    def __init__(self, index: PyramidIndex, *, replicas: int = 1,
                 ef: Optional[int] = None, auto_restart: bool = True,
                 executor_batch: int = 16, warm_k: int = 10):
        self.index = index
        self.cfg = index.config
        self.metric = "ip" if self.cfg.is_mips else self.cfg.metric
        self.ef = ef or self.cfg.ef_search
        self.w = index.num_shards
        self.auto_restart = auto_restart
        self.executor_batch = executor_batch
        self.warm_k = warm_k

        self.meta_arrays = index.meta_arrays()
        self.part_of_center = jnp.asarray(index.part_of_center)
        self.sub_arrays = [index.sub_arrays(i) for i in range(self.w)]

        self.topics: List[queue.Queue] = [queue.Queue()
                                          for _ in range(self.w)]
        self.result_bus: "queue.Queue" = queue.Queue()
        self.heartbeat: Dict[str, float] = {}
        self.executors: Dict[str, Executor] = {}
        self._qid = 0
        self._pending: Dict[int, Tuple[QueryRequest, List[PartialResult]]] = {}
        self._done: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()

        for s in range(self.w):
            for r in range(replicas):
                self._spawn(s, r)
        self.monitor = Monitor(self)
        self.monitor.start()
        self._merger = threading.Thread(target=self._merge_loop, daemon=True)
        self._merger_running = True
        self._merger.start()

    # -- lifecycle ---------------------------------------------------------

    def _spawn(self, shard: int, replica: int) -> Executor:
        name = f"exec-s{shard}-r{replica}"
        ex = Executor(name, self.topics[shard], shard,
                      self.sub_arrays[shard], self.metric, self.ef,
                      self.result_bus, self.heartbeat,
                      batch_max=self.executor_batch, warm_k=self.warm_k)
        self.executors[name] = ex
        ex.start()
        return ex

    def restart_executor(self, name: str) -> None:
        old = self.executors[name]
        shard = old.shard_id
        replica = int(name.split("-r")[1])
        self._spawn(shard, replica)

    def kill_executor(self, name: str) -> None:
        self.executors[name].kill()

    def set_cpu_share(self, name: str, share: float) -> None:
        self.executors[name].cpu_share = share

    def shutdown(self) -> None:
        self.monitor.running = False
        self._merger_running = False
        for ex in self.executors.values():
            ex.kill()

    # -- query path --------------------------------------------------------

    def submit(self, vectors: np.ndarray, k: int = 10,
               branching_factor: Optional[int] = None) -> List[int]:
        """Coordinator: route + enqueue a batch; returns query ids."""
        q = M.preprocess_queries(vectors, self.cfg.metric)
        kb = branching_factor or self.cfg.branching_factor
        mask, _ = route_queries(
            self.meta_arrays, self.part_of_center, jnp.asarray(q),
            metric=self.metric, branching_factor=kb, num_shards=self.w,
            ef=max(64, kb))
        mask = np.asarray(mask)
        qids = []
        now = time.monotonic()
        with self._lock:
            for i in range(q.shape[0]):
                qid = self._qid
                self._qid += 1
                topics = np.where(mask[i])[0]
                req = QueryRequest(qid, q[i], k, len(topics), now)
                self._pending[qid] = (req, [])
                for s in topics:
                    self.topics[s].put(req)
                qids.append(qid)
        return qids

    def _merge_loop(self) -> None:
        while self._merger_running:
            try:
                part: PartialResult = self.result_bus.get(timeout=0.05)
            except queue.Empty:
                continue
            with self._lock:
                if part.query_id not in self._pending:
                    continue  # duplicate delivery (at-least-once): drop
                req, parts = self._pending[part.query_id]
                parts.append(part)
                if len(parts) < req.num_topics:
                    continue
                del self._pending[part.query_id]
            ids = np.concatenate([p.ids for p in parts])
            scores = np.concatenate([p.scores for p in parts])
            order = np.argsort(-scores)
            seen, top_ids, top_scores = set(), [], []
            for j in order:
                v = int(ids[j])
                if v < 0 or v in seen:
                    continue
                seen.add(v)
                top_ids.append(v)
                top_scores.append(scores[j])
                if len(top_ids) == req.k:
                    break
            self._done.put(QueryResult(
                req.query_id, np.asarray(top_ids), np.asarray(top_scores),
                time.monotonic() - req.submitted_at))

    def collect(self, n: int, timeout: float = 30.0) -> List[QueryResult]:
        out = []
        deadline = time.monotonic() + timeout
        while len(out) < n and time.monotonic() < deadline:
            try:
                out.append(self._done.get(timeout=0.1))
            except queue.Empty:
                continue
        return out
