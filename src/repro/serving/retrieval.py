"""Retrieval-augmented decoding (kNN-LM) on a Pyramid-sharded datastore.

This is where the paper's technique becomes a first-class serving feature
(DESIGN.md §4): the decoder's last hidden state queries the distributed
Pyramid index; retrieved (hidden -> next-token) memories are converted to a
kNN distribution over the vocab and interpolated with the LM distribution
(Khandelwal et al. kNN-LM — the paper's citation [10] use case).

Datastore keys are hidden states (works identically for attention and
attention-free archs), values are the observed next tokens.

Retrieval runs either single-host (``search_single_host``, now the fused
route->search->merge pipeline over the index's device-resident
``ShardArena``) or through the distributed serving engine via a
:class:`PyramidClient` session — ``open_datastore_client`` starts the
engine and ``knn_probs(..., client=...)`` routes lookups through its
futures surface. Both paths share one arena per index (one HBM copy).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.common.config import ArchConfig, PyramidConfig
from repro.core.client import PyramidClient, gather
from repro.core.meta_index import PyramidIndex, build_pyramid_index
from repro.core.distributed import search_single_host
from repro.models.transformer import forward


@dataclasses.dataclass
class Datastore:
    index: PyramidIndex
    values: np.ndarray          # [n] int32 next-token ids


def build_datastore(params, cfg: ArchConfig, token_batches,
                    pyr_cfg: PyramidConfig) -> Datastore:
    """Run the model over batches; store (hidden_state -> next token).

    token_batches: iterable of [B, S] int arrays.
    """
    keys = []
    vals = []
    for toks in token_batches:
        toks = jnp.asarray(toks)
        hidden = hidden_states(params, cfg, toks)      # [B, S, D]
        # key at position t predicts token t+1
        keys.append(np.asarray(hidden[:, :-1].reshape(-1, hidden.shape[-1]),
                               np.float32))
        vals.append(np.asarray(toks[:, 1:]).reshape(-1).astype(np.int32))
    x = np.concatenate(keys, axis=0)
    v = np.concatenate(vals, axis=0)
    index = build_pyramid_index(x, pyr_cfg)
    return Datastore(index=index, values=v)


def hidden_states(params, cfg: ArchConfig, tokens) -> jnp.ndarray:
    """Final-norm hidden states [B, S, D] (the kNN-LM key convention).

    Implemented by running ``forward`` with an identity LM head — the
    "logits" of the modified model ARE the normed hidden states, so no
    second code path through the trunk exists to drift out of sync.
    """
    if cfg.tie_embeddings:
        raise NotImplementedError("tied-embedding datastore keys")
    d = cfg.d_model
    p2 = {**params, "lm_head": jnp.eye(d, dtype=jnp.dtype(cfg.dtype))}
    cfg2 = dataclasses.replace(cfg, vocab_size=d)
    hid, _, _ = forward(p2, cfg2, tokens)
    return hid


def open_datastore_client(datastore: Datastore, *, replicas: int = 1,
                          **engine_kw) -> PyramidClient:
    """Serve ``datastore.index`` through the distributed engine; the
    returned session feeds ``knn_probs(..., client=...)``. Callers own
    teardown: ``client.engine.shutdown()``. Engine kwargs pass through —
    ``quantize=True`` serves the datastore from the int8 arena (hidden-
    state datastores are where the ~4x HBM saving bites first)."""
    return PyramidClient.from_index(datastore.index, replicas=replicas,
                                    **engine_kw)


def _search_via_client(client: PyramidClient, queries: np.ndarray, k: int,
                       branching_factor: Optional[int],
                       timeout_s: float):
    futures = client.search_batch(queries, k,
                                  branching_factor=branching_factor)
    ids = np.full((len(futures), k), -1, np.int64)
    scores = np.full((len(futures), k), -np.inf, np.float32)
    for i, r in enumerate(gather(futures, timeout_s)):
        n = min(len(r.ids), k)
        ids[i, :n] = r.ids[:n]
        scores[i, :n] = r.scores[:n]
    return ids, scores


def knn_probs(datastore: Datastore, queries: np.ndarray, *, k: int,
              vocab_size: int, temperature: float = 10.0,
              branching_factor: Optional[int] = None,
              client: Optional[PyramidClient] = None,
              timeout_s: float = 30.0) -> np.ndarray:
    """kNN next-token distribution per query. queries: [B, D] hidden states.

    Returns [B, V] probabilities (host-side numpy; the search itself runs
    the jitted Pyramid path). With ``client`` the lookup goes through the
    distributed serving engine's futures surface instead of the
    single-host path; a lookup missing ``timeout_s`` raises
    ``TimeoutError``.
    """
    if client is not None:
        ids, scores = _search_via_client(client, queries, k,
                                         branching_factor, timeout_s)
    else:
        ids, scores, _ = search_single_host(
            datastore.index, queries, k=k,
            branching_factor=branching_factor)
    b = queries.shape[0]
    probs = np.zeros((b, vocab_size), np.float32)
    for i in range(b):
        valid = ids[i] >= 0
        if not valid.any():
            probs[i] = 1.0 / vocab_size
            continue
        # scores are similarities (-L2^2 / ip); softmax with temperature
        s = scores[i][valid] / temperature
        s = np.exp(s - s.max())
        s /= s.sum()
        np.add.at(probs[i], datastore.values[ids[i][valid]], s)
    return probs


def interpolate(lm_logits: np.ndarray, knn_p: np.ndarray,
                lam: float = 0.25) -> np.ndarray:
    """p = lam * p_knn + (1-lam) * p_lm; returns log-probs [B, V]."""
    lm = np.asarray(lm_logits, np.float32)
    lm_p = np.exp(lm - lm.max(-1, keepdims=True))
    lm_p /= lm_p.sum(-1, keepdims=True)
    return np.log(lam * knn_p + (1 - lam) * lm_p + 1e-20)
