"""Retrieval-augmented decoding (kNN-LM) on a Pyramid-sharded datastore.

This is where the paper's technique becomes a first-class serving feature
(DESIGN.md §4): the decoder's last hidden state queries the distributed
Pyramid index; retrieved (hidden -> next-token) memories are converted to a
kNN distribution over the vocab and interpolated with the LM distribution
(Khandelwal et al. kNN-LM — the paper's citation [10] use case).

Datastore keys are hidden states (works identically for attention and
attention-free archs), values are the observed next tokens.

Retrieval runs either single-host (``search_single_host``, now the fused
route->search->merge pipeline over the index's device-resident
``ShardArena``) or through the distributed serving engine via a
:class:`PyramidClient` session — ``open_datastore_client`` starts the
engine and ``knn_probs(..., client=...)`` routes lookups through its
futures surface. Both paths share one arena per index (one HBM copy).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.common.config import ArchConfig, PyramidConfig
from repro.core.client import PyramidClient, gather_arrays
from repro.core.meta_index import PyramidIndex, build_pyramid_index
from repro.core.distributed import search_single_host
from repro.models.transformer import forward


@dataclasses.dataclass
class Datastore:
    index: PyramidIndex
    values: np.ndarray          # [n] int32 next-token ids


def build_datastore(params, cfg: ArchConfig, token_batches,
                    pyr_cfg: PyramidConfig) -> Datastore:
    """Run the model over batches; store (hidden_state -> next token).

    token_batches: iterable of [B, S] int arrays.
    """
    keys = []
    vals = []
    for toks in token_batches:
        toks = jnp.asarray(toks)
        hidden = hidden_states(params, cfg, toks)      # [B, S, D]
        # key at position t predicts token t+1
        keys.append(np.asarray(hidden[:, :-1].reshape(-1, hidden.shape[-1]),
                               np.float32))
        vals.append(np.asarray(toks[:, 1:]).reshape(-1).astype(np.int32))
    x = np.concatenate(keys, axis=0)
    v = np.concatenate(vals, axis=0)
    index = build_pyramid_index(x, pyr_cfg)
    return Datastore(index=index, values=v)


def hidden_states(params, cfg: ArchConfig, tokens) -> jnp.ndarray:
    """Final-norm hidden states [B, S, D] (the kNN-LM key convention).

    Implemented by running ``forward`` with ``skip_head=True`` — the
    "logits" of the head-skipped model ARE the normed hidden states, so
    no second code path through the trunk exists to drift out of sync
    (bit-identical to the old identity-LM-head formulation, and it works
    for tied-embedding archs too).
    """
    hid, _, _ = forward(params, cfg, tokens, skip_head=True)
    return hid


class DatastoreClient(PyramidClient):
    """A :class:`PyramidClient` that OWNS its engine: it is a context
    manager whose ``with`` block (or explicit :meth:`shutdown`) tears
    the engine's threads down. ``open_datastore_client`` used to hand
    back a bare session and rely on every caller remembering
    ``client.engine.shutdown()`` — a forgotten teardown leaked executor
    threads for the life of the process (and could abort the interpreter
    at exit mid-XLA-call)."""

    def shutdown(self) -> None:
        """Shut the owned engine down, then close the session."""
        try:
            self.engine.shutdown()
        finally:
            self.close()

    def __exit__(self, *exc) -> None:
        if not self._closed:   # idempotent: explicit shutdown() inside
            self.shutdown()    # the with-block must not double-teardown


def open_datastore_client(datastore: Datastore, *, replicas: int = 1,
                          **engine_kw) -> DatastoreClient:
    """Serve ``datastore.index`` through the distributed engine; the
    returned session feeds ``knn_probs(..., client=...)`` and the
    streaming decode engine (``repro.serving.stream``). The client owns
    the engine — use it as a context manager::

        with open_datastore_client(ds) as client:
            knn_probs(ds, q, k=8, vocab_size=V, client=client)

    (or call ``client.shutdown()`` explicitly). Engine kwargs pass
    through — ``quantize=True`` serves the datastore from the int8
    arena (hidden-state datastores are where the ~4x HBM saving bites
    first)."""
    return DatastoreClient.from_index(datastore.index, replicas=replicas,
                                      **engine_kw)


def knn_vocab_probs(values: np.ndarray, ids: np.ndarray,
                    scores: np.ndarray, *, vocab_size: int,
                    temperature: float = 10.0) -> np.ndarray:
    """Batched (hit ids, scores) -> [B, V] kNN next-token distributions.

    One vectorised vocab scatter for the whole batch (``np.add.at`` over
    flat (row, token) pairs) instead of a Python loop per query — this
    is the per-decode-step path of the streaming engine, where every
    active slot resolves its lookup at once. Rows with no valid hit
    (all ids ``-1``) fall back to the uniform distribution, matching the
    old per-query behaviour.
    """
    ids = np.asarray(ids)
    scores = np.asarray(scores, np.float32)
    b, k = ids.shape
    valid = ids >= 0
    # scores are similarities (-L2^2 / ip); softmax with temperature,
    # max-subtracted per row exactly as the old per-query loop did
    s = np.where(valid, scores / temperature, -np.inf)
    smax = s.max(axis=1, keepdims=True)
    w = np.where(valid,
                 np.exp(s - np.where(np.isfinite(smax), smax, 0.0)), 0.0)
    norm = w.sum(axis=1, keepdims=True)
    w = w / np.where(norm > 0, norm, 1.0)
    probs = np.zeros((b, vocab_size), np.float32)
    rows = np.repeat(np.arange(b), k)
    toks = values[np.where(valid, ids, 0)].astype(np.int64)
    np.add.at(probs, (rows, toks.reshape(-1)),
              w.astype(np.float32).reshape(-1))
    probs[norm[:, 0] == 0] = 1.0 / vocab_size
    return probs


def knn_probs(datastore: Datastore, queries: np.ndarray, *, k: int,
              vocab_size: int, temperature: float = 10.0,
              branching_factor: Optional[int] = None,
              client: Optional[PyramidClient] = None,
              timeout_s: float = 30.0) -> np.ndarray:
    """kNN next-token distribution per query. queries: [B, D] hidden states.

    Returns [B, V] probabilities (host-side numpy; the search itself runs
    the jitted Pyramid path). With ``client`` the lookup goes through the
    distributed serving engine's futures surface instead of the
    single-host path — one ``search_batch`` for the whole [B, D] batch,
    bulk-resolved via :func:`repro.core.client.gather_arrays`; a lookup
    missing ``timeout_s`` raises ``TimeoutError``.
    """
    if client is not None:
        futures = client.search_batch(queries, k,
                                      branching_factor=branching_factor)
        ids, scores = gather_arrays(futures, k, timeout_s)
    else:
        ids, scores, _ = search_single_host(
            datastore.index, queries, k=k,
            branching_factor=branching_factor)
    return knn_vocab_probs(datastore.values, ids, scores,
                           vocab_size=vocab_size, temperature=temperature)


def interpolate(lm_logits: np.ndarray, knn_p: np.ndarray,
                lam: float = 0.25) -> np.ndarray:
    """p = lam * p_knn + (1-lam) * p_lm; returns log-probs [B, V]."""
    lm = np.asarray(lm_logits, np.float32)
    lm_p = np.exp(lm - lm.max(-1, keepdims=True))
    lm_p /= lm_p.sum(-1, keepdims=True)
    return np.log(lam * knn_p + (1 - lam) * lm_p + 1e-20)
