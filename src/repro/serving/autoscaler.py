"""Signal-driven elastic autoscaler over ``ServingEngine.scale()``.

The observability layer makes per-shard load *legible* — tracked p99
service latency (``engine.tracker``) and per-shard access rates
(``stats()['access_rate_per_shard']``) — and this module closes the
loop: shards whose p99 inflates past ``p99_high_s`` or whose routing
access fraction exceeds ``access_high`` get another replica; shards
that stay below ``p99_low_s`` for ``scale_down_after`` consecutive
ticks shed one (hysteresis: a single quiet tick never triggers a
scale-down, and every action starts a per-shard cooldown so the
autoscaler cannot flap faster than new latency evidence arrives).

Deterministic by construction: all decisions happen in :meth:`tick`,
which reads the engine's current signals and calls ``engine.scale`` —
no wall-clock sleeps, no background sampling. Tests drive ``tick()``
directly and inject latency via ``engine.tracker.observe``
(``tests/test_autoscaler.py``); production wires :meth:`start` for a
thread that ticks every ``period_s``, or an engine drain hook via
:meth:`install` for the same step clock the fault schedule and the
maintenance compactor use.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import List, Optional, Tuple

from repro.obs import NULL_TRACER, MetricsRegistry


@dataclasses.dataclass
class AutoscalerConfig:
    """Thresholds and hysteresis knobs (see API.md "Observability").

    Attributes:
      min_replicas / max_replicas: hard bounds per shard.
      p99_high_s: scale UP a shard whose tracked p99 exceeds this.
      p99_low_s: a tick with p99 below this is a scale-DOWN vote.
      access_high: scale UP a shard routed to by more than this
        fraction of queries (hot-shard signal; works before latency
        degrades). ``None`` disables the access-rate trigger.
      scale_down_after: consecutive low-p99 ticks required before one
        replica is shed (the hysteresis band: between ``p99_low_s`` and
        ``p99_high_s`` nothing happens and the streak resets).
      cooldown_ticks: ticks a shard sits out after any action, so the
        next decision sees latency evidence from the NEW replica count.
    """
    min_replicas: int = 1
    max_replicas: int = 4
    p99_high_s: float = 0.5
    p99_low_s: float = 0.1
    access_high: Optional[float] = 0.9
    scale_down_after: int = 3
    cooldown_ticks: int = 2


class Autoscaler:
    """Drives ``engine.scale()`` from the engine's own signals.

    ``registry``/``tracer`` default to the engine's, so autoscaler
    counters land next to the serving counters in one ``/metrics``
    scrape and scale actions show up as instants in the query trace.
    """

    def __init__(self, engine, config: Optional[AutoscalerConfig] = None,
                 *, registry: Optional[MetricsRegistry] = None,
                 tracer=None, period_s: float = 1.0):
        self.engine = engine
        self.config = config or AutoscalerConfig()
        if self.config.min_replicas < 1:
            raise ValueError("min_replicas must be >= 1 (a shard with "
                             "zero consumers strands its queries)")
        self.period_s = period_s
        self.obs = registry if registry is not None else engine.obs
        self.tracer = tracer if tracer is not None else engine.tracer
        m = self.obs
        self._m_ticks = m.counter(
            "pyramid_autoscaler_ticks_total", "autoscaler decisions run")
        self._m_up = m.counter(
            "pyramid_autoscaler_scale_ups_total",
            "replicas added", labelnames=("shard",))
        self._m_down = m.counter(
            "pyramid_autoscaler_scale_downs_total",
            "replicas removed", labelnames=("shard",))
        self._low_streak = [0] * engine.w
        self._cooldown = [0] * engine.w
        self.actions: List[Tuple[int, str, int, str]] = []
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self._wake = threading.Event()

    # -- the decision --------------------------------------------------------

    def _signals(self, shard: int) -> Tuple[Optional[float], float]:
        p99 = self.engine.tracker.quantile(shard, 99.0)
        access = self.engine.stats()["access_rate_per_shard"][shard]
        return p99, access

    def tick(self) -> List[Tuple[int, str, int, str]]:
        """One deterministic decision pass over all shards. Returns the
        actions taken: ``(shard, "up"|"down", new_replicas, reason)``."""
        cfg = self.config
        taken: List[Tuple[int, str, int, str]] = []
        with self._lock:
            self._m_ticks.inc()
            for s in range(self.engine.w):
                if self._cooldown[s] > 0:
                    self._cooldown[s] -= 1
                    continue
                p99, access = self._signals(s)
                cur = self.engine.replica_count(s)
                hot_lat = p99 is not None and p99 > cfg.p99_high_s
                hot_acc = (cfg.access_high is not None
                           and access == access       # nan-safe
                           and access > cfg.access_high)
                if (hot_lat or hot_acc) and cur < cfg.max_replicas:
                    n = cur + 1
                    reason = (f"p99={p99:.4f}s>{cfg.p99_high_s}s"
                              if hot_lat else
                              f"access={access:.3f}>{cfg.access_high}")
                    self.engine.scale(s, n)
                    self._m_up.labels(shard=str(s)).inc()
                    self.tracer.instant("autoscaler.scale_up", shard=s,
                                        replicas=n, reason=reason)
                    self._low_streak[s] = 0
                    self._cooldown[s] = cfg.cooldown_ticks
                    taken.append((s, "up", n, reason))
                    continue
                cold = p99 is not None and p99 < cfg.p99_low_s
                if cold and cur > cfg.min_replicas:
                    self._low_streak[s] += 1
                    if self._low_streak[s] >= cfg.scale_down_after:
                        n = cur - 1
                        reason = (f"p99={p99:.4f}s<{cfg.p99_low_s}s "
                                  f"for {self._low_streak[s]} ticks")
                        self.engine.scale(s, n)
                        self._m_down.labels(shard=str(s)).inc()
                        self.tracer.instant("autoscaler.scale_down",
                                            shard=s, replicas=n,
                                            reason=reason)
                        self._low_streak[s] = 0
                        self._cooldown[s] = cfg.cooldown_ticks
                        taken.append((s, "down", n, reason))
                else:
                    # in the hysteresis band (or at min): the streak
                    # resets — scale-down needs CONSECUTIVE quiet ticks
                    self._low_streak[s] = 0
            self.actions.extend(taken)
        return taken

    # -- production drivers --------------------------------------------------

    def install(self) -> None:
        """Tick off the engine's batch-drain step clock (the same
        deterministic boundary the fault schedule and the maintenance
        compactor use). The hook runs on executor threads, so it only
        sets a wake flag; pair with :meth:`start`."""
        self.engine.add_drain_hook(self._on_drain)

    def _on_drain(self, actor: str) -> None:
        if self._running:
            self._wake.set()

    def start(self) -> "Autoscaler":
        """Background mode: tick every ``period_s`` (or when woken by an
        installed drain hook)."""
        if self._thread is not None:
            return self
        self._running = True
        self._thread = threading.Thread(target=self._loop,
                                        name="autoscaler", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._running = False
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def _loop(self) -> None:
        while self._running:
            self._wake.wait(timeout=self.period_s)
            self._wake.clear()
            if not self._running:
                return
            try:
                self.tick()
            except Exception:   # the engine may be shutting down; a
                pass            # scaler crash must never kill serving

    # -- observability -------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            return {
                "ticks": int(self._m_ticks.value),
                "actions": [list(a) for a in self.actions],
                "low_streak": list(self._low_streak),
                "cooldown": list(self._cooldown),
                "config": dataclasses.asdict(self.config),
            }
