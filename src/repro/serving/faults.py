"""Deterministic fault-injection control plane for the serving engine.

The paper's robustness figures (Fig. 12 straggler, Fig. 13 failure) and
the DIMS-style stress tests need *replayable* fault storms: the same
script of kill / restart / cpu_share events must hit the engine at the
same logical points on every run, on any machine. Wall-clock timers
cannot give that (a loaded CI box drains batches at a different rate),
so a :class:`FaultSchedule` is indexed by **batch-drain steps** instead:

  * every time any executor drains a batch from its topic it calls
    ``engine._fault_tick()`` (the paper's Kafka consumer poll boundary);
  * the tick advances one global step counter and fires every event
    whose ``step`` has been reached, exactly once;
  * the executor that triggered the tick then re-checks its own
    ``alive`` flag before searching — so a kill event aimed at it lands
    *mid-batch*, with the drained items still in hand (they are
    requeued, at-least-once).

Targets are executor names or ``fnmatch`` patterns over them
(``exec-s*-r0`` = every shard's replica-0). Schedules can be scripted
explicitly or generated from a seed (:meth:`FaultSchedule.storm`), and
record everything they fired in :attr:`FaultSchedule.fired` so a replay
can be asserted identical.

    schedule = FaultSchedule([
        FaultEvent(step=2, action="kill", target="exec-s*-r0"),
        FaultEvent(step=5, action="restart", target="exec-s0-r0"),
        FaultEvent(step=1, action="cpu_share", target="exec-s1-r1",
                   value=0.1),
    ])
    eng = ServingEngine(index, replicas=2, fault_schedule=schedule)
"""
from __future__ import annotations

import dataclasses
import fnmatch
import threading
from typing import List, Sequence, Tuple

import numpy as np

ACTIONS = ("kill", "restart", "cpu_share")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault.

    ``step`` is the 1-based global batch-drain index at which the event
    becomes due (events with ``step <= 0`` fire on the first tick).
    ``target`` is an executor name or fnmatch pattern, expanded over the
    executors registered at fire time. ``value`` is the CPU share for
    ``cpu_share`` events and ignored otherwise. ``when_actor``
    (optional pattern) defers a due event until the executor *whose
    drain ticked the schedule* matches — e.g. ``when_actor=target`` on
    a kill guarantees the victim dies mid-batch with its drained items
    in hand, rather than idle because a peer ticked first.
    """
    step: int
    action: str
    target: str
    value: float = 0.0
    when_actor: str = ""

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(
                f"unknown fault action {self.action!r}; one of {ACTIONS}")
        if self.action == "cpu_share" and not 0.0 < self.value <= 1.0:
            raise ValueError(   # share 0 would divide-by-zero the
                f"cpu_share event needs value in (0, 1], "   # throttle
                f"got {self.value}")


class FaultSchedule:
    """A step-indexed script of :class:`FaultEvent`s one engine executes.

    Thread-safe: ticks arrive concurrently from every executor thread;
    the schedule serialises them so each event fires exactly once and
    ``fired`` is a single deterministic log. A schedule instance is
    single-use (it remembers what it fired); build a fresh one per
    engine/replay.
    """

    def __init__(self, events: Sequence[FaultEvent]):
        # stable order: by step, then script order for equal steps
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: e.step))
        self.step = 0
        self.fired: List[dict] = []
        self._done_flags = [False] * len(self.events)
        self._lock = threading.Lock()

    # -- engine side -------------------------------------------------------

    def tick(self, engine, actor: str = "") -> None:
        """Advance one batch-drain step and fire every due event.

        Called by executor threads at each drain boundary (``actor`` is
        the draining executor's name); applies events through the
        engine's public fault-injection surface (``kill_executor`` /
        ``restart_executor`` / ``set_cpu_share``). A due event with
        ``when_actor`` set stays pending until a matching executor
        ticks.
        """
        with self._lock:
            self.step += 1
            for i, ev in enumerate(self.events):
                if self._done_flags[i] or ev.step > self.step:
                    continue
                if ev.when_actor and not fnmatch.fnmatch(
                        actor, ev.when_actor):
                    continue   # deferred: wrong executor's drain
                self._done_flags[i] = True
                self._apply(engine, ev)

    def _apply(self, engine, ev: FaultEvent) -> None:
        names = fnmatch.filter(sorted(engine.executors), ev.target)
        matched = []
        for name in names:
            ex = engine.executors.get(name)
            if ex is None:
                continue
            if ev.action == "kill":
                ex.kill()
            elif ev.action == "cpu_share":
                ex.cpu_share = ev.value
            elif ev.action == "restart":
                # only a dead executor may be respawned under its name
                # (restarting a live one would double the consumer);
                # ``matched`` records respawns that actually happened
                if ex.alive and ex.is_alive():
                    continue
                if not engine.restart_executor(name):
                    continue
            matched.append(name)
        self.fired.append({
            "step": self.step, "action": ev.action, "target": ev.target,
            "value": ev.value, "matched": matched})

    def done(self) -> bool:
        with self._lock:
            return all(self._done_flags)

    # -- authoring ---------------------------------------------------------

    @classmethod
    def storm(cls, seed: int, *, num_shards: int, replicas: int,
              n_events: int = 8, max_step: int = 16,
              actions: Sequence[str] = ACTIONS) -> "FaultSchedule":
        """Seeded random storm: ``n_events`` events over drain steps
        ``[1, max_step]`` aimed at uniformly-drawn executors. The same
        seed always yields the same script (assert ``s.events ==
        FaultSchedule.storm(seed, ...).events`` to prove a replay).
        """
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_events):
            action = actions[int(rng.integers(len(actions)))]
            target = (f"exec-s{int(rng.integers(num_shards))}"
                      f"-r{int(rng.integers(replicas))}")
            value = (float(rng.uniform(0.05, 1.0))
                     if action == "cpu_share" else 0.0)
            events.append(FaultEvent(int(rng.integers(1, max_step + 1)),
                                     action, target, value))
        return cls(events)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"FaultSchedule(step={self.step}, "
                f"fired={len(self.fired)}/{len(self.events)})")
