"""Sharded serving steps: prefill and single-token decode.

Cache sharding policy (adaptive to shape — see DESIGN.md §6):
  * batch dim   -> data axes when divisible (decode_32k: 128/16),
  * kv seq dim  -> model axis when the batch cannot shard (long_500k: B=1,
                   524288/16 splits the cache across chips), else replicated,
  * kv heads    -> model axis only when divisible (rare: most archs have
                   fewer kv heads than the model axis; replicated otherwise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import ArchConfig
from repro.common import sharding as S
from repro.models.transformer import forward, make_cache
from repro.train.train_step import abstract_params, param_shardings


def _batch_shardable(mesh: Mesh, batch: int) -> bool:
    n = 1
    for a in S.batch_axes(mesh):
        n *= mesh.shape[a]
    return batch % n == 0


def cache_shardings(mesh: Mesh, cfg: ArchConfig, batch: int):
    """NamedShardings for a ``make_cache``-shaped tree."""
    bax = S.batch_axes(mesh)
    bspec = (bax if len(bax) > 1 else bax[0]) if _batch_shardable(
        mesh, batch) else None
    model = S.MODEL_AXIS
    seq_spec = None if bspec is not None else model
    kv_spec = None  # kv heads rarely divide the model axis; replicate

    abstract = jax.eval_shape(lambda: make_cache(cfg, batch, max(8, getattr(cfg, "sliding_window", 8))))

    def spec_of(path_key, arr):
        name = path_key[-1]
        if name in ("k", "v"):
            seq = arr.shape[2]
            ss = seq_spec if (seq_spec is not None and
                              seq % mesh.shape[model] == 0) else None
            return P(None, bspec, ss, kv_spec, None)
        if name == "ssm":   # [slots, B, H, N, P]: heads over model
            h = arr.shape[2]
            hs = model if h % mesh.shape[model] == 0 else None
            return P(None, bspec, hs, None, None)
        if name == "conv":  # [slots, B, W-1, d_inner]
            d = arr.shape[3]
            ds = model if d % mesh.shape[model] == 0 else None
            return P(None, bspec, None, ds)
        raise KeyError(name)

    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract)
    shardings = [NamedSharding(mesh, spec_of(
        tuple(getattr(k, "key", k) for k in path), leaf))
        for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, shardings)


def token_shardings(mesh: Mesh, cfg: ArchConfig, batch: int, rank: int):
    bax = S.batch_axes(mesh)
    bspec = (bax if len(bax) > 1 else bax[0]) if _batch_shardable(
        mesh, batch) else None
    return NamedSharding(mesh, P(*([bspec] + [None] * (rank - 1))))


def decode_step(params, cache, tokens, pos, *, cfg: ArchConfig,
                mesh=None):
    """One greedy decode step.

    tokens [B, 1] (or [B, 1, F] for frontend archs); pos [B].
    Returns (next_token [B], logits [B, V], new_cache).
    """
    logits, _, new_cache = forward(
        params, cfg, tokens, cache=cache, decode_pos=pos, mesh=mesh)
    step_logits = logits[:, 0].astype(jnp.float32)
    nxt = jnp.argmax(step_logits, axis=-1).astype(jnp.int32)
    return nxt, step_logits, new_cache


def prefill_step(params, inputs, *, cfg: ArchConfig, mesh=None):
    """Prefill: returns (logits [B, S, V], cache covering S positions)."""
    logits, _, cache = forward(params, cfg, inputs, build_cache=True,
                               mesh=mesh)
    return logits, cache


def make_decode_step(mesh: Mesh, cfg: ArchConfig, *, batch: int,
                     max_seq: int):
    """jit'd decode step with explicit shardings for the mesh."""
    pshape = abstract_params(cfg)
    ps = param_shardings(mesh, cfg, pshape)
    cs = cache_shardings(mesh, cfg, batch)
    tok_rank = 3 if cfg.frontend else 2
    ts = token_shardings(mesh, cfg, batch, tok_rank)
    pos_s = token_shardings(mesh, cfg, batch, 1)
    vshard = (S.MODEL_AXIS
              if cfg.vocab_size % mesh.shape[S.MODEL_AXIS] == 0 else None)
    logits_s = NamedSharding(mesh, P(ts.spec[0], vshard))
    step = functools.partial(
        decode_step, cfg=cfg,
        mesh=mesh if _batch_shardable(mesh, batch) else None)
    return jax.jit(
        step,
        in_shardings=(ps, cs, ts, pos_s),
        out_shardings=(pos_s, logits_s, cs),
        donate_argnums=(1,),
    ), (ps, cs, ts, pos_s)


def make_prefill_step(mesh: Mesh, cfg: ArchConfig, *, batch: int,
                      seq_len: int):
    pshape = abstract_params(cfg)
    ps = param_shardings(mesh, cfg, pshape)
    tok_rank = 3 if cfg.frontend else 2
    ts = token_shardings(mesh, cfg, batch, tok_rank)
    bspec = ts.spec[0]
    vshard = (S.MODEL_AXIS
              if cfg.vocab_size % mesh.shape[S.MODEL_AXIS] == 0 else None)
    logits_s = NamedSharding(mesh, P(bspec, None, vshard))
    cs = cache_shardings(mesh, cfg, batch)
    step = functools.partial(
        prefill_step, cfg=cfg,
        mesh=mesh if _batch_shardable(mesh, batch) else None)
    return jax.jit(
        step, in_shardings=(ps, ts), out_shardings=(logits_s, cs)), (ps, ts)
