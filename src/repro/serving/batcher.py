"""Continuous batching for decode: fixed-slot scheduler over the jitted
(prefill, decode) steps.

Requests arrive asynchronously with variable-length prompts; the batcher
keeps a fixed decode batch of ``num_slots`` sequences (static shapes =>
one compiled decode step), admitting new requests into freed slots and
evicting finished ones every step — the vLLM-style scheduling loop on top
of this framework's serving substrate.

Implementation notes:
  * per-slot prefill (batch=1) writes the prompt's cache, which is then
    scattered into the shared decode cache at the slot index;
  * ring (@swa) cache groups scatter identically (slot dim is leading);
  * stop condition: max_new_tokens or an optional eos id.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ArchConfig
from repro.models.transformer import forward, grow_cache, make_cache
from repro.serving.sampler import SamplerConfig, sample


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: List[int]
    prompt_len: int
    steps: int


def scatter_slot(cache, pcache, slot: int):
    """Scatter a batch=1 prefill cache into slot ``slot`` of a shared
    decode cache (ring/@swa groups scatter identically — the slot dim
    leads every cache leaf). Shared by :class:`ContinuousBatcher` and
    the streaming engine (``repro.serving.stream``)."""
    def put(full, one):
        return full.at[:, slot].set(one[:, 0].astype(full.dtype))
    return jax.tree.map(put, cache, pcache)


class ContinuousBatcher:
    """Fixed-slot continuous batching over one model."""

    def __init__(self, params, cfg: ArchConfig, *, num_slots: int,
                 max_seq: int, sampler: SamplerConfig = SamplerConfig(
                     greedy=True), seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.sampler = sampler
        self.key = jax.random.PRNGKey(seed)

        self.cache = make_cache(cfg, num_slots, max_seq)
        self.pos = np.zeros(num_slots, np.int64)      # next write position
        self.active: List[Optional[Request]] = [None] * num_slots
        self.generated: Dict[int, List[int]] = {}
        self.steps_taken: Dict[int, int] = {}
        self.last_token = np.zeros(num_slots, np.int64)
        self.pending: List[Request] = []
        self.done: List[Completion] = []

        def _decode(params, cache, tokens, pos, key):
            logits, _, new_cache = forward(
                params, cfg, tokens, cache=cache, decode_pos=pos)
            nxt = sample(logits[:, 0], key, self.sampler)
            return nxt, new_cache

        self._decode = jax.jit(_decode)

    # -- admission -----------------------------------------------------

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _admit(self) -> None:
        for slot in range(self.num_slots):
            if self.active[slot] is not None or not self.pending:
                continue
            req = self.pending.pop(0)
            self._prefill_into_slot(slot, req)

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
        logits, pcache, _ = None, None, None
        logits, _, pcache = forward(self.params, self.cfg, prompt,
                                    build_cache=True)
        pcache = grow_cache(pcache, self.max_seq,
                            window=self.cfg.sliding_window)
        self.cache = scatter_slot(self.cache, pcache, slot)
        first = int(jnp.argmax(logits[0, -1]))
        self.active[slot] = req
        self.pos[slot] = len(req.prompt)
        self.last_token[slot] = first
        self.generated[req.request_id] = [first]
        self.steps_taken[req.request_id] = 1

    # -- decode loop -----------------------------------------------------

    def _evict_finished(self) -> None:
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            gen = self.generated[req.request_id]
            hit_eos = req.eos_id is not None and gen and gen[-1] == req.eos_id
            full = self.pos[slot] >= self.max_seq - 1
            if len(gen) >= req.max_new_tokens or hit_eos or full:
                self.done.append(Completion(
                    req.request_id, gen, len(req.prompt),
                    self.steps_taken[req.request_id]))
                self.active[slot] = None

    def step(self) -> int:
        """Admit + one decode step for all active slots. Returns the
        number of active sequences stepped."""
        self._admit()
        self._evict_finished()  # prefill may already satisfy eos/max_new
        live = [s for s in range(self.num_slots)
                if self.active[s] is not None]
        if not live:
            return 0
        tokens = jnp.asarray(self.last_token[:, None], jnp.int32)
        pos = jnp.asarray(self.pos, jnp.int32)
        self.key, sub = jax.random.split(self.key)
        nxt, self.cache = self._decode(self.params, self.cache, tokens,
                                       pos, sub)
        nxt = np.asarray(nxt)
        for slot in live:
            req = self.active[slot]
            self.generated[req.request_id].append(int(nxt[slot]))
            self.steps_taken[req.request_id] += 1
            self.pos[slot] += 1
            self.last_token[slot] = int(nxt[slot])
        self._evict_finished()
        return len(live)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Completion]:
        steps = 0
        while (self.pending or any(a is not None for a in self.active)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.done
