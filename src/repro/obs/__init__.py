"""Unified observability layer: metrics registry, per-query trace
spans, exposition endpoints, and runtime logging.

One import surface for the four pieces (see API.md "Observability"):

  * :class:`MetricsRegistry` / :func:`get_registry` — counters, gauges,
    fixed-bucket histograms; Prometheus text + JSON snapshot export;
  * :class:`Tracer` / :data:`NULL_TRACER` — per-query spans with
    explicit parent/child causality, Chrome ``trace_event`` export;
  * :class:`StatsServer` — ``/metrics`` (Prometheus) + ``/stats``
    (JSON) HTTP endpoint;
  * :func:`get_logger` — the logging tree all CLI output routes
    through (bare ``print`` in ``src/`` is banned by ruff T201).
"""
from repro.obs.logs import get_logger
from repro.obs.registry import (LATENCY_BUCKETS, Counter, Gauge, Histogram,
                                MetricsRegistry, get_registry)
from repro.obs.stats_server import StatsServer
from repro.obs.trace import (NULL_TRACER, Span, Tracer,
                             validate_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "LATENCY_BUCKETS",
    "MetricsRegistry", "NULL_TRACER", "Span", "StatsServer", "Tracer",
    "get_logger", "get_registry", "validate_chrome_trace",
]
