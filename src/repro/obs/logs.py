"""Runtime logging for CLI / launch output.

All runtime text output in ``src/`` routes through here instead of bare
``print`` (enforced by ruff's flake8-print ``T201`` rule, see
``ruff.toml``): a ``repro``-rooted ``logging`` tree with one stdout
handler, message-only formatting (CLI output looks exactly like the
prints it replaced), and an env override for verbosity::

    from repro.obs import get_logger
    log = get_logger(__name__)
    log.info("[serve] decoded %d tokens", n)

``REPRO_LOG_LEVEL=DEBUG`` (or any level name) raises/lowers the tree's
threshold. Libraries embedding repro can detach the handler with
``logging.getLogger("repro").handlers.clear()`` and route records into
their own stack — which a bare ``print`` never allows.
"""
from __future__ import annotations

import logging
import os
import sys
import threading

_ROOT = "repro"
_lock = threading.Lock()
_configured = False


def _configure() -> None:
    global _configured
    with _lock:
        if _configured:
            return
        root = logging.getLogger(_ROOT)
        if not root.handlers:   # respect an embedding app's own setup
            handler = logging.StreamHandler(sys.stdout)
            handler.setFormatter(logging.Formatter("%(message)s"))
            root.addHandler(handler)
            root.propagate = False
        level = os.environ.get("REPRO_LOG_LEVEL", "INFO").upper()
        root.setLevel(getattr(logging, level, logging.INFO))
        _configured = True


def get_logger(name: str) -> logging.Logger:
    """A logger under the ``repro`` tree (lazy one-time handler setup).
    ``name`` is typically ``__name__``; non-repro names are nested
    under ``repro.`` so the single handler covers them."""
    _configure()
    if name != _ROOT and not name.startswith(_ROOT + "."):
        name = f"{_ROOT}.{name}"
    return logging.getLogger(name)
