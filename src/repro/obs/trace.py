"""Per-query trace spans with Chrome ``trace_event`` export.

A :class:`Tracer` records :class:`Span` records — name, span id, parent
id, start/end time, thread — into a bounded ring buffer. The serving
stack threads spans through the whole query path (client submit →
coordinator route → per-shard dispatch/hedge → executor batch drain →
beam-walk kernel call → merge → rerank → future resolve) plus the
streaming decode loop and maintenance compaction cycles, so one trace
shows exactly where a query's latency went and which recovery machinery
touched it.

Causality is explicit: a span's ``parent_id`` links it to the span that
caused it, across threads — a hedge re-dispatch span is a child of its
query's root span even though the merger thread emitted it, an executor
respawn span is a child of the monitor's recovery span for that death.
Within one thread, ``tracer.span(...)`` context managers nest
implicitly (a thread-local stack supplies the parent).

Determinism: the tracer takes an injectable monotonic ``clock`` — under
a :class:`repro.serving.faults.FaultSchedule` replay with a scripted
clock the span set and its parent/child edges are reproducible (span
ids come from one atomic counter; timestamps come from the clock).

Export: :meth:`Tracer.chrome_trace` emits Chrome ``trace_event`` JSON
(the ``{"traceEvents": [...]}`` object form) loadable by Perfetto /
``chrome://tracing`` — complete (``ph: "X"``) events carry the span id
and parent id in ``args`` so causality survives the format.
:func:`validate_chrome_trace` checks the schema; ``launch/serve
--trace-out`` writes a validated file.

Cost: ``NULL_TRACER`` (the default everywhere) is a shared no-op whose
``span()`` returns a reusable null context manager — the disabled hot
path is one attribute lookup and one method call (gated by
``benchmarks/bench_gate.py --obs-overhead``).
"""
from __future__ import annotations

import collections
import itertools
import json
import threading
import time
from typing import Dict, List, Optional


class Span:
    """One finished (or in-flight) span. ``attrs`` are free-form
    key/values surfaced as Chrome trace ``args``."""

    __slots__ = ("name", "span_id", "parent_id", "t0", "t1", "thread",
                 "attrs")

    def __init__(self, name: str, span_id: int, parent_id: Optional[int],
                 t0: float, thread: str, attrs: dict):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0
        self.t1: Optional[float] = None
        self.thread = thread
        self.attrs = attrs

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Span({self.name!r}, id={self.span_id}, "
                f"parent={self.parent_id}, dur={self.duration:.6f})")


class _NullSpan:
    """Reusable no-op context manager; also stands in for a Span handle
    (``span_id`` of a null span is ``None``, which ``start`` accepts as
    "no parent")."""

    __slots__ = ()
    span_id = None
    parent_id = None
    name = ""
    attrs: dict = {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        pass


_NULL_SPAN = _NullSpan()


class _SpanCtx:
    """Context-manager handle pairing a live Span with its tracer (and
    the thread-local parent stack, resolved once at creation — the
    enter/exit fast path must not repay the thread-local lookup)."""

    __slots__ = ("tracer", "span", "stack")

    def __init__(self, tracer: "Tracer", span: Span, stack: list):
        self.tracer = tracer
        self.span = span
        self.stack = stack

    @property
    def span_id(self) -> int:
        return self.span.span_id

    def set(self, **attrs) -> None:
        self.span.attrs.update(attrs)

    def __enter__(self) -> "_SpanCtx":
        self.stack.append(self.span)
        return self

    def __exit__(self, *exc) -> bool:
        span = self.span
        stack = self.stack
        if stack and stack[-1] is span:
            stack.pop()
        span.t1 = self.tracer.clock()
        self.tracer._spans.append(span)
        return False


class Tracer:
    """Bounded-buffer span recorder.

    Args:
      clock: monotonic-seconds callable; inject a scripted clock for
        deterministic replay traces (default ``time.monotonic``).
      capacity: finished-span ring size (oldest spans drop first).
      enabled: a disabled tracer records nothing but keeps the same
        surface; prefer the shared :data:`NULL_TRACER` for "off".
    """

    def __init__(self, clock=time.monotonic, capacity: int = 65536,
                 enabled: bool = True):
        self.enabled = enabled
        self.clock = clock
        self._ids = itertools.count(1)
        # the finished-span ring is lock-free: deque.append and
        # list(deque) are single C calls, atomic under the GIL, so the
        # hot path never contends executor/merger threads on a mutex
        self._spans: collections.deque = collections.deque(maxlen=capacity)
        self._local = threading.local()
        self._t_origin = clock()

    # -- recording ---------------------------------------------------------

    def _stack(self) -> list:
        local = self._local
        stack = getattr(local, "stack", None)
        if stack is None:
            stack = local.stack = []
        return stack

    def _tname(self) -> str:
        local = self._local
        tname = getattr(local, "tname", None)
        if tname is None:
            tname = local.tname = threading.current_thread().name
        return tname

    def current(self) -> Optional[Span]:
        """The innermost open ``span()`` on this thread, if any."""
        stack = self._stack()
        return stack[-1] if stack else None

    def start(self, name: str, parent: Optional[int] = None,
              **attrs) -> Span:
        """Open a span explicitly (cross-thread handle: stash the
        returned span, ``end()`` it later, quote ``span.span_id`` as
        another span's ``parent``). ``parent=None`` inherits this
        thread's innermost open span."""
        if not self.enabled:
            return _NULL_SPAN
        if parent is None:
            stack = self._stack()
            if stack:
                parent = stack[-1].span_id
        return Span(name, next(self._ids), parent, self.clock(),
                    self._tname(), attrs)

    def end(self, span) -> None:
        if span is _NULL_SPAN or not self.enabled:
            return
        span.t1 = self.clock()
        self._spans.append(span)

    def span(self, name: str, parent: Optional[int] = None, **attrs):
        """Context manager form; nests via the thread-local stack."""
        if not self.enabled:
            return _NULL_SPAN
        stack = self._stack()
        if parent is None and stack:
            parent = stack[-1].span_id
        span = Span(name, next(self._ids), parent, self.clock(),
                    self._tname(), attrs)
        return _SpanCtx(self, span, stack)

    def instant(self, name: str, parent: Optional[int] = None,
                **attrs) -> None:
        """Zero-duration marker (rendered as a Chrome instant event)."""
        if not self.enabled:
            return
        span = self.start(name, parent, **attrs)
        span.t1 = span.t0
        self._spans.append(span)

    # -- reading / export --------------------------------------------------

    def snapshot(self) -> List[Span]:
        return list(self._spans)

    def find(self, name: str) -> List[Span]:
        return [s for s in self.snapshot() if s.name == name]

    def by_id(self) -> Dict[int, Span]:
        return {s.span_id: s for s in self.snapshot()}

    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` JSON (object form) — load in Perfetto
        or ``chrome://tracing``. Spans become complete (``"ph": "X"``)
        events; zero-duration spans become instants (``"ph": "i"``);
        thread names ride on ``"M"`` metadata events."""
        events = []
        tids: Dict[str, int] = {}
        for span in self.snapshot():
            tid = tids.setdefault(span.thread, len(tids) + 1)
            args = {"span_id": span.span_id}
            if span.parent_id is not None:
                args["parent_id"] = span.parent_id
            args.update(span.attrs)
            ev = {
                "name": span.name,
                "cat": span.name.split(".", 1)[0],
                "pid": 1,
                "tid": tid,
                "ts": round(1e6 * (span.t0 - self._t_origin), 3),
                "args": args,
            }
            if span.t1 is not None and span.t1 > span.t0:
                ev["ph"] = "X"
                ev["dur"] = round(1e6 * (span.t1 - span.t0), 3)
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            events.append(ev)
        for thread, tid in sorted(tids.items(), key=lambda kv: kv[1]):
            events.append({"name": "thread_name", "ph": "M", "pid": 1,
                           "tid": tid, "args": {"name": thread}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome(self, path: str) -> dict:
        payload = self.chrome_trace()
        validate_chrome_trace(payload)
        with open(path, "w") as f:
            json.dump(payload, f)
        return payload


class _NullTracer(Tracer):
    """Shared disabled tracer: every entry point is a constant-work
    no-op (no clock call, no allocation)."""

    def __init__(self):
        super().__init__(clock=lambda: 0.0, capacity=1, enabled=False)

    def start(self, name, parent=None, **attrs):
        return _NULL_SPAN

    def end(self, span):
        pass

    def span(self, name, parent=None, **attrs):
        return _NULL_SPAN

    def instant(self, name, parent=None, **attrs):
        pass


NULL_TRACER = _NullTracer()


def validate_chrome_trace(payload: dict) -> None:
    """Assert ``payload`` is schema-valid Chrome ``trace_event`` JSON
    (object form with a ``traceEvents`` list; every event carries the
    required keys with the right types; ``X`` events have a
    non-negative ``dur``; instants carry a valid scope). Raises
    ``ValueError`` with the first offending event."""
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        raise ValueError("chrome trace must be an object with "
                         "'traceEvents'")
    events = payload["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        for key, types in (("name", str), ("ph", str), ("pid", int),
                           ("tid", int)):
            if not isinstance(ev.get(key), types):
                raise ValueError(
                    f"traceEvents[{i}] missing/invalid {key!r}: {ev}")
        ph = ev["ph"]
        if ph == "M":
            continue
        if not isinstance(ev.get("ts"), (int, float)):
            raise ValueError(f"traceEvents[{i}] missing numeric 'ts'")
        if ph == "X":
            if not (isinstance(ev.get("dur"), (int, float))
                    and ev["dur"] >= 0):
                raise ValueError(
                    f"traceEvents[{i}] 'X' event needs dur >= 0")
        elif ph == "i":
            if ev.get("s", "t") not in ("g", "p", "t"):
                raise ValueError(
                    f"traceEvents[{i}] instant scope must be g/p/t")
        else:
            raise ValueError(
                f"traceEvents[{i}] unsupported phase {ph!r} (exporter "
                "emits X/i/M only)")
