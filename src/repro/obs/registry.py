"""Process-wide metrics registry: counters, gauges, fixed-bucket
histograms.

One schema for every signal the serving stack emits — the engine's
hedge/expiry/restart counters, per-shard latency histograms, stream
decode throughput, maintenance cycle counts — instead of the scattered
one-off dicts they used to live in. The registry is the single source
of truth: ``ServingEngine.stats()`` *reads* these counters rather than
keeping parallel attributes, so the Prometheus text endpoint
(``repro.obs.stats_server``) and ``stats()`` can never disagree.

Design:

  * thread-safe — every mutation takes the metric's own lock (never a
    registry-wide one on the hot path);
  * near-zero-cost when disabled — ``MetricsRegistry(enabled=False)``
    hands out shared no-op metric singletons, so instrumented code pays
    one attribute call and nothing else (measured in
    ``benchmarks/bench_gate.py --obs-overhead``). A disabled registry
    records NOTHING: engine ``stats()`` counters read back 0;
  * labels — a metric created with ``labelnames`` is a family;
    ``metric.labels(shard="3")`` returns (and caches) the child;
  * idempotent registration — asking for an existing name returns the
    existing collector (type and labelnames must match), so an engine
    hot-swap can re-bind onto a shared registry and counters keep their
    Prometheus monotonic-counter semantics across swaps.

Exposition: :meth:`MetricsRegistry.render_prometheus` emits the
Prometheus text format (``name{label="v"} value`` with
``_bucket``/``_sum``/``_count`` series for histograms);
:meth:`MetricsRegistry.snapshot` returns the same data as a
JSON-friendly dict (what the benchmark ``--metrics`` flags embed in
their BENCH artifacts).
"""
from __future__ import annotations

import bisect
import threading
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

# default histogram buckets: serving latencies from 100us to 10s
LATENCY_BUCKETS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
                   0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

_INF = float("inf")


def _label_key(labelnames: Tuple[str, ...], labels: dict
               ) -> Tuple[str, ...]:
    try:
        return tuple(str(labels[n]) for n in labelnames)
    except KeyError as e:
        raise ValueError(
            f"metric expects labels {labelnames}, got "
            f"{sorted(labels)}") from e


def _fmt_labels(labelnames: Sequence[str], values: Sequence[str],
                extra: str = "") -> str:
    parts = [f'{n}="{v}"' for n, v in zip(labelnames, values)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class Counter:
    """Monotonic counter; ``inc`` only. A labeled family's children are
    reached via :meth:`labels`."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labelnames: Tuple[str, ...] = ()):
        self.name = name
        self.help = help
        self.labelnames = labelnames
        self._lock = threading.Lock()
        self._value = 0.0
        self._children: Dict[Tuple[str, ...], "Counter"] = {}

    def labels(self, **labels) -> "Counter":
        key = _label_key(self.labelnames, labels)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = Counter(self.name, self.help)
                self._children[key] = child
            return child

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    # -- exposition --------------------------------------------------------

    def _series(self) -> Iterable[Tuple[Tuple[str, ...], float]]:
        if self.labelnames:
            with self._lock:
                items = list(self._children.items())
            for key, child in sorted(items):
                yield key, child.value
        else:
            yield (), self.value

    def render(self) -> Iterable[str]:
        for key, v in self._series():
            yield (f"{self.name}"
                   f"{_fmt_labels(self.labelnames, key)} {_num(v)}")

    def to_dict(self) -> list:
        return [{"labels": dict(zip(self.labelnames, key)), "value": v}
                for key, v in self._series()]


class Gauge(Counter):
    """Settable instantaneous value. Alternatively collected lazily: a
    ``fn`` returning a scalar (no labels) or ``{(label values): scalar}``
    is called at scrape/snapshot time — how the engine exposes queue
    depths and heartbeat staleness without a poller thread."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labelnames: Tuple[str, ...] = (),
                 fn: Optional[Callable] = None):
        super().__init__(name, help, labelnames)
        self.fn = fn

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    def _series(self):
        if self.fn is not None:
            out = self.fn()
            if isinstance(out, dict):
                for key, v in sorted(out.items()):
                    key = (key,) if isinstance(key, str) else tuple(
                        str(k) for k in key)
                    yield key, float(v)
            else:
                yield (), float(out)
            return
        yield from super()._series()


class Histogram:
    """Fixed-bucket histogram (cumulative counts, Prometheus ``le``
    semantics) plus exact ``sum``/``count``. Buckets are chosen at
    registration; observations beyond the last bound land in ``+Inf``."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Tuple[str, ...] = (),
                 buckets: Sequence[float] = LATENCY_BUCKETS):
        self.name = name
        self.help = help
        self.labelnames = labelnames
        self.buckets = tuple(sorted(buckets))
        if not self.buckets:
            raise ValueError(f"histogram {name} needs >= 1 bucket")
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.buckets) + 1)   # +1: +Inf
        self._sum = 0.0
        self._count = 0
        self._children: Dict[Tuple[str, ...], "Histogram"] = {}

    def labels(self, **labels) -> "Histogram":
        key = _label_key(self.labelnames, labels)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = Histogram(self.name, self.help,
                                  buckets=self.buckets)
                self._children[key] = child
            return child

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    # -- exposition --------------------------------------------------------

    def _snap(self) -> Tuple[list, float, int]:
        with self._lock:
            return list(self._counts), self._sum, self._count

    def _series(self):
        if self.labelnames:
            with self._lock:
                items = list(self._children.items())
            for key, child in sorted(items):
                yield key, child._snap()
        else:
            yield (), self._snap()

    def render(self) -> Iterable[str]:
        for key, (counts, total, count) in self._series():
            cum = 0
            for le, c in zip(self.buckets + (_INF,), counts):
                cum += c
                le_s = "+Inf" if le == _INF else _num(le)
                lbl = _fmt_labels(self.labelnames, key, f'le="{le_s}"')
                yield f"{self.name}_bucket{lbl} {cum}"
            lbl = _fmt_labels(self.labelnames, key)
            yield f"{self.name}_sum{lbl} {_num(total)}"
            yield f"{self.name}_count{lbl} {count}"

    def to_dict(self) -> list:
        out = []
        for key, (counts, total, count) in self._series():
            cum, rows = 0, []
            for le, c in zip(self.buckets + (_INF,), counts):
                cum += c
                rows.append([le if le != _INF else "inf", cum])
            out.append({"labels": dict(zip(self.labelnames, key)),
                        "buckets": rows, "sum": total, "count": count})
        return out


def _num(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else repr(float(v))


class _NullMetric:
    """Shared do-nothing stand-in handed out by a disabled registry —
    instrumented code pays a method call and nothing else."""

    kind = "null"
    name = help = ""
    labelnames: Tuple[str, ...] = ()
    value = 0.0

    def labels(self, **labels):
        return self

    def inc(self, n: float = 1.0) -> None:
        pass

    def dec(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def render(self):
        return ()

    def to_dict(self):
        return []


NULL_METRIC = _NullMetric()

_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Collector registry. Engines create a private one by default (so
    per-engine ``stats()`` stays per-engine); pass one explicitly to
    share counters across components — e.g. one registry for an engine
    plus its compactor plus the stream engine decoding over it, scraped
    by one :class:`repro.obs.stats_server.StatsServer`."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _register(self, cls, name: str, help: str,
                  labelnames: Sequence[str], **kw):
        if not self.enabled:
            return NULL_METRIC
        labelnames = tuple(labelnames)
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if (type(existing) is not cls
                        or existing.labelnames != labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{existing.kind} with labels "
                        f"{existing.labelnames}")
                return existing
            m = cls(name, help, labelnames, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = (),
              fn: Optional[Callable] = None) -> Gauge:
        g = self._register(Gauge, name, help, labelnames)
        if fn is not None and g is not NULL_METRIC:
            g.fn = fn
        return g

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, labelnames,
                              buckets=buckets)

    def collect(self) -> list:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines = []
        for m in self.collect():
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            lines.extend(m.render())
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-friendly dump of every collector (the BENCH ``--metrics``
        embedding)."""
        return {m.name: {"type": m.kind, "help": m.help,
                         "series": m.to_dict()}
                for m in self.collect()}


# the process-wide default registry: shared by components that opt in
# via get_registry() (engines default to a PRIVATE registry instead so
# two engines in one process never mix counters)
_GLOBAL = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _GLOBAL
