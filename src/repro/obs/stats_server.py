"""HTTP exposition endpoint: Prometheus text metrics + JSON stats.

A tiny stdlib ``http.server`` wrapper (no new dependencies) serving:

  * ``GET /metrics`` — the registry's Prometheus text exposition
    (``Content-Type: text/plain; version=0.0.4``), scrape-ready;
  * ``GET /stats``   — a JSON document merging every registered stats
    provider (e.g. ``engine.stats``), for humans and dashboards;
  * ``GET /healthz`` — liveness probe (``ok``).

Usage::

    server = StatsServer(registry, port=9100)
    server.add_stats_provider("engine", engine.stats)
    server.start()                      # daemon thread
    ...
    server.stop()

``port=0`` binds an ephemeral port (``server.port`` reports the real
one) — what the tests use so parallel CI lanes never collide.
"""
from __future__ import annotations

import json
import logging
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, Optional

from repro.obs.registry import MetricsRegistry

logger = logging.getLogger(__name__)


def _default(obj):
    """JSON fallback for numpy scalars/arrays inside stats dicts."""
    try:
        import numpy as np
        if isinstance(obj, np.ndarray):
            return obj.tolist()
        if isinstance(obj, np.generic):
            return obj.item()
    except ImportError:  # pragma: no cover - numpy is a hard dep here
        pass
    return repr(obj)


class StatsServer:
    """Serve one :class:`MetricsRegistry` (plus optional JSON stats
    providers) over HTTP. Start/stop are idempotent; the listener is a
    daemon ``ThreadingHTTPServer`` so a scrape can never block serving.
    """

    def __init__(self, registry: MetricsRegistry, *, host: str = "0.0.0.0",
                 port: int = 0):
        self.registry = registry
        self.host = host
        self._port = port
        self._providers: Dict[str, Callable[[], dict]] = {}
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        """The bound port (resolves ``port=0`` after :meth:`start`)."""
        return (self._httpd.server_address[1] if self._httpd
                else self._port)

    def add_stats_provider(self, name: str,
                           fn: Callable[[], dict]) -> None:
        self._providers[name] = fn

    def stats(self) -> dict:
        out = {}
        for name, fn in list(self._providers.items()):
            try:
                out[name] = fn()
            except Exception as e:   # a dead provider must not 500 the
                out[name] = {"error": repr(e)}   # whole endpoint
        return out

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "StatsServer":
        if self._httpd is not None:
            return self
        server = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt, *args):   # route through logging,
                logger.debug("stats_server: " + fmt, *args)   # not stderr

            def _send(self, code: int, body: str, ctype: str) -> None:
                data = body.encode()
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def do_GET(self):
                path = self.path.split("?", 1)[0]
                try:
                    if path == "/metrics":
                        self._send(200, server.registry.render_prometheus(),
                                   "text/plain; version=0.0.4; "
                                   "charset=utf-8")
                    elif path == "/stats":
                        self._send(200,
                                   json.dumps(server.stats(),
                                              default=_default),
                                   "application/json")
                    elif path == "/healthz":
                        self._send(200, "ok\n", "text/plain")
                    else:
                        self._send(404, f"unknown path {path}\n",
                                   "text/plain")
                except BrokenPipeError:   # client went away mid-write
                    pass

        self._httpd = ThreadingHTTPServer((self.host, self._port), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="stats-server",
            daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._httpd is None:
            return
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self._httpd = self._thread = None

    def __enter__(self) -> "StatsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
