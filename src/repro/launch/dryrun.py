import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input shape) on the
production meshes, and extract the roofline terms from the compiled
artifact (deliverables (e) and (g)).

MUST be run as its own process (the XLA flag above binds at first jax
init): ``PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b
--shape train_4k [--multi-pod] [--out artifacts/dryrun]``.

Per combo it records a JSON artifact with:
  * compiled cost_analysis flops / bytes accessed,
  * per-device peak memory from memory_analysis,
  * collective bytes by op kind, parsed from the post-SPMD HLO
    (convention: the *output* shape bytes of each collective op),
  * the three roofline terms in seconds for the hardware model
    (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI),
  * MODEL_FLOPS = 6*N*D (6*N_active*D for MoE) and the useful-compute
    ratio MODEL_FLOPS / HLO_FLOPs.
"""
import argparse
import dataclasses
import json
import re
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import INPUT_SHAPES, ArchConfig, InputShape
from repro.common.registry import get_arch, list_archs
from repro.launch.mesh import make_production_mesh
from repro.obs import get_logger

log = get_logger(__name__)

# hardware model (TPU v5e)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# combos skipped by design (DESIGN.md §Arch-applicability)
LONG_CONTEXT_OK = ("h2o-danube-1.8b", "zamba2-7b", "gemma3-12b",
                   "mamba2-780m")


def skip_reason(cfg: ArchConfig, shape: InputShape) -> Optional[str]:
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_OK:
        return ("pure full-attention arch: long_500k requires sub-quadratic "
                "attention (DESIGN.md §Arch-applicability)")
    return None


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend:
        tok = jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.float32)
        tok1 = jax.ShapeDtypeStruct((b, 1, cfg.frontend_dim), jnp.float32)
    else:
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        tok1 = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tgt = jax.ShapeDtypeStruct((b, s), jnp.int32)
    msk = jax.ShapeDtypeStruct((b, s), jnp.float32)
    if shape.mode == "train":
        return {"batch": {"inputs": tok, "targets": tgt, "mask": msk}}
    if shape.mode == "prefill":
        return {"inputs": tok}
    if shape.mode == "decode":
        from repro.models.transformer import make_cache
        cache = jax.eval_shape(lambda: make_cache(cfg, b, s))
        pos = jax.ShapeDtypeStruct((b,), jnp.int32)
        return {"cache": cache, "tokens": tok1, "pos": pos}
    raise ValueError(shape.mode)


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------


def lower_combo(cfg: ArchConfig, shape: InputShape, mesh):
    """Returns the jax ``Lowered`` for the combo's step function."""
    from repro.serving.decode import (cache_shardings, make_decode_step,
                                      make_prefill_step, token_shardings)
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import (abstract_params, make_train_step,
                                        opt_shardings, param_shardings)
    from repro.train.optimizer import OptState

    pshape = abstract_params(cfg)
    with mesh:
        if shape.mode == "train":
            step, (ps, os_, bs) = make_train_step(
                mesh, cfg, AdamWConfig())
            params = jax.tree.map(
                lambda x, sh: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                                   sharding=sh),
                pshape, ps)
            opt_abs = jax.eval_shape(
                lambda p: OptState(
                    step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(
                        lambda a: jnp.zeros(a.shape, jnp.float32), p),
                    nu=jax.tree.map(
                        lambda a: jnp.zeros(a.shape, jnp.float32), p)),
                pshape)
            opt = jax.tree.map(
                lambda x, sh: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                                   sharding=sh),
                opt_abs, os_)
            batch = jax.tree.map(
                lambda x, sh: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                                   sharding=sh),
                input_specs(cfg, shape)["batch"], bs)
            return step.lower(params, opt, batch)

        if shape.mode == "prefill":
            step, (ps, ts) = make_prefill_step(
                mesh, cfg, batch=shape.global_batch, seq_len=shape.seq_len)
            params = jax.tree.map(
                lambda x, sh: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                                   sharding=sh),
                pshape, ps)
            spec = input_specs(cfg, shape)
            inputs = jax.ShapeDtypeStruct(
                spec["inputs"].shape, spec["inputs"].dtype, sharding=ts)
            return step.lower(params, inputs)

        # decode
        step, (ps, cs, ts, pos_s) = make_decode_step(
            mesh, cfg, batch=shape.global_batch, max_seq=shape.seq_len)
        params = jax.tree.map(
            lambda x, sh: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                               sharding=sh), pshape, ps)
        spec = input_specs(cfg, shape)
        cache = jax.tree.map(
            lambda x, sh: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                               sharding=sh),
            spec["cache"], cs)
        tokens = jax.ShapeDtypeStruct(spec["tokens"].shape,
                                      spec["tokens"].dtype, sharding=ts)
        pos = jax.ShapeDtypeStruct(spec["pos"].shape, spec["pos"].dtype,
                                   sharding=pos_s)
        return step.lower(params, cache, tokens, pos)


# ---------------------------------------------------------------------------
# artifact extraction
# ---------------------------------------------------------------------------

_HLO_SHAPE_RE = re.compile(
    r"=\s+([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+(" +
    "|".join(k.replace("-", "[-]") for k in COLLECTIVE_KINDS) + r")[\s(]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HEAD_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
    return comps


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Collective bytes by kind, **trip-count aware**.

    XLA's cost/byte attribution counts a while-loop body once, but every
    ``lax.scan`` over layers executes it L times. We split the module into
    computations, find ``while`` ops with their condition/body names, take
    the largest integer constant in the condition as the trip count (the
    scan bound — heuristic, documented in EXPERIMENTS.md), and multiply
    collective bytes inside each body accordingly (recursively, so chunked
    attention scans nested in layer scans compound).
    Convention: a collective's cost is its *output-shape* bytes.
    """
    comps = _split_computations(hlo_text)

    def direct_bytes(lines):
        out = {k: 0 for k in COLLECTIVE_KINDS}
        counts = {k: 0 for k in COLLECTIVE_KINDS}
        for line in lines:
            m = _HLO_SHAPE_RE.search(line)
            if not m:
                continue
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            if dtype not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            out[kind] += n * _DTYPE_BYTES[dtype]
            counts[kind] += 1
        return out, counts

    def trip_count(cond_name: str) -> int:
        consts = []
        for line in comps.get(cond_name, []):
            consts += [int(c) for c in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    memo: Dict[str, Dict[str, int]] = {}

    def total(name: str, stack=()) -> Dict[str, int]:
        if name in memo:
            return memo[name]
        if name in stack:  # defensive: no recursion in valid HLO
            return {k: 0 for k in COLLECTIVE_KINDS}
        lines = comps.get(name, [])
        out, _ = direct_bytes(lines)
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = trip_count(cond)
                sub = total(body, stack + (name,))
                for k in COLLECTIVE_KINDS:
                    out[k] += trips * sub[k]
        memo[name] = out
        return out

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEAD_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: flat sum (no loop multiplication)
        out, counts = direct_bytes(hlo_text.splitlines())
        out["counts"] = counts
        return out
    out = dict(total(entry))
    _, counts = direct_bytes(hlo_text.splitlines())
    out["counts"] = counts
    return out


def model_flops(cfg: ArchConfig, shape: InputShape) -> float:
    """6*N*D (N_active for MoE); D = tokens processed by the step."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens   # forward only
    tokens = shape.global_batch   # one token per sequence
    return 2.0 * n * tokens


def analytic_flops(cfg: ArchConfig, shape: InputShape) -> float:
    """Analytic step FLOPs: param math + attention + SSD scan.

    XLA's cost_analysis counts while-loop bodies ONCE, so its FLOPs for a
    scanned-layer model are ~L x too small; the compute roofline term uses
    this analytic count instead (EXPERIMENTS.md §Roofline methodology).
    Training factor 4 = fwd + 2x bwd + ~1x remat recompute.
    """
    from repro.common.config import AttentionKind, BlockKind, SSMConfig
    b, s = shape.global_batch, shape.seq_len
    if shape.mode == "train":
        tokens, factor = b * s, 4.0
    elif shape.mode == "prefill":
        tokens, factor = b * s, 1.0
    else:
        tokens, factor = b, 1.0

    total = 2.0 * cfg.active_param_count() * tokens * factor

    hd = cfg.resolved_head_dim
    for idx, kind in enumerate(cfg.layer_kinds()):
        if kind in (BlockKind.ATTENTION, BlockKind.SHARED_ATTENTION):
            if shape.mode == "decode":
                ctx = float(s)
            elif cfg.attention_kind == AttentionKind.SLIDING:
                ctx = min(float(s) / 2, cfg.sliding_window)
            elif cfg.attention_kind == AttentionKind.LOCAL_GLOBAL:
                r = cfg.local_to_global_ratio
                is_global = (idx % (r + 1)) == r if r else True
                ctx = float(s) / 2 if is_global else min(
                    float(s) / 2, cfg.sliding_window)
            else:
                ctx = float(s) / 2  # causal average
            # QK^T and PV: 2 matmuls of [tokens, ctx] x hd per head
            total += 4.0 * tokens * ctx * cfg.num_heads * hd * factor
        elif kind == BlockKind.MAMBA2:
            scfg = cfg.ssm or SSMConfig()
            d_in = scfg.expand * cfg.d_model
            # SSD: B/C state projections plus intra-chunk matmuls
            total += 6.0 * tokens * d_in * scfg.state_dim * factor
    return total


def analytic_bytes(cfg: ArchConfig, shape: InputShape,
                   num_chips: int) -> float:
    """Analytic per-chip HBM traffic per step (napkin model, documented):

      weights: fwd reads params once (bf16); train adds grad write/read +
               f32 Adam m/v read+write + param write  (~22 bytes/param);
      activations: C_act * tokens * d_model * 2B per layer (C_act = 16
               train incl. remat recompute, 6 fwd-only);
      kv/ssm caches (decode): full cache read + point write.
    All sharded terms divide by the chip count.
    """
    from repro.common.config import BlockKind, SSMConfig
    b, s = shape.global_batch, shape.seq_len
    n_params = cfg.param_count()
    if shape.mode == "train":
        tokens, w_bytes, c_act = b * s, 22.0, 16.0
    elif shape.mode == "prefill":
        tokens, w_bytes, c_act = b * s, 2.0, 6.0
    else:
        tokens, w_bytes, c_act = b, 2.0, 6.0

    total = n_params * w_bytes
    total += c_act * tokens * cfg.d_model * 2.0 * cfg.num_layers

    if shape.mode == "decode":
        kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        for kind in cfg.layer_kinds():
            if kind in (BlockKind.ATTENTION, BlockKind.SHARED_ATTENTION):
                total += 2.0 * b * s * kvh * hd * 2.0   # read k+v cache
            elif kind == BlockKind.MAMBA2:
                scfg = cfg.ssm or SSMConfig()
                d_in = scfg.expand * cfg.d_model
                total += 2.0 * b * (d_in // scfg.head_dim) * \
                    scfg.state_dim * scfg.head_dim * 4.0  # rw ssm state
    return total / num_chips


def analyse(lowered, compiled, cfg: ArchConfig, shape: InputShape,
            num_chips: int) -> Dict:
    cost = compiled.cost_analysis()
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    coll_total = sum(v for k, v in coll.items() if k != "counts")

    # Roofline terms. compute/memory use the analytic estimators because
    # XLA cost_analysis counts while-loop (scan) bodies once (~L x under-
    # count for scanned layers); the collective term uses the trip-count-
    # aware HLO parse (real compiled structure). All terms are per chip.
    a_flops = analytic_flops(cfg, shape)
    a_bytes = analytic_bytes(cfg, shape, num_chips)
    compute_s = a_flops / num_chips / PEAK_FLOPS
    memory_s = a_bytes / HBM_BW
    collective_s = coll_total / ICI_BW

    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes": int(ma.argument_size_in_bytes +
                              ma.temp_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    mf = model_flops(cfg, shape)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    return {
        "arch": cfg.name,
        "shape": shape.name,
        "mode": shape.mode,
        "num_chips": num_chips,
        "analytic_flops_global": a_flops,
        "analytic_bytes_per_chip": a_bytes,
        "hlo_flops_per_chip_raw": hlo_flops,   # while bodies counted once
        "hlo_bytes_per_chip_raw": hlo_bytes,   # (recorded for reference)
        "collective_bytes_per_chip": coll_total,
        "collective_breakdown": coll,
        "memory": mem,
        "roofline": {**terms, "dominant": dominant},
        "model_flops_global": mf,
        "useful_compute_ratio": mf / a_flops if a_flops else 0.0,
    }


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: Optional[str]) -> Dict:
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    mesh_tag = "multipod" if multi_pod else "pod"
    if reason:
        rec = {"arch": arch, "shape": shape_name, "skipped": reason,
               "mesh": mesh_tag}
        _save(rec, out_dir, arch, shape_name, mesh_tag)
        log.info(f"[dryrun] SKIP {arch} x {shape_name}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered = lower_combo(cfg, shape, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    rec = analyse(lowered, compiled, cfg, shape, mesh.devices.size)
    rec.update({"mesh": mesh_tag, "lower_s": t_lower,
                "compile_s": t_compile})
    log.info(f"[dryrun] OK {arch} x {shape_name} [{mesh_tag}] "
          f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
          f"dominant={rec['roofline']['dominant']} "
          f"peak={rec['memory'].get('peak_bytes', 0)/2**30:.2f}GiB/chip")
    log.info(f"  memory_analysis: {rec['memory']}")
    log.info(f"  analytic: flops(global)={rec['analytic_flops_global']:.3e} "
          f"bytes/chip={rec['analytic_bytes_per_chip']:.3e} "
          f"coll/chip={rec['collective_bytes_per_chip']:.3e} "
          f"(hlo_raw flops/chip={rec['hlo_flops_per_chip_raw']:.2e})")
    _save(rec, out_dir, arch, shape_name, mesh_tag)
    return rec


def _save(rec: Dict, out_dir: Optional[str], arch: str, shape: str,
          mesh_tag: str) -> None:
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


# ---------------------------------------------------------------------------
# Pyramid search-step dry-run (the paper's own step on the production mesh)
# ---------------------------------------------------------------------------


def run_pyramid(multi_pod: bool, out_dir: Optional[str], *,
                naive: bool, n_per_shard: int = 1_000_000, d: int = 96,
                batch_per_replica: int = 256, k: int = 10,
                branching: int = 8) -> Dict:
    """Lower + compile Alg. 4 on the production mesh.

    Deployment model (paper Table I scale): Deep500M-like, 96-dim; one
    sub-HNSW shard per chip along the model axis x w_local, the data axis
    holds independent replica groups (the paper's replication). The naive
    baseline (HNSW-naive) sets capacity C = B; Pyramid routes to K of w.
    """
    from repro.common.config import PyramidConfig
    from repro.core.distributed import StackedShards, make_pyramid_search_fn
    from repro.core import hnsw as HN

    mesh = make_production_mesh(multi_pod=multi_pod)
    model_n = mesh.shape["model"]
    w = 16 * model_n  # 16 shards per model-axis chip
    cfg = PyramidConfig(metric="l2", num_shards=w, meta_size=10_000,
                        branching_factor=branching, capacity_factor=1.5,
                        ef_search=100)
    m0, mu, lpad, meta_m = 32, 16, 3, cfg.meta_size

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

    stacked = StackedShards(
        data=sds((w, n_per_shard, d), jnp.float32),
        ids=sds((w, n_per_shard), jnp.int32),
        bottom=sds((w, n_per_shard, m0), jnp.int32),
        upper=sds((w, lpad, n_per_shard, mu), jnp.int32),
        entry=sds((w,), jnp.int32),
        num_upper_levels=sds((w,), jnp.int32))
    meta = HN.HNSWArrays(
        data=sds((meta_m, d), jnp.float32),
        ids=sds((meta_m,), jnp.int32),
        bottom=sds((meta_m, m0), jnp.int32),
        upper=sds((lpad, meta_m, mu), jnp.int32),
        entry=sds((), jnp.int32),
        num_upper_levels=sds((), jnp.int32))
    part = sds((meta_m,), jnp.int32)
    queries = sds((batch_per_replica * mesh.shape["data"] *
                   (mesh.shape.get("pod", 1) if multi_pod else 1), d),
                  jnp.float32)

    fn = make_pyramid_search_fn(
        mesh, cfg, k=k, batch=batch_per_replica, ef=100, max_iters=200,
        naive=naive, data_axis="data")
    with mesh:
        t0 = time.time()
        lowered = fn.lower(stacked, meta, part, queries)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    coll_total = sum(v for kk, v in coll.items() if kk != "counts")
    ma = compiled.memory_analysis()
    name = "pyramid_naive" if naive else "pyramid_routed"
    mesh_tag = "multipod" if multi_pod else "pod"
    rec = {
        "arch": name, "shape": f"search_b{batch_per_replica}", "mesh": mesh_tag,
        "num_chips": mesh.devices.size,
        "hlo_flops_per_chip_raw": float(cost.get("flops", 0.0)),
        "hlo_bytes_per_chip_raw": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_chip": coll_total,
        "collective_breakdown": coll,
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes": int(ma.argument_size_in_bytes +
                              ma.temp_size_in_bytes),
        },
        "lower_s": t_lower, "compile_s": t_compile,
        "capacity": "B" if naive else
            f"B*K/w*cf={batch_per_replica}*{branching}/{w}*1.5",
    }
    log.info(f"[dryrun] OK {name} [{mesh_tag}] lower={t_lower:.1f}s "
          f"compile={t_compile:.1f}s "
          f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB/chip "
          f"flops/chip(raw)={rec['hlo_flops_per_chip_raw']:.3e}")
    _save(rec, out_dir, name, rec["shape"], mesh_tag)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch name or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--pyramid", action="store_true",
                    help="dry-run the Alg. 4 search step itself "
                         "(naive + routed) instead of the archs")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    if args.pyramid:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for mp in meshes:
            for naive in (True, False):
                run_pyramid(mp, args.out, naive=naive)
        return

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_one(arch, shape, mp, args.out)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    log.info(f"[dryrun] FAIL {arch} x {shape} "
                          f"{'multipod' if mp else 'pod'}: {e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    log.info("[dryrun] all combos lowered + compiled OK")


if __name__ == "__main__":
    main()
