"""Maintenance launcher: compact a store's delta log + rebalance shards.

Runs the background :class:`repro.store.maintenance.Compactor` against a
published store — once by default (fold whatever the log holds, apply
at most one split/merge, publish, truncate), or as a long-running
daemon with ``--watch``:

PYTHONPATH=src python -m repro.launch.maintain \\
    --store /tmp/pyramid_store --gc-keep 2

Serving processes pointed at the same store pick the compacted version
up on their next ``Brokers.replace_index(name, path)`` /
``ServingEngine.from_store``; in-process serving instead wires the
compactor through ``Brokers.attach_maintenance`` so each cycle
hot-swaps the engine directly (see API.md "Online index maintenance").
"""
from __future__ import annotations

import argparse
import json
import time

from repro.obs import get_logger

log = get_logger(__name__)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", required=True, help="store root")
    ap.add_argument("--threshold", type=int, default=1,
                    help="fold once this many delta records accumulated "
                         "(--watch mode; a one-shot run always folds)")
    ap.add_argument("--no-rebalance", action="store_true",
                    help="disable shard split/merge planning")
    ap.add_argument("--split-factor", type=float, default=4.0,
                    help="split a shard above this multiple of the "
                         "mean sub-dataset size")
    ap.add_argument("--merge-factor", type=float, default=0.25,
                    help="merge two shards both below this multiple "
                         "of the mean")
    ap.add_argument("--refresh-every", type=int, default=0,
                    help="kmeans++ meta-centroid refresh every N "
                         "cycles (0 = never; it is a full routing "
                         "rebuild)")
    ap.add_argument("--gc-keep", type=int, default=None,
                    help="GC superseded versions after each cycle, "
                         "keeping this many")
    ap.add_argument("--watch", action="store_true",
                    help="keep running, folding whenever --threshold "
                         "records accumulate")
    ap.add_argument("--poll-s", type=float, default=1.0,
                    help="--watch mode store poll period")
    args = ap.parse_args()

    from repro.store import Compactor, IndexStore
    store = IndexStore(args.store)
    index = store.load()
    compactor = Compactor(
        store, index, threshold_records=args.threshold,
        rebalance=not args.no_rebalance,
        split_factor=args.split_factor, merge_factor=args.merge_factor,
        refresh_every=args.refresh_every, gc_keep=args.gc_keep,
        poll_s=args.poll_s)

    if not args.watch:
        log = index.delta_log()
        n = len(log) if log is not None else 0
        vid = compactor.run_once(force=True)
        log.info(f"compacted {n} delta records into {vid} "
              f"(store={args.store})")
        log.info(json.dumps(compactor.stats(), indent=1))
        return

    # watch mode: the store is the only signal (writers live in other
    # processes), so poll the attached log length instead of the
    # in-process drain hook
    log.info(f"watching {args.store} (threshold={args.threshold} records, "
          f"poll={args.poll_s}s; ctrl-c to stop)")
    try:
        while True:
            log = compactor.index.delta_log()
            if log is not None and len(log) >= args.threshold:
                vid = compactor.run_once(force=True)
                log.info(f"[maintain] cycle {compactor.cycles}: "
                      f"published {vid}, "
                      f"stats={json.dumps(compactor.stats())}")
            time.sleep(args.poll_s)
    except KeyboardInterrupt:
        log.info(f"stopped after {compactor.cycles} cycles")


if __name__ == "__main__":
    main()
