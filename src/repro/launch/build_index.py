"""Index-building launcher (the paper's GraphConstructor, Sec. IV-A).

PYTHONPATH=src python -m repro.launch.build_index \
    --n 20000 --d 32 --metric l2 --shards 8 --out /tmp/pyramid_index
"""
from __future__ import annotations

import argparse
import os
import pickle
import time

import numpy as np

from repro.common.config import PyramidConfig
from repro.core.meta_index import PyramidIndex, build_pyramid_index
from repro.data.synthetic import clustered_vectors, norm_spread_vectors


def save_index(index: PyramidIndex, path: str) -> None:
    os.makedirs(path, exist_ok=True)
    with open(os.path.join(path, "index.pkl"), "wb") as f:
        pickle.dump(index, f)


def load_index(path: str) -> PyramidIndex:
    with open(os.path.join(path, "index.pkl"), "rb") as f:
        return pickle.load(f)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--metric", default="l2",
                    choices=["l2", "ip", "angular"])
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--meta-size", type=int, default=256)
    ap.add_argument("--replication-r", type=int, default=0)
    ap.add_argument("--data", default=None,
                    help=".npy file with the dataset (default: synthetic)")
    ap.add_argument("--out", default="/tmp/pyramid_index")
    args = ap.parse_args()

    if args.data:
        x = np.load(args.data).astype(np.float32)
    elif args.metric == "ip":
        x = norm_spread_vectors(args.n, args.d, 64)
    else:
        x = clustered_vectors(args.n, args.d, 64)

    cfg = PyramidConfig(
        metric=args.metric, num_shards=args.shards,
        meta_size=args.meta_size, sample_size=min(len(x), 10_000),
        replication_r=args.replication_r or (300 if args.metric == "ip"
                                             else 0))
    t0 = time.time()
    index = build_pyramid_index(x, cfg, verbose=True)
    print(f"index built in {time.time()-t0:.1f}s; saving to {args.out}")
    save_index(index, args.out)


if __name__ == "__main__":
    main()
