"""Index-building launcher (the paper's GraphConstructor, Sec. IV-A).

Builds with the parallel constructor (``repro.build``) and publishes a
versioned, checksummed store (``repro.store``) — the paper's "construct
in parallel across the cluster, persist to HDFS" flow:

PYTHONPATH=src python -m repro.launch.build_index \\
    --n 20000 --d 32 --metric l2 --shards 8 --workers 4 \\
    --out /tmp/pyramid_store

Serving then recovers from the store (``ServingEngine.from_store``) or
hot-swaps onto a fresh publish (``Brokers.replace_index(name, path)``).

``save_index`` / ``load_index`` remain as *deprecated* shims over the
store (``load_index`` still reads seed-era ``index.pkl`` pickles); new
code should use :class:`repro.store.IndexStore` directly.
"""
from __future__ import annotations

import argparse
import os
import pickle
import time
import warnings
from typing import Optional

import numpy as np

from repro.common.config import PyramidConfig
from repro.core.meta_index import PyramidIndex
from repro.data.synthetic import clustered_vectors, norm_spread_vectors
from repro.obs import get_logger

log = get_logger(__name__)


def save_index(index: PyramidIndex, path: str) -> None:
    """Deprecated: publish a store version at ``path`` instead.

    Kept for source compatibility with the seed-era pickle API; now
    delegates to :meth:`repro.store.IndexStore.publish` (atomic,
    checksummed, versioned — no pickle is written). A legacy
    ``index.pkl`` in the same directory is moved aside so the old
    save/load round-trip cannot return the stale pickle."""
    warnings.warn(
        "save_index is deprecated: use "
        "repro.store.IndexStore(path).publish(index)",
        DeprecationWarning, stacklevel=2)
    from repro.store import IndexStore
    IndexStore(path).publish(index)
    pkl = os.path.join(path, "index.pkl")
    if os.path.exists(pkl):   # superseded by the publish above
        os.replace(pkl, pkl + ".migrated")


def load_index(path: str, *, version: Optional[str] = None) -> PyramidIndex:
    """Open the index at ``path``: a store root (latest published
    version + delta-log replay) or a legacy ``index.pkl`` pickle
    (deprecated migration path). A published store version always wins
    over a leftover pickle — it is the newer artifact."""
    from repro.store import IndexStore
    store = IndexStore(path)
    pkl = os.path.join(path, "index.pkl")
    # an explicit version request can never be served by the unversioned
    # pickle — fall through to the store, which raises if it's absent
    if version is None and os.path.exists(pkl) and not store.exists():
        warnings.warn(
            "loading a legacy pickle index; re-publish it with "
            "repro.store.IndexStore(path).publish(load_index(path)) — "
            "pickle support will be removed",
            DeprecationWarning, stacklevel=2)
        with open(pkl, "rb") as f:
            return pickle.load(f)
    return store.load(version=version)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--metric", default="l2",
                    choices=["l2", "ip", "angular"])
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--meta-size", type=int, default=256)
    ap.add_argument("--replication-r", type=int, default=0)
    ap.add_argument("--workers", type=int, default=None,
                    help="sub-HNSW build processes (default: "
                         "min(shards, cpu_count); 0 = sequential)")
    ap.add_argument("--data", default=None,
                    help=".npy file with the dataset (default: synthetic)")
    ap.add_argument("--out", default="/tmp/pyramid_store",
                    help="store root (a version is published under it)")
    ap.add_argument("--gc-keep", type=int, default=None,
                    help="after publishing, GC superseded versions "
                         "keeping this many")
    ap.add_argument("--quantize", action="store_true",
                    help="print the frozen int8 quantization grid. "
                         "(Every publish persists the grid in the "
                         "manifest, so ServingEngine.from_store(path, "
                         "quantize=True) always reopens without "
                         "re-deriving params and delta replay "
                         "requantizes appends on the identical grid; "
                         "this flag only surfaces it.)")
    args = ap.parse_args()

    if args.data:
        x = np.load(args.data).astype(np.float32)
    elif args.metric == "ip":
        x = norm_spread_vectors(args.n, args.d, 64)
    else:
        x = clustered_vectors(args.n, args.d, 64)

    cfg = PyramidConfig(
        metric=args.metric, num_shards=args.shards,
        meta_size=args.meta_size, sample_size=min(len(x), 10_000),
        replication_r=args.replication_r or (300 if args.metric == "ip"
                                             else 0))
    from repro.build import build_pyramid_index_parallel
    from repro.store import IndexStore
    t0 = time.time()
    index = build_pyramid_index_parallel(
        x, cfg, workers=args.workers, verbose=True)
    t_build = time.time() - t0
    if args.quantize:
        qp = index.quant_params()   # publish persists this frozen grid
        log.info(f"quantization grid: d={qp.d}, int8 "
              f"(vector payload shrinks ~4x in quantize=True engines)")
    store = IndexStore(args.out)
    t0 = time.time()
    vid = store.publish(index, keep=args.gc_keep)
    log.info(f"index built in {t_build:.1f}s "
          f"(mode={index.build_stats['build_mode']}, "
          f"workers={index.build_stats['build_workers']}); "
          f"published {vid} to {args.out} in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
