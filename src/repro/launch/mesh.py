"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — required for the dry-run's forced-host-device
setup ordering.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips, TPU v5e pod) or 2x16x16 multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Degenerate mesh on whatever devices exist (CPU tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
