"""Serving launcher: reduced-scale prefill + decode with optional kNN-LM
retrieval through a Pyramid datastore served by the distributed engine
(lookups go through the futures-based ``PyramidClient`` session).

Observability: ``--trace-out trace.json`` records the whole run —
prefill, every decode step, and (with ``--retrieval``) the engine-side
route/dispatch/batch/merge spans under them — as Chrome ``trace_event``
JSON loadable in Perfetto / ``chrome://tracing``. ``--metrics-port``
serves the run's registry at ``/metrics`` (Prometheus text) and
``/stats`` while it lasts.

For real launches, source the host-tuning environment first (tcmalloc
preload when available + XLA host-platform flags; measured effect in
API.md "Serving host environment"):

    source scripts/serve_env.sh
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --tokens 16
"""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import PyramidConfig
from repro.common.registry import get_arch, list_archs
from repro.models.transformer import grow_cache, init_params
from repro.obs import MetricsRegistry, StatsServer, Tracer, get_logger
from repro.serving.decode import decode_step, prefill_step
from repro.serving.retrieval import (build_datastore, hidden_states,
                                     interpolate, knn_probs,
                                     open_datastore_client)

log = get_logger(__name__)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--retrieval", action="store_true",
                    help="kNN-LM interpolation via a Pyramid datastore")
    ap.add_argument("--quantize", action="store_true",
                    help="serve the retrieval datastore from the int8 "
                         "arena (asymmetric distances + exact float32 "
                         "rerank; ~4x smaller device vector payload)")
    ap.add_argument("--rerank-factor", type=int, default=4,
                    help="with --quantize: exact-rerank the top "
                         "rerank_factor * k quantized candidates")
    ap.add_argument("--tenant", default=None, metavar="NAME",
                    help="serve the retrieval datastore as this named "
                         "tenant through a TenantManager (admission-"
                         "controlled device-memory budget, LRU "
                         "eviction; see repro.serving.tenancy)")
    ap.add_argument("--tenant-budget-mb", type=float, default=256.0,
                    help="with --tenant: the manager's total device-"
                         "memory budget for tenant arenas, in MiB")
    ap.add_argument("--lam", type=float, default=0.3)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace_event JSON of the run "
                         "(validated; open in Perfetto)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics (Prometheus) and /stats on "
                         "this port for the duration of the run "
                         "(0 = ephemeral)")
    args = ap.parse_args(argv)

    tracer = Tracer() if args.trace_out else None
    registry = MetricsRegistry()
    server = None
    if args.metrics_port is not None:
        server = StatsServer(registry, port=args.metrics_port).start()
        log.info("[serve] stats server on :%d (/metrics /stats)",
                 server.port)

    cfg = get_arch(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    if cfg.frontend:
        prompt = jnp.asarray(rng.normal(size=(
            args.batch, args.prompt_len, cfg.frontend_dim)).astype(
                np.float32))
    else:
        prompt = jnp.asarray(rng.integers(
            0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
            jnp.int32)

    ds = None
    ds_client = None
    span = (tracer.span if tracer else
            (lambda *a, **kw: contextlib.nullcontext()))
    # the datastore client is a context manager owning its engine: the
    # with-block guarantees the executor threads come down on any exit
    # path (an abandoned engine can abort the interpreter mid-XLA-call)
    with contextlib.ExitStack() as stack:
        if args.retrieval:
            if cfg.frontend:
                raise SystemExit("--retrieval expects a token-input arch")
            corpus = rng.integers(0, cfg.vocab_size, size=(8, 64))
            pyr = PyramidConfig(metric="l2", num_shards=4, meta_size=32,
                                sample_size=400, branching_factor=2,
                                max_degree=12, max_degree_upper=6,
                                ef_construction=40, ef_search=60)
            with span("serve.build_datastore"):
                ds = build_datastore(params, cfg, [corpus], pyr)
                if args.tenant:
                    from repro.serving.tenancy import TenantManager
                    tm = stack.enter_context(TenantManager(
                        int(args.tenant_budget_mb * 2**20),
                        registry=registry))
                    tm.create(args.tenant, ds.index,
                              quantize=args.quantize,
                              rerank_factor=args.rerank_factor,
                              tracer=tracer)
                    ds_client = tm.client(args.tenant)
                    log.info("[serve] tenant %r admitted: %s",
                             args.tenant, tm.stats()["tenants"])
                    if server is not None:
                        server.add_stats_provider("tenancy", tm.stats)
                else:
                    ds_client = stack.enter_context(
                        open_datastore_client(
                            ds, quantize=args.quantize,
                            rerank_factor=args.rerank_factor,
                            registry=registry, tracer=tracer))
            stats = ds_client.stats()
            log.info(
                "[serve] datastore ready: %d entries, served by %d "
                "executors (quantized=%s, arena vector bytes=%d)",
                ds.values.shape[0], len(stats["executors"]),
                stats["quantized"], stats["arena_vector_bytes"])
            if server is not None:
                server.add_stats_provider("engine", ds_client.stats)

        t0 = time.time()
        with span("serve.prefill", batch=args.batch,
                  prompt_len=args.prompt_len):
            logits, cache = prefill_step(params, prompt, cfg=cfg)
            cache = grow_cache(cache, args.prompt_len + args.tokens,
                               window=cfg.sliding_window)
        log.info("[serve] prefill %s in %.2fs", tuple(prompt.shape),
                 time.time() - t0)

        tok = jnp.argmax(logits[:, -1:].astype(jnp.float32),
                         -1).astype(jnp.int32)
        if cfg.frontend:  # frontend archs decode over embedding stand-ins
            tok_emb = jnp.zeros((args.batch, 1, cfg.frontend_dim),
                                jnp.float32)
        out_tokens = [np.asarray(tok[:, 0])]
        t0 = time.time()
        for t in range(args.tokens - 1):
            with span("serve.decode_step", step=t):
                pos = jnp.full((args.batch,), args.prompt_len + t,
                               jnp.int32)
                inp = tok_emb if cfg.frontend else tok
                nxt, step_logits, cache = decode_step(params, cache, inp,
                                                      pos, cfg=cfg)
                if ds is not None:
                    # demo-grade retrieval key: context-free hidden
                    # state of the last token (the retrieval_decode
                    # example shows the full flow)
                    kp = knn_probs(ds, np.asarray(
                        hidden_states(params, cfg, tok),
                        np.float32)[:, -1], k=8,
                        vocab_size=cfg.vocab_size, client=ds_client)
                    mixed = interpolate(np.asarray(step_logits), kp,
                                        lam=args.lam)
                    nxt = jnp.asarray(mixed.argmax(-1), jnp.int32)
                tok = nxt[:, None]
                out_tokens.append(np.asarray(nxt))
        dt = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    log.info("[serve] decoded %d tokens/seq in %.2fs (%.1f tok/s)",
             args.tokens, dt, args.batch * args.tokens / dt)
    log.info("[serve] generated ids (row 0): %s", gen[0][:16])
    if tracer is not None:
        payload = tracer.write_chrome(args.trace_out)
        log.info("[serve] wrote %d trace events to %s",
                 len(payload["traceEvents"]), args.trace_out)
    if server is not None:
        server.stop()


if __name__ == "__main__":
    main()
