"""Training launcher for the assigned architectures.

Reduced CPU run:   PYTHONPATH=src python -m repro.launch.train \
                       --arch qwen3-1.7b --reduced --steps 50
Production lower:  handled by repro.launch.dryrun (no TPU here).
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp

from repro.common.registry import get_arch, list_archs
from repro.data.synthetic import SyntheticLM
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.obs import get_logger
from repro.train.checkpoint import save_checkpoint
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_sharded, make_train_step

log = get_logger(__name__)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer smoke-scale variant (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    step_fn, _ = make_train_step(mesh, cfg, opt_cfg)
    params, opt_state = init_sharded(mesh, cfg)
    data = iter(SyntheticLM(cfg, batch=args.batch, seq_len=args.seq))

    t0 = time.time()
    for i in range(args.steps):
        b = next(data)
        batch = {"inputs": jnp.asarray(b.inputs),
                 "targets": jnp.asarray(b.targets),
                 "mask": jnp.asarray(b.mask)}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            log.info(f"[train:{cfg.name}] step {i:4d} "
                  f"loss={float(m['loss']):.4f} lr={float(m['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, opt_state, step=args.steps,
                        meta={"arch": cfg.name})
        log.info(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
