"""Metadata filter semantics for filtered kNN.

Every item carries an int64 **tag bitset**; a query carries an int64
``filter_tags`` word. The contract, shared by every search path
(fused kernel / jnp oracle / numpy twin / host reference):

  * ``filter_tags == 0``  -> no filtering (every item alive);
  * ``filter_tags != 0``  -> item alive iff ``tags & filter_tags != 0``
    (ANY-of bit match).

Filtering is applied as an **alive-mask on candidates** — after the
beam walk emits its candidate set, before the per-shard top-k and the
cross-shard merge — never on the navigation beam itself (masking the
walk would disconnect the HNSW graph and collapse recall) and never as
a post-merge drop (which under-fills k). Dead candidates become
``(-inf, -1)`` exactly like structural padding, so the downstream
top-k/merge machinery needs no new cases.

Device representation: JAX runs with x64 disabled, so an int64 array
pushed to the device silently truncates to 32 bits. Tags therefore
travel device-side as **two int32 words** ``[..., 2]`` (lo, hi) and the
alive test ORs the two per-word intersections — the full 64-bit bitset
survives. :func:`split_tag_words` / :func:`filter_words` produce the
word form from host int64 values.

Selectivity handling: at low selectivity the walk's candidate set
thins out after masking, so callers inflate the candidate budget
(``ef`` / per-shard k / ``rerank_factor``) by ``1/selectivity`` capped
at :data:`INFLATE_CAP` — see :func:`inflation` (the "filter-selectivity
rerank rule" in API.md).
"""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp
import numpy as np

# hard cap on the 1/selectivity candidate-budget inflation: below
# 1/INFLATE_CAP selectivity the graph walk itself is the wrong tool
# (a brute-force scan over the tagged subset would win) — we keep the
# budget bounded instead of chasing arbitrarily thin filters
INFLATE_CAP = 8

_LO_MASK = np.uint64(0xFFFFFFFF)
_SHIFT = np.uint64(32)


def split_tag_words(tags: np.ndarray) -> np.ndarray:
    """Host int64 tag bitsets ``[...]`` -> device-safe int32 word pairs
    ``[..., 2]`` (lo word, hi word)."""
    t = np.asarray(tags).astype(np.uint64)
    lo = (t & _LO_MASK).astype(np.uint32).view(np.int32)
    hi = (t >> _SHIFT).astype(np.uint32).view(np.int32)
    return np.stack([lo, hi], axis=-1)


def filter_words(filter_tags) -> np.ndarray:
    """Scalar-or-array int64 filter(s) -> int32 word pairs ``[..., 2]``."""
    return split_tag_words(np.asarray(filter_tags, dtype=np.uint64))


def alive_words(tag_words: jnp.ndarray, fw: jnp.ndarray) -> jnp.ndarray:
    """Alive mask from word-split bitsets (device side).

    Args:
      tag_words: ``[..., 2]`` int32 item tag words.
      fw: ``[..., 2]`` int32 filter words, broadcastable against
        ``tag_words[..., 0]``'s shape.

    Returns a bool mask of the broadcast shape: True where the filter
    is empty (no filtering) or the bitsets intersect.
    """
    lo = jnp.bitwise_and(tag_words[..., 0], fw[..., 0])
    hi = jnp.bitwise_and(tag_words[..., 1], fw[..., 1])
    no_filter = jnp.bitwise_or(fw[..., 0], fw[..., 1]) == 0
    return jnp.logical_or(no_filter, jnp.bitwise_or(lo, hi) != 0)


def alive_np(tags: np.ndarray, filter_tags) -> np.ndarray:
    """Numpy twin of :func:`alive_words` on raw int64 bitsets."""
    t = np.asarray(tags).astype(np.uint64)
    f = np.asarray(filter_tags, dtype=np.uint64)
    return np.logical_or(f == 0, (t & f) != 0)


def selectivity_np(tags: Optional[np.ndarray], filter_tags: int) -> float:
    """Fraction of items alive under ``filter_tags`` (host estimate used
    to size the candidate-budget inflation). ``filter == 0`` -> 1.0; an
    untagged corpus under a non-zero filter -> 0.0."""
    if int(filter_tags) == 0:
        return 1.0
    if tags is None or np.asarray(tags).size == 0:
        return 0.0
    return float(np.mean(alive_np(tags, filter_tags)))


def inflation(selectivity: float, *, cap: int = INFLATE_CAP) -> int:
    """Candidate-budget multiplier for a filter of the given selectivity:
    ``ceil(1/selectivity)`` capped at ``cap`` (>= 1). Selectivity 0 maps
    to the cap — the search still runs (and returns empty) at bounded
    cost."""
    if selectivity >= 1.0:
        return 1
    if selectivity <= 0.0:
        return int(cap)
    return int(min(int(cap), math.ceil(1.0 / selectivity)))
