"""Weight-balanced graph partitioning of the meta-HNSW bottom layer.

The paper uses the Karlsruhe Fast Flow Partitioner (KaFFPa [34]), a
multilevel local-improvement partitioner. We implement a faithful stand-in
with the same contract — *balanced* (by vertex weight) partitions that
*minimise edge cut* — using:

  1. greedy weighted graph-growing for the initial partition, then
  2. Fiduccia–Mattheyses-style boundary refinement passes (move the vertex
     with the best cut-gain that keeps both sides within the balance bound).

The meta graph is small (m ≈ 1e3..1e5 vertices, degree ≤ 32), so a
host-side numpy implementation is appropriate — this runs once, offline,
at index-build time (Alg. 3 line 6).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _symmetrize(adj: np.ndarray) -> list:
    """[n, M] padded adjacency -> list of unique undirected neighbour arrays."""
    n = adj.shape[0]
    nbrs = [set() for _ in range(n)]
    for u in range(n):
        for v in adj[u]:
            if v >= 0 and v != u:
                nbrs[u].add(int(v))
                nbrs[v].add(u)
    return [np.fromiter(s, dtype=np.int64, count=len(s)) for s in nbrs]


def partition_graph(adj: np.ndarray, weights: np.ndarray, w: int, *,
                    epsilon: float = 0.10, refine_passes: int = 8,
                    seed: int = 0) -> np.ndarray:
    """Partition a padded adjacency graph into w weight-balanced parts.

    Args:
      adj: [n, M] int32 adjacency (directed ok; symmetrised internally).
      weights: [n] nonnegative vertex weights (cluster sizes, Alg. 3).
      w: number of partitions.
      epsilon: allowed imbalance; each part <= (1+eps) * total/w.

    Returns labels [n] int32 in [0, w).
    """
    n = adj.shape[0]
    weights = np.asarray(weights, dtype=np.float64)
    if w <= 1:
        return np.zeros(n, dtype=np.int32)
    if w > n:
        raise ValueError(f"w={w} > n={n}")
    rng = np.random.default_rng(seed)
    nbrs = _symmetrize(adj)
    total = float(weights.sum())
    target = total / w
    cap = (1.0 + epsilon) * target

    # --- phase 1: greedy graph growing -----------------------------------
    labels = np.full(n, -1, dtype=np.int32)
    part_weight = np.zeros(w, dtype=np.float64)
    unassigned = set(range(n))
    order = np.argsort(-weights)  # heavy seeds first
    for p in range(w):
        seed_v = next((v for v in order if labels[v] < 0), None)
        if seed_v is None:
            break
        frontier = [seed_v]
        while frontier and part_weight[p] < target:
            v = frontier.pop(0)
            if labels[v] >= 0:
                continue
            labels[v] = p
            part_weight[p] += weights[v]
            unassigned.discard(v)
            for u in nbrs[v]:
                if labels[u] < 0:
                    frontier.append(int(u))
    # leftovers -> currently lightest part (or neighbour-majority part)
    for v in sorted(unassigned, key=lambda v: -weights[v]):
        nb = [labels[u] for u in nbrs[v] if labels[u] >= 0]
        if nb:
            cands, counts = np.unique(nb, return_counts=True)
            ok = cands[part_weight[cands] + weights[v] <= cap]
            if ok.size:
                p = ok[np.argmax(counts[np.isin(cands, ok)])]
            else:
                p = int(np.argmin(part_weight))
        else:
            p = int(np.argmin(part_weight))
        labels[v] = p
        part_weight[p] += weights[v]

    # --- phase 2: FM-style boundary refinement ---------------------------
    for _ in range(refine_passes):
        moved = 0
        # connectivity counts conn[v, p] = # neighbours of v in part p
        conn = np.zeros((n, w), dtype=np.int32)
        for v in range(n):
            for u in nbrs[v]:
                conn[v, labels[u]] += 1
        boundary = [v for v in range(n)
                    if conn[v, labels[v]] < len(nbrs[v])]
        rng.shuffle(boundary)
        for v in boundary:
            p = labels[v]
            gains = conn[v] - conn[v, p]
            gains[p] = -1
            # balance: target part must stay under cap and source part
            # should not become too empty
            feasible = part_weight + weights[v] <= cap
            feasible[p] = False
            gains = np.where(feasible, gains, -(10 ** 9))
            q = int(np.argmax(gains))
            if gains[q] > 0 or (gains[q] == 0 and
                                part_weight[p] > part_weight[q] + weights[v]):
                labels[v] = q
                part_weight[p] -= weights[v]
                part_weight[q] += weights[v]
                for u in nbrs[v]:
                    conn[u, p] -= 1
                    conn[u, q] += 1
                moved += 1
        if moved == 0:
            break
    return labels


def edge_cut(adj: np.ndarray, labels: np.ndarray) -> int:
    """Number of (directed) edges crossing partitions — the Alg. 3 objective."""
    n, m = adj.shape
    src = np.repeat(np.arange(n), m)
    dst = adj.reshape(-1)
    valid = dst >= 0
    return int(np.sum(labels[src[valid]] != labels[dst[valid]]))


def balance_stats(weights: np.ndarray, labels: np.ndarray,
                  w: int) -> Tuple[float, np.ndarray]:
    """(max part weight / ideal, per-part weights)."""
    pw = np.zeros(w)
    np.add.at(pw, labels, weights)
    ideal = weights.sum() / w
    return float(pw.max() / max(ideal, 1e-12)), pw
