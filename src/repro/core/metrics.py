"""Similarity functions.

The paper frames everything as *similarity* s(q, x) where larger is more
similar (Sec. II): Euclidean NNS uses s = -||q-x||^2 (monotone to -||q-x||),
MIPS uses s = q.x, angular uses cosine (items/queries normalised up front,
after which it coincides with inner product — Sec. III-C).
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
import numpy as np

METRICS = ("l2", "ip", "angular")


def similarity_matrix(q: jnp.ndarray, x: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Pairwise similarity, q:[B,d] x:[n,d] -> [B,n]. Larger = more similar."""
    if metric == "l2":
        # -||q-x||^2 = 2 q.x - ||q||^2 - ||x||^2 ; matmul-shaped for the MXU.
        qn = jnp.sum(q * q, axis=-1, keepdims=True)
        xn = jnp.sum(x * x, axis=-1)
        return 2.0 * q @ x.T - qn - xn[None, :]
    if metric == "ip":
        return q @ x.T
    if metric == "angular":
        qn = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
        xn = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)
        return qn @ xn.T
    raise ValueError(f"unknown metric {metric!r}")


def similarity_matrix_np(q: np.ndarray, x: np.ndarray, metric: str) -> np.ndarray:
    """Numpy twin of ``similarity_matrix`` for offline index building."""
    q = np.asarray(q, dtype=np.float32)
    x = np.asarray(x, dtype=np.float32)
    if metric == "l2":
        qn = np.sum(q * q, axis=-1, keepdims=True)
        xn = np.sum(x * x, axis=-1)
        return 2.0 * q @ x.T - qn - xn[None, :]
    if metric == "ip":
        return q @ x.T
    if metric == "angular":
        qn = q / (np.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
        xn = x / (np.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)
        return qn @ xn.T
    raise ValueError(f"unknown metric {metric!r}")


def brute_force_topk(q: np.ndarray, x: np.ndarray, k: int, metric: str):
    """Exact ground truth: (ids [B,k], scores [B,k]) by descending similarity."""
    sims = similarity_matrix_np(q, x, metric)
    k = min(k, x.shape[0])
    part = np.argpartition(-sims, k - 1, axis=1)[:, :k]
    part_scores = np.take_along_axis(sims, part, axis=1)
    order = np.argsort(-part_scores, axis=1)
    ids = np.take_along_axis(part, order, axis=1)
    scores = np.take_along_axis(part_scores, order, axis=1)
    return ids, scores


def preprocess_dataset(x: np.ndarray, metric: str) -> np.ndarray:
    """Dataset-side normalisation (angular -> unit norm, Sec. III-C)."""
    if metric == "angular":
        return x / (np.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)
    return np.asarray(x, dtype=np.float32)


def preprocess_queries(q: np.ndarray, metric: str) -> np.ndarray:
    if metric == "angular":
        return q / (np.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
    return np.asarray(q, dtype=np.float32)


def get_metric_fn(metric: str) -> Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]:
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}")
    return lambda q, x: similarity_matrix(q, x, metric)
