"""Futures-based client surface for distributed Pyramid search.

This is the stable public API the ROADMAP's serving work builds on: user
code talks to a :class:`PyramidClient` session and gets back
:class:`SearchFuture` handles, never touching the engine's threads,
topics, or replica groups. The paper's Listing 1-3 classes
(``Coordinator`` / ``Executor`` / ``GraphConstructor`` in
``repro.core.api``) remain as thin shims over this module.

    with Brokers() as brokers:
        client = brokers.open_client("wiki", index_path)
        fut = client.search(q, k=10)            # -> SearchFuture
        res = fut.result(timeout=5.0)           # raises TimeoutError

        futs = client.search_batch(Q, k=10)
        for fut in as_completed(futs):          # streaming merge order
            consume(fut.result())

Design notes:

  * every submitted query gets its own future, keyed by query id inside
    the engine — two clients sharing one engine can never steal each
    other's results (the old shared ``_done`` queue allowed exactly that);
  * a timed-out ``result()`` raises :class:`TimeoutError` instead of the
    query silently vanishing from the batch;
  * engine shutdown fails all in-flight futures with
    :class:`EngineShutdownError` so callers never hang on a dead engine;
  * robustness is visible at the future level: ``SearchFuture.hedges``
    counts the engine's hedge/retry re-dispatches for that query (the
    final count also rides on ``QueryResult.hedges``), so a caller can
    tell a first-try answer from one rescued off a straggler.

The module deliberately does not import the serving engine: the client is
duck-typed over any object with ``submit / scale / stats / shutdown``,
which keeps ``core`` free of a runtime dependency on ``serving``.

Persistence: clients bound through ``Brokers.open_client(name, path)``
accept a ``repro.store.IndexStore`` root as ``path`` (the versioned,
checksummed replacement for the deprecated pickle format, see API.md
"Index build & store"); ``Brokers.replace_index(name, path)`` hot-swaps
the serving engine onto the latest published version, and a session
keeps working across the swap — futures resolve against whichever
engine completed them.
"""
from __future__ import annotations

import logging
import queue
import threading
import time
from typing import (TYPE_CHECKING, Callable, Iterable, Iterator, List,
                    Optional, Tuple)

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.serving.engine import QueryResult, ServingEngine

logger = logging.getLogger(__name__)


class EngineShutdownError(RuntimeError):
    """The engine serving this future was shut down before it completed."""


class QueryExpiredError(RuntimeError):
    """The engine gave up on this query: it sat in ``_pending`` past the
    engine's ``pending_deadline_s`` (e.g. its shard lost every live
    replica, so the missing partials can never arrive). Unlike the
    builtin ``TimeoutError`` from ``SearchFuture.result(timeout)`` —
    after which the query keeps running — an expired query is dropped by
    the engine and its future can never complete."""


class SearchFuture:
    """Handle for one in-flight query.

    Mirrors the ``concurrent.futures.Future`` surface we need —
    ``result(timeout)``, ``done()``, ``exception()``,
    ``add_done_callback()`` — but raises the *builtin* ``TimeoutError``
    and is completed by the engine's merger thread via ``set_result`` /
    ``set_exception`` (engine-side API; user code only reads).
    """

    def __init__(self, query_id: int = -1):
        self.query_id = query_id
        self._cond = threading.Condition()
        self._done = False
        self._result: Optional["QueryResult"] = None
        self._exception: Optional[BaseException] = None
        self._callbacks: List[Callable[["SearchFuture"], None]] = []
        self._hedges = 0

    # -- reader side -------------------------------------------------------

    def done(self) -> bool:
        with self._cond:
            return self._done

    def result(self, timeout: Optional[float] = None) -> "QueryResult":
        """Block for the merged result.

        Raises ``TimeoutError`` if the result is not ready within
        ``timeout`` seconds (the query itself keeps running and the
        future may still complete later), or re-raises the exception the
        engine failed this future with.
        """
        with self._cond:
            if not self._cond.wait_for(lambda: self._done, timeout):
                raise TimeoutError(
                    f"query {self.query_id} not completed within "
                    f"{timeout}s")
            if self._exception is not None:
                raise self._exception
            return self._result

    def exception(self,
                  timeout: Optional[float] = None) -> Optional[BaseException]:
        with self._cond:
            if not self._cond.wait_for(lambda: self._done, timeout):
                raise TimeoutError(
                    f"query {self.query_id} not completed within "
                    f"{timeout}s")
            return self._exception

    @property
    def hedges(self) -> int:
        """Hedge/retry re-dispatches the engine has issued for this query
        so far (live counter; the final count also arrives on
        ``QueryResult.hedges``). 0 means the primary dispatch answered
        every shard within its latency deadline."""
        with self._cond:
            return self._hedges

    def add_done_callback(self,
                          fn: Callable[["SearchFuture"], None]) -> None:
        """Call ``fn(self)`` when the future completes (immediately if it
        already has). Callbacks run on the completing thread."""
        with self._cond:
            if not self._done:
                self._callbacks.append(fn)
                return
        fn(self)

    # -- engine side -------------------------------------------------------

    def record_hedge(self) -> None:
        """Engine-side: note one hedge/retry re-dispatch for this query
        (visible to callers via :attr:`hedges` while still pending)."""
        with self._cond:
            self._hedges += 1

    def set_result(self, result: "QueryResult") -> None:
        self._finish(result=result)

    def set_exception(self, exc: BaseException) -> None:
        self._finish(exc=exc)

    def _finish(self, result=None, exc=None) -> None:
        with self._cond:
            if self._done:  # first completion wins (duplicate delivery)
                return
            self._result = result
            self._exception = exc
            self._done = True
            callbacks, self._callbacks = self._callbacks, []
            self._cond.notify_all()
        for fn in callbacks:
            try:
                fn(self)
            except Exception:   # a bad callback must not kill the
                logger.exception(   # merger thread or abort shutdown
                    "done-callback for query %d raised", self.query_id)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        state = ("done" if self.done() else "pending")
        return f"SearchFuture(query_id={self.query_id}, {state})"


def gather(futures: Iterable[SearchFuture],
           timeout: Optional[float] = None, *,
           return_exceptions: bool = False) -> List:
    """Await a batch of futures under ONE shared deadline, preserving
    submit order.

    Raises the first per-query failure (``TimeoutError`` included) —
    or, with ``return_exceptions=True``, places the exception in the
    result list instead so callers can count stragglers.
    """
    deadline = None if timeout is None else time.monotonic() + timeout
    out = []
    for fut in futures:
        remaining = (None if deadline is None
                     else max(0.0, deadline - time.monotonic()))
        try:
            out.append(fut.result(remaining))
        except Exception as exc:
            if not return_exceptions:
                raise
            out.append(exc)
    return out


def gather_arrays(futures: Iterable[SearchFuture], k: int,
                  timeout: Optional[float] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Bulk-resolve a batch of futures into dense ``(ids [B, k] int64,
    scores [B, k] float32)`` arrays in submit order, under ONE shared
    deadline.

    Short results are padded with ``-1`` ids / ``-inf`` scores; results
    wider than ``k`` are trimmed. This is the per-step bulk path the
    streaming decode engine (``repro.serving.stream``) and the kNN-LM
    vocab scatter (``repro.serving.retrieval.knn_probs``) consume: one
    call per decode step resolves every active slot's lookup at once
    instead of shaping each future's result separately.
    """
    futures = list(futures)
    ids = np.full((len(futures), k), -1, np.int64)
    scores = np.full((len(futures), k), -np.inf, np.float32)
    for i, r in enumerate(gather(futures, timeout)):
        n = min(len(r.ids), k)
        ids[i, :n] = r.ids[:n]
        scores[i, :n] = r.scores[:n]
    return ids, scores


def as_completed(futures: Iterable[SearchFuture],
                 timeout: Optional[float] = None
                 ) -> Iterator[SearchFuture]:
    """Yield futures as they complete (streaming-merge order, not submit
    order). Raises ``TimeoutError`` if not all complete within
    ``timeout`` seconds of the call."""
    futures = list(futures)
    ready: "queue.Queue[SearchFuture]" = queue.Queue()
    for fut in futures:
        fut.add_done_callback(ready.put)
    deadline = None if timeout is None else time.monotonic() + timeout
    for i in range(len(futures)):
        remaining = None
        if deadline is not None:
            remaining = max(0.0, deadline - time.monotonic())
        try:
            yield ready.get(timeout=remaining)
        except queue.Empty:
            raise TimeoutError(
                f"{len(futures) - i} of {len(futures)} futures did not "
                f"complete within {timeout}s") from None


class PyramidClient:
    """A search session against one serving engine.

    Obtain one from :meth:`repro.core.api.Brokers.open_client` (tracks
    engine hot-swaps done via ``Brokers.replace_index``) or construct
    directly over an engine. The client owns no engine state: closing it
    never tears the engine down, and many clients can share one engine —
    each receives exactly its own results.
    """

    def __init__(self, engine: Optional["ServingEngine"] = None, *,
                 engine_resolver: Optional[
                     Callable[[], "ServingEngine"]] = None,
                 name: Optional[str] = None):
        if (engine is None) == (engine_resolver is None):
            raise ValueError(
                "pass exactly one of engine / engine_resolver")
        self._engine = engine
        self._resolver = engine_resolver
        self._closed = False
        self.name = name

    @classmethod
    def from_index(cls, index, *, replicas: int = 1,
                   name: Optional[str] = None,
                   **engine_kw) -> "PyramidClient":
        """Start a :class:`ServingEngine` over ``index`` and return a
        session on it. The caller owns teardown:
        ``client.engine.shutdown()``."""
        from repro.serving.engine import ServingEngine
        return cls(ServingEngine(index, replicas=replicas, **engine_kw),
                   name=name)

    @property
    def engine(self) -> "ServingEngine":
        if self._closed:
            raise RuntimeError(f"client {self.name or ''} is closed")
        return self._engine if self._engine is not None else self._resolver()

    # -- queries -----------------------------------------------------------

    def search(self, query: np.ndarray, k: int = 10, *,
               branching_factor: Optional[int] = None,
               filter_tags=None) -> SearchFuture:
        """Submit ONE query vector; returns its future immediately.

        ``filter_tags`` (int64 bitset; ``repro.core.filters``
        semantics) restricts results to items whose tag bitset
        intersects it — 0 / ``None`` means unfiltered."""
        return self.search_batch(np.asarray(query)[None, :], k,
                                 branching_factor=branching_factor,
                                 filter_tags=filter_tags)[0]

    def search_batch(self, queries: np.ndarray, k: int = 10, *,
                     branching_factor: Optional[int] = None,
                     filter_tags=None) -> List[SearchFuture]:
        """Submit a [n, d] batch; returns one future per query, in
        submit order. Use :func:`as_completed` to stream the merges.
        ``filter_tags`` is a scalar or per-query int64 bitset (see
        :meth:`search`)."""
        return self.engine.submit(queries, k=k,
                                  branching_factor=branching_factor,
                                  filter_tags=filter_tags)

    # -- lifecycle / introspection (public replacements for the old
    # ``engine._spawn`` / ``engine.executors`` poking) ---------------------

    def scale(self, shard: int, n_replicas: int) -> List[str]:
        """Resize one shard's replica group; returns live replica names."""
        return self.engine.scale(shard, n_replicas)

    def stats(self) -> dict:
        return self.engine.stats()

    def close(self) -> None:
        """Detach from the engine (does NOT shut the engine down)."""
        self._closed = True

    def __enter__(self) -> "PyramidClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
