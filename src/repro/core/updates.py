"""Incremental index updates — beyond the paper's full ``refresh()``.

The paper rebuilds the whole index on dataset change (Sec. IV-A). Because
our meta-HNSW routing is stable under insertions (new items are assigned
to existing partitions by Alg. 3 lines 7-10), we can support *online
inserts* by rebuilding ONLY the sub-HNSWs that received new items — the
meta-HNSW, partition labels and all untouched shards are reused.

This keeps insert cost at O(|affected shards|) instead of O(w), which is
the production middle ground between per-item graph insertion (hard to do
well online) and the paper's full rebuild.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import hnsw as H
from repro.core import metrics as M
from repro.core.meta_index import PyramidIndex, _assign_items


def add_items(index: PyramidIndex, new_items: np.ndarray,
              new_ids: np.ndarray = None) -> PyramidIndex:
    """Insert ``new_items`` into an existing index (in place).

    Args:
      index: a built PyramidIndex.
      new_items: [m, d] raw vectors (normalised internally for angular).
      new_ids: optional global ids; defaults to continuing after the
        current max id.

    Returns the same index object with affected sub-HNSWs rebuilt.
    """
    cfg = index.config
    x = M.preprocess_dataset(new_items, cfg.metric)
    if new_ids is None:
        cur_max = max(int(g.ids.max()) for g in index.subs)
        new_ids = np.arange(cur_max + 1, cur_max + 1 + x.shape[0],
                            dtype=np.int64)
    metric = "ip" if cfg.is_mips else cfg.metric

    parts = _assign_items(x, index.meta_arrays(), index.part_of_center,
                          metric)
    affected: List[int] = sorted(set(parts.tolist()))
    for s in affected:
        sel = parts == s
        old = index.subs[s]
        data = np.concatenate([old.data, x[sel]])
        ids = np.concatenate([old.ids, new_ids[sel]])
        index.subs[s] = H.build_hnsw(
            data, metric=metric, max_degree=cfg.max_degree,
            max_degree_upper=cfg.max_degree_upper,
            ef_construction=cfg.ef_construction, seed=cfg.seed + 1 + s,
            ids=ids)
    index.build_stats["sub_sizes"] = [g.n for g in index.subs]
    index.build_stats["total_stored"] = sum(g.n for g in index.subs)
    index.invalidate_device_cache()   # subs changed: arena must rebuild
    return index


def remove_items(index: PyramidIndex, remove_ids: np.ndarray
                 ) -> PyramidIndex:
    """Delete items by global id; affected sub-HNSWs are rebuilt."""
    cfg = index.config
    metric = "ip" if cfg.is_mips else cfg.metric
    to_remove = set(np.asarray(remove_ids).tolist())
    for s, old in enumerate(index.subs):
        keep = np.asarray([int(i) not in to_remove for i in old.ids])
        if keep.all():
            continue
        if not keep.any():
            keep[0] = True  # degenerate guard: keep one item
        index.subs[s] = H.build_hnsw(
            old.data[keep], metric=metric, max_degree=cfg.max_degree,
            max_degree_upper=cfg.max_degree_upper,
            ef_construction=cfg.ef_construction, seed=cfg.seed + 1 + s,
            ids=old.ids[keep])
    index.build_stats["sub_sizes"] = [g.n for g in index.subs]
    index.build_stats["total_stored"] = sum(g.n for g in index.subs)
    index.invalidate_device_cache()   # subs changed: arena must rebuild
    return index
