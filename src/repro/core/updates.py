"""Incremental index updates — beyond the paper's full ``refresh()``.

The paper rebuilds the whole index on dataset change (Sec. IV-A). Because
our meta-HNSW routing is stable under insertions (new items are assigned
to existing partitions by Alg. 3 lines 7-10), we can support *online
inserts* by rebuilding ONLY the sub-HNSWs that received new items — the
meta-HNSW, partition labels and all untouched shards are reused.

This keeps insert cost at O(|affected shards|) instead of O(w), which is
the production middle ground between per-item graph insertion (hard to do
well online) and the paper's full rebuild.

Durability: when the index is attached to a published store version
(``repro.store.IndexStore`` publish/load), every ``add_items`` and
``remove_items`` call is journaled to that version's append-only delta
log *after* it is applied — inserts as vector records, removals as
tombstones — so both survive a restart: ``IndexStore.load`` replays the
log in journal order through these same functions (same ``shard_seed``,
bit-identical rebuild).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core import hnsw as H
from repro.core import metrics as M
from repro.core.meta_index import PyramidIndex, _assign_items


def _merge_tags(old: "H.HNSWGraph", new_tags: Optional[np.ndarray],
                m: int) -> Optional[np.ndarray]:
    """Tag column for a shard rebuild that appends ``m`` items: ``None``
    when neither side carries tags (the untagged fast path stays
    untagged), else old tags (zeros if absent) + new tags (zeros if
    absent)."""
    if old.tags is None and new_tags is None:
        return None
    new_col = (np.zeros(m, np.int64) if new_tags is None
               else np.asarray(new_tags, np.int64))
    return np.concatenate([old.tags_or_zeros(), new_col])


def add_items(index: PyramidIndex, new_items: np.ndarray,
              new_ids: Optional[np.ndarray] = None, *,
              tags: Optional[np.ndarray] = None,
              log_delta: bool = True) -> PyramidIndex:
    """Insert ``new_items`` into an existing index (in place).

    Args:
      index: a built PyramidIndex.
      new_items: [m, d] raw vectors (normalised internally for angular).
      new_ids: optional global ids; defaults to continuing after the
        current max id.
      tags: optional [m] int64 metadata tag bitsets for the new items
        (``repro.core.filters``); omitted means tag 0 (matches no
        non-empty filter). Journaled with the insert and replayed, so
        tags survive restart and compaction.
      log_delta: journal this insert to the index's attached store delta
        log (no-op when the index is not store-attached). The replay
        path passes ``False`` — replaying must not re-journal.

    Returns the same index object with affected sub-HNSWs rebuilt.
    """
    cfg = index.config
    log = index.delta_log() if log_delta else None
    if log is not None:
        # fail BEFORE mutating: if the journal can no longer accept
        # records (its version was GC'd), raising after the in-memory
        # apply would leave a half-committed state a retry duplicates
        log.ensure_writable()
    # cast BEFORE preprocessing: the delta journal stores float32, and
    # replay must normalise the exact bytes the live apply normalised
    # (angular preprocessing keeps the input dtype, so float64 input
    # would otherwise round differently on replay)
    new_items = np.asarray(new_items, np.float32)
    x = M.preprocess_dataset(new_items, cfg.metric)
    if new_ids is None:
        # next free id = max over the non-empty shards (a skewed
        # partition or remove_items can leave a zero-item shard whose
        # ids.max() would raise) AND the persistent high-water mark —
        # without the watermark, ids freed by an un-journaled
        # remove_items would be reused, and delta replay onto the
        # published state (where the removed item still exists) would
        # alias one global id to two different vectors
        occupied = [int(g.ids.max()) for g in index.subs if g.ids.size]
        hwm = int(index.build_stats.get("max_assigned_id", -1))
        cur_max = max(occupied + [hwm], default=-1)
        new_ids = np.arange(cur_max + 1, cur_max + 1 + x.shape[0],
                            dtype=np.int64)
    else:
        new_ids = np.asarray(new_ids, dtype=np.int64)
    if new_ids.size:
        index.build_stats["max_assigned_id"] = max(
            int(index.build_stats.get("max_assigned_id", -1)),
            int(new_ids.max()))
    metric = "ip" if cfg.is_mips else cfg.metric
    if tags is not None:
        tags = np.asarray(tags, dtype=np.int64).ravel()

    parts = _assign_items(x, index.meta_arrays(), index.part_of_center,
                          metric)
    affected: List[int] = sorted(set(parts.tolist()))
    for s in affected:
        sel = parts == s
        old = index.subs[s]
        data = np.concatenate([old.data, x[sel]])
        ids = np.concatenate([old.ids, new_ids[sel]])
        index.subs[s] = H.build_hnsw(
            data, metric=metric, max_degree=cfg.max_degree,
            max_degree_upper=cfg.max_degree_upper,
            ef_construction=cfg.ef_construction,
            seed=H.shard_seed(cfg.seed, s), ids=ids,
            tags=_merge_tags(old, None if tags is None else tags[sel],
                             int(sel.sum())))
    index.build_stats["sub_sizes"] = [g.n for g in index.subs]
    index.build_stats["total_stored"] = sum(g.n for g in index.subs)
    index.invalidate_device_cache()   # subs changed: arena must rebuild
    if log is not None:
        # journal AFTER the in-memory apply (a crash mid-rebuild must
        # not leave a committed record the memory state never saw),
        # with the raw-but-f32 vectors + resolved ids: replay goes
        # back through add_items itself, preprocessing included. If
        # this append itself fails, the in-memory apply HAS happened —
        # the exception signals lost durability, not a failed insert.
        log.append(new_items, new_ids, tags=tags)
    return index


def remove_items(index: PyramidIndex, remove_ids: np.ndarray, *,
                 log_delta: bool = True) -> PyramidIndex:
    """Delete items by global id; affected sub-HNSWs are rebuilt.

    Removing every item of a shard leaves a truly-empty sub-HNSW
    (``H.empty_hnsw``): searches skip it and the arena pads it with an
    inert row, so a deleted id can never be returned by any path.

    Durable on store-attached indexes: the removal is journaled as a
    tombstone record *after* it is applied (``log_delta=False`` on the
    replay path), so crash recovery cannot resurrect deleted vectors.
    """
    cfg = index.config
    metric = "ip" if cfg.is_mips else cfg.metric
    remove_ids = np.asarray(remove_ids, dtype=np.int64).ravel()
    log = index.delta_log() if log_delta else None
    if log is not None:
        # fail BEFORE mutating, same contract as add_items
        log.ensure_writable()
    # pin the high-water mark BEFORE freeing ids: a later add_items must
    # never hand a removed item's id to a new vector (delta replay onto
    # the published state would alias the id to both)
    occupied = [int(g.ids.max()) for g in index.subs if g.ids.size]
    index.build_stats["max_assigned_id"] = max(
        occupied + [int(index.build_stats.get("max_assigned_id", -1))],
        default=-1)
    to_remove = set(remove_ids.tolist())
    for s, old in enumerate(index.subs):
        keep = np.asarray([int(i) not in to_remove for i in old.ids],
                          dtype=bool)
        if keep.size and keep.all():
            continue
        if not keep.any():
            index.subs[s] = H.empty_hnsw(
                old.d, metric=metric, max_degree=cfg.max_degree)
            continue
        index.subs[s] = H.build_hnsw(
            old.data[keep], metric=metric, max_degree=cfg.max_degree,
            max_degree_upper=cfg.max_degree_upper,
            ef_construction=cfg.ef_construction,
            seed=H.shard_seed(cfg.seed, s), ids=old.ids[keep],
            tags=None if old.tags is None else old.tags[keep])
    index.build_stats["sub_sizes"] = [g.n for g in index.subs]
    index.build_stats["total_stored"] = sum(g.n for g in index.subs)
    index.invalidate_device_cache()   # subs changed: arena must rebuild
    if log is not None:
        # journal AFTER the in-memory apply (mirrors add_items): replay
        # re-runs remove_items on the published state in journal order,
        # so a crash can never resurrect a deleted vector
        log.append_remove(remove_ids)
    return index


def set_item_tags(index: PyramidIndex, ids: np.ndarray,
                  tags: np.ndarray, *,
                  log_delta: bool = True) -> PyramidIndex:
    """Assign metadata tag bitsets to existing items by global id.

    Tags are per-node metadata — they never influence graph structure —
    so this mutates the sub-HNSW tag columns in place without any
    rebuild (cost O(total items), no device upload until the next
    search). Ids absent from the index are ignored; under MIPS
    replication every replica of an id receives the tag.

    Durable on store-attached indexes: journaled as an ``op="tags"``
    delta record applied in journal order on replay, so a tag written
    before a crash (or folded by the compactor) is never lost.
    """
    ids = np.asarray(ids, dtype=np.int64).ravel()
    tags = np.broadcast_to(
        np.asarray(tags, dtype=np.int64), ids.shape).ravel()
    log = index.delta_log() if log_delta else None
    if log is not None:
        log.ensure_writable()   # fail BEFORE mutating (same as add_items)
    tag_of = dict(zip(ids.tolist(), tags.tolist()))
    for g in index.subs:
        if not g.n:
            continue
        hits = [i for i, gid in enumerate(np.asarray(g.ids, np.int64))
                if int(gid) in tag_of]
        if not hits:
            continue
        col = g.tags_or_zeros()
        for i in hits:
            col[i] = tag_of[int(np.asarray(g.ids)[i])]
        g.tags = col
    # only the tag caches are stale: graphs, arenas and rerank tables
    # are untouched, so a full invalidate (and the arena re-upload it
    # forces) would be wasted work
    index._tags_arena = None
    index._tags_host = None
    if log is not None:
        log.append_tags(ids, tags)
    return index
