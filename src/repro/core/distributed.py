"""Distributed query processing (Alg. 4) as an SPMD JAX program.

The paper's Kafka topic-per-sub-HNSW dispatch becomes capacity-bounded
dispatch over the ``model`` mesh axis (DESIGN.md §3):

  * the w sub-HNSWs are stacked into equal-padded arrays and sharded over
    ``model`` (each device owns w / |model| shards);
  * every device routes the (replicated) query batch through the replicated
    meta-HNSW, picks the <= C queries assigned to *its* shards
    (``jnp.nonzero(..., size=C)`` = static-shape queue draining), searches
    its local sub-HNSWs, and
  * partial results are combined with an ``all_gather`` + scatter + top-k —
    the coordinator merge of Alg. 4 line 9.

Per-shard work drops from B queries (HNSW-naive) to C ≈ B·K/w — the paper's
throughput mechanism, realised as a FLOP reduction instead of queue load.

``search_single_host`` is the pure-numpy/JAX reference used by tests and
CPU benchmarks; the SPMD path is validated against it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.jax_compat import shard_map

from repro.common.config import PyramidConfig
from repro.core import hnsw as H
from repro.core import metrics as M
from repro.core.meta_index import PyramidIndex
from repro.core.router import route_queries


# ---------------------------------------------------------------------------
# Stacked shard arrays (equal-padded, shardable over the model axis)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StackedShards:
    """All w sub-HNSWs stacked on a leading shard axis.

    Padding: graphs are padded to the max sub-dataset size with isolated
    nodes (all -1 neighbours, id -1, zero vector) which can never be reached
    by the walk nor returned (ids filtered downstream).
    """

    data: jnp.ndarray     # [w, n_pad, d]
    ids: jnp.ndarray      # [w, n_pad] (-1 pad)
    bottom: jnp.ndarray   # [w, n_pad, M0]
    upper: jnp.ndarray    # [w, L, n_pad, Mu]
    entry: jnp.ndarray    # [w]
    num_upper_levels: jnp.ndarray  # [w]

    def tree_flatten(self):
        return (self.data, self.ids, self.bottom, self.upper, self.entry,
                self.num_upper_levels), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_shards(self) -> int:
        return self.data.shape[0]

    def shard(self, i: int) -> H.HNSWArrays:
        return H.HNSWArrays(
            data=self.data[i], ids=self.ids[i], bottom=self.bottom[i],
            upper=self.upper[i], entry=self.entry[i],
            num_upper_levels=self.num_upper_levels[i])


def stack_shards(index: PyramidIndex) -> StackedShards:
    arrs = [g.device_arrays() for g in index.subs]
    n_pad = max(a.data.shape[0] for a in arrs)
    l_pad = max(a.upper.shape[0] for a in arrs)
    mu = max(a.upper.shape[2] for a in arrs)
    m0 = max(a.bottom.shape[1] for a in arrs)
    d = arrs[0].data.shape[1]
    w = len(arrs)

    data = np.zeros((w, n_pad, d), np.float32)
    ids = np.full((w, n_pad), -1, np.int32)
    bottom = np.full((w, n_pad, m0), -1, np.int32)
    upper = np.full((w, l_pad, n_pad, mu), -1, np.int32)
    entry = np.zeros((w,), np.int32)
    nul = np.zeros((w,), np.int32)
    for i, a in enumerate(arrs):
        n = a.data.shape[0]
        data[i, :n] = np.asarray(a.data)
        ids[i, :n] = np.asarray(a.ids)
        bottom[i, :n, : a.bottom.shape[1]] = np.asarray(a.bottom)
        up = np.asarray(a.upper)
        upper[i, : up.shape[0], :n, : up.shape[2]] = up
        entry[i] = int(a.entry)
        nul[i] = int(a.num_upper_levels)
    return StackedShards(
        data=jnp.asarray(data), ids=jnp.asarray(ids),
        bottom=jnp.asarray(bottom), upper=jnp.asarray(upper),
        entry=jnp.asarray(entry), num_upper_levels=jnp.asarray(nul))


# ---------------------------------------------------------------------------
# Reference path (single host, python loop over shards)
# ---------------------------------------------------------------------------


def search_single_host(index: PyramidIndex, queries: np.ndarray, k: int, *,
                       ef: Optional[int] = None,
                       branching_factor: Optional[int] = None,
                       naive: bool = False):
    """Alg. 4 reference implementation.

    naive=True searches every shard (the HNSW-naive baseline of Sec. III).
    Returns (ids [B, k], scores [B, k], mask [B, w]).
    """
    cfg = index.config
    ef = ef or cfg.ef_search
    kb = branching_factor or cfg.branching_factor
    metric = "ip" if cfg.is_mips else cfg.metric
    q = M.preprocess_queries(queries, cfg.metric)
    b = q.shape[0]
    w = index.num_shards

    if naive:
        mask = np.ones((b, w), dtype=bool)
    else:
        mask_j, _ = route_queries(
            index.meta_arrays(), jnp.asarray(index.part_of_center),
            jnp.asarray(q), metric=metric, branching_factor=kb,
            num_shards=w, ef=max(64, kb))
        mask = np.asarray(mask_j)

    all_scores = np.full((b, w, k), -np.inf, np.float32)
    all_ids = np.full((b, w, k), -1, np.int64)
    for s in range(w):
        sel = np.where(mask[:, s])[0]
        if sel.size == 0:
            continue
        arrs = index.sub_arrays(s)
        kk = min(k, index.subs[s].n)
        # pad the per-shard batch to the next power of two so repeated
        # calls with varying routing fan-out reuse the jit cache
        padded = 1 << (int(sel.size) - 1).bit_length()
        qs = q[sel]
        if padded > sel.size:
            qs = np.concatenate(
                [qs, np.repeat(qs[:1], padded - sel.size, axis=0)])
        ids, scores = H.hnsw_search(
            arrs, jnp.asarray(qs), metric=metric, k=kk, ef=ef)
        all_ids[sel, s, :kk] = np.asarray(ids)[: sel.size]
        all_scores[sel, s, :kk] = np.asarray(scores)[: sel.size]

    flat_scores = all_scores.reshape(b, -1)
    flat_ids = all_ids.reshape(b, -1)
    # dedupe replicated ids (MIPS replication may return one item twice)
    order = np.argsort(-flat_scores, axis=1)
    out_ids = np.full((b, k), -1, np.int64)
    out_scores = np.full((b, k), -np.inf, np.float32)
    for i in range(b):
        seen = set()
        j = 0
        for idx in order[i]:
            v = int(flat_ids[i, idx])
            if v < 0 or v in seen:
                continue
            seen.add(v)
            out_ids[i, j] = v
            out_scores[i, j] = flat_scores[i, idx]
            j += 1
            if j == k:
                break
    return out_ids, out_scores, mask


# ---------------------------------------------------------------------------
# SPMD path (shard_map over the model axis)
# ---------------------------------------------------------------------------


def _local_search(g: H.HNSWArrays, q: jnp.ndarray, metric: str, k: int,
                  ef: int, max_iters: int):
    """hnsw_search without the jit wrapper (already inside shard_map)."""

    def one(qv):
        entry = H._greedy_descend(g, qv, metric, max_steps=64)
        scores, nodes = H._beam_search_bottom(g, qv, entry, metric, ef,
                                              max_iters)
        top_scores, idx = jax.lax.top_k(scores, k)
        nds = nodes[idx]
        ext = jnp.where(nds >= 0, g.ids[jnp.clip(nds, 0)], -1)
        return ext, top_scores

    return jax.vmap(one)(q)


def make_pyramid_search_fn(mesh: Mesh, cfg: PyramidConfig, *, k: int,
                           batch: int, ef: Optional[int] = None,
                           max_iters: int = 400, naive: bool = False,
                           model_axis: str = "model",
                           data_axis: Optional[str] = None):
    """Builds the jitted SPMD search step for a given mesh.

    The returned fn has signature
      fn(stacked: StackedShards, meta: HNSWArrays, part_of_center [m],
         queries [B, d]) -> (ids [B, k], scores [B, k])
    with ``stacked`` sharded over ``model`` on its leading (shard) axis and
    meta replicated. Capacity C = ceil(B * K / w * capacity_factor)
    (C = B for the naive baseline).

    When ``data_axis`` is given, the query batch is sharded over it (each
    data slice is an independent replica group serving its slice — the
    paper's replication axis) and ``batch`` must be the PER-REPLICA batch.
    """
    metric = "ip" if cfg.is_mips else cfg.metric
    ef = ef or cfg.ef_search
    w = cfg.num_shards
    n_model = mesh.shape[model_axis]
    assert w % n_model == 0, (w, n_model)
    w_local = w // n_model
    if naive:
        capacity = batch
    else:
        capacity = int(np.ceil(
            batch * cfg.branching_factor / w * cfg.capacity_factor))
        capacity = max(1, min(batch, capacity))

    def spmd(stacked: StackedShards, meta: H.HNSWArrays,
             part_of_center: jnp.ndarray, queries: jnp.ndarray):
        my = jax.lax.axis_index(model_axis)

        if naive:
            mask = jnp.ones((queries.shape[0], w), dtype=jnp.bool_)
        else:
            mask, _ = route_queries.__wrapped__(
                meta, part_of_center, queries, metric=metric,
                branching_factor=cfg.branching_factor, num_shards=w,
                ef=max(64, cfg.branching_factor))

        b = queries.shape[0]

        def one_shard(shard_slot: int):
            g = stacked.shard(shard_slot)
            global_shard = my * w_local + shard_slot
            q_mask = mask[:, global_shard]                       # [B]
            # static-size queue drain: indices of assigned queries; overflow
            # and empty slots point at the dummy row b (sliced off below).
            qidx = jnp.nonzero(q_mask, size=capacity, fill_value=b)[0]
            slot_valid = qidx < b
            qs = queries[jnp.clip(qidx, 0, b - 1)]               # [C, d]
            ids, scores = _local_search(g, qs, metric, k,
                                        max(ef, k), max_iters)
            ids = jnp.where(slot_valid[:, None], ids, -1)
            scores = jnp.where(slot_valid[:, None], scores, -jnp.inf)
            return qidx, ids, scores

        per = [one_shard(s) for s in range(w_local)]
        qidx = jnp.stack([p[0] for p in per])       # [w_local, C]
        ids = jnp.stack([p[1] for p in per])        # [w_local, C, k]
        scores = jnp.stack([p[2] for p in per])     # [w_local, C, k]

        # coordinator merge: gather partials from all shards
        qidx = jax.lax.all_gather(qidx, model_axis, tiled=True)    # [w, C]
        ids = jax.lax.all_gather(ids, model_axis, tiled=True)      # [w, C, k]
        scores = jax.lax.all_gather(scores, model_axis, tiled=True)

        # dummy row b absorbs invalid slots; sliced off before the merge
        out_scores = jnp.full((b + 1, w * k), -jnp.inf, jnp.float32)
        out_ids = jnp.full((b + 1, w * k), -1, jnp.int32)
        for s in range(w):
            col = slice(s * k, (s + 1) * k)
            out_scores = out_scores.at[qidx[s], col].set(scores[s])
            out_ids = out_ids.at[qidx[s], col].set(ids[s])
        top_scores, sel = jax.lax.top_k(out_scores[:b], k)
        top_ids = jnp.take_along_axis(out_ids[:b], sel, axis=1)
        return top_ids, top_scores

    qspec = P(data_axis) if data_axis else P()
    fn = shard_map(
        spmd, mesh=mesh,
        in_specs=(
            StackedShards(
                data=P(model_axis), ids=P(model_axis),
                bottom=P(model_axis), upper=P(model_axis),
                entry=P(model_axis), num_upper_levels=P(model_axis)),
            H.HNSWArrays(P(), P(), P(), P(), P(), P()),  # replicated meta
            P(),
            qspec,
        ),
        out_specs=(qspec, qspec),
        check_vma=False)
    return jax.jit(fn)
