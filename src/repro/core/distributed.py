"""Distributed query processing (Alg. 4) as an SPMD JAX program.

The paper's Kafka topic-per-sub-HNSW dispatch becomes capacity-bounded
dispatch over the ``model`` mesh axis (DESIGN.md §3):

  * the w sub-HNSWs live in ONE device-resident :class:`ShardArena`
    (``repro.core.arena``), sharded over ``model`` (each device owns
    w / |model| shards);
  * every device routes the (replicated) query batch through the
    replicated meta-HNSW, picks the <= C queries assigned to *its* shards
    (``jnp.nonzero(..., size=C)`` = static-shape queue draining), searches
    its local sub-HNSWs, and
  * partial results are combined with an ``all_gather`` + scatter +
    ``merge_topk`` dedup merge — the coordinator merge of Alg. 4 line 9.

Per-shard work drops from B queries (HNSW-naive) to C ≈ B·K/w — the paper's
throughput mechanism, realised as a FLOP reduction instead of queue load.

All three search paths (this SPMD program, ``search_single_host``, the
serving engine) are thin orchestrations of the same arena building blocks
— ``shard_search`` / ``scatter_partials`` / ``merge_topk`` — so they
cannot drift apart in merge or dedup semantics. ``search_single_host`` is
the single-host entry point used by tests, examples and CPU benchmarks;
``search_single_host_python`` preserves the pre-arena per-shard Python
loop as an independent oracle (and the "before" side of the fused-merge
microbench in ``benchmarks/fig7_throughput.py``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.jax_compat import shard_map

from repro.common.config import PyramidConfig
from repro.core import filters as F
from repro.core import hnsw as H
from repro.core import metrics as M
from repro.core import quant as Q
from repro.core.arena import (QuantizedShardArena, ShardArena,
                              arena_search, scatter_partials,
                              shard_search)
from repro.core.meta_index import PyramidIndex
from repro.core.router import route_queries
from repro.kernels.merge_topk import merge_topk

# Back-compat aliases: StackedShards was promoted to
# ``repro.core.arena.ShardArena`` (same pytree layout and field order).
StackedShards = ShardArena


def stack_shards(index: PyramidIndex) -> ShardArena:
    """Deprecated alias for ``index.arena()`` (memoised; prefer that)."""
    return index.arena()


# ---------------------------------------------------------------------------
# Single-host path (fused arena pipeline)
# ---------------------------------------------------------------------------


def _pow2(n: int) -> int:
    return 1 << (max(1, int(n)) - 1).bit_length()


def search_single_host(index: PyramidIndex, queries: np.ndarray, k: int, *,
                       ef: Optional[int] = None,
                       branching_factor: Optional[int] = None,
                       naive: bool = False, quantize: bool = False,
                       rerank_factor: int = 4,
                       filter_tags=None):
    """Alg. 4 single-host entry point, on the fused arena pipeline.

    Routes on device, then runs ``arena_search`` with a precomputed mask
    and capacity = the *actual* max per-shard load — exact reference
    semantics (no capacity drops) while still bounding per-shard work.
    The batch is padded to a power of two and the capacity to a multiple
    of 32 (tighter: capacity overshoot multiplies by w shards) so
    repeated calls with varying routing fan-out reuse the jit cache.

    naive=True searches every shard (the HNSW-naive baseline of Sec. III).
    quantize=True runs the pipeline over the int8 arena
    (``index.arena(dtype="int8")``): the beam search scores asymmetric
    float32-query x int8-database distances, returns the top
    ``rerank_factor * k`` candidates, and an exact float32 rerank
    against ``index.rerank_table()`` keeps the k best — recall@10 stays
    within 1% of the float path (see ``tests/test_quant.py``) while the
    device vector payload shrinks ~4x.

    ``filter_tags`` (scalar int64, or [B] per query) runs metadata-
    filtered kNN (``repro.core.filters``): the alive-mask is applied on
    device at the walk's candidate emission — pre-merge, never
    post-filter-then-under-fill — and the candidate budget
    (``ef``/per-shard k/``rerank_factor``) auto-inflates by
    1/selectivity (capped) so thin filters keep filling k.

    Returns (ids [B, k], scores [B, k], mask [B, w]); with
    ``quantize=True`` the scores are exact float32 similarities.
    """
    cfg = index.config
    ef = ef or cfg.ef_search
    kb = branching_factor or cfg.branching_factor
    metric = "ip" if cfg.is_mips else cfg.metric
    q = M.preprocess_queries(queries, cfg.metric)
    b = q.shape[0]
    w = index.num_shards
    arena = index.arena("int8" if quantize else "float32")

    tag_words = None
    filters_np = None
    inflate = 1
    if filter_tags is not None:
        filters_np = np.broadcast_to(
            np.asarray(filter_tags, dtype=np.int64), (b,)).copy()
        if np.any(filters_np != 0):
            tag_words = index.tags_arena()
            # size the candidate budget for the thinnest filter in the
            # batch (the filter-selectivity rerank rule, see API.md)
            sel = min(F.selectivity_np(index.tags_host(), int(f))
                      for f in np.unique(filters_np))
            inflate = F.inflation(sel)
        else:
            filters_np = None

    k_search = (k * rerank_factor if quantize else k) * inflate
    ef = max(ef * inflate, k_search)

    if naive:
        mask = np.ones((b, w), dtype=bool)
    else:
        mask_j, _ = route_queries(
            index.meta_arrays(), jnp.asarray(index.part_of_center),
            jnp.asarray(q), metric=metric, branching_factor=kb,
            num_shards=w, ef=max(64, kb))
        mask = np.asarray(mask_j)

    bp = _pow2(b)
    qp = q
    mp = mask
    fp = filters_np
    if bp > b:   # pad with the first query, routed nowhere
        qp = np.concatenate([q, np.repeat(q[:1], bp - b, axis=0)])
        mp = np.concatenate(
            [mask, np.zeros((bp - b, w), dtype=bool)])
        if fp is not None:   # pad rows run unfiltered (routed nowhere)
            fp = np.concatenate([fp, np.zeros(bp - b, np.int64)])
    max_load = int(mp.sum(axis=0).max())
    capacity = min(bp, max(32, -(-max_load // 32) * 32))

    filter_words = None
    if fp is not None:
        filter_words = jnp.asarray(F.filter_words(fp))
    ids, scores, _ = arena_search(
        arena, None, None, jnp.asarray(qp), metric=metric, k=k_search,
        ef=ef, capacity=capacity, mask=jnp.asarray(mp),
        tag_words=tag_words, filter_words=filter_words)
    if quantize:
        table_ids, table_vecs = index.rerank_table()
        out_ids, out_scores = Q.exact_rerank_np(
            q, np.asarray(ids)[:b], k, table_ids=table_ids,
            table_vecs=table_vecs, metric=metric)
        return out_ids, out_scores, mask
    return (np.asarray(ids)[:b, :k].astype(np.int64),
            np.asarray(scores)[:b, :k], mask)


def search_single_host_python(index: PyramidIndex, queries: np.ndarray,
                              k: int, *, ef: Optional[int] = None,
                              branching_factor: Optional[int] = None,
                              naive: bool = False):
    """Pre-arena reference: per-shard Python loop + host heap-free merge.

    Kept as an independent oracle for the fused pipeline (parity tests)
    and as the "before" baseline of the fig7 merge microbench, so it
    reproduces the pre-arena cost profile faithfully: each shard is
    uploaded as its own [n_i]-shaped ``device_arrays()`` per call (no
    shared arena, per-shard jit shapes). Same return contract as
    :func:`search_single_host`.
    """
    cfg = index.config
    ef = ef or cfg.ef_search
    kb = branching_factor or cfg.branching_factor
    metric = "ip" if cfg.is_mips else cfg.metric
    q = M.preprocess_queries(queries, cfg.metric)
    b = q.shape[0]
    w = index.num_shards

    if naive:
        mask = np.ones((b, w), dtype=bool)
    else:
        mask_j, _ = route_queries(
            index.meta_arrays(), jnp.asarray(index.part_of_center),
            jnp.asarray(q), metric=metric, branching_factor=kb,
            num_shards=w, ef=max(64, kb))
        mask = np.asarray(mask_j)

    all_scores = np.full((b, w, k), -np.inf, np.float32)
    all_ids = np.full((b, w, k), -1, np.int64)
    for s in range(w):
        sel = np.where(mask[:, s])[0]
        if sel.size == 0 or index.subs[s].n == 0:
            continue
        arrs = index.subs[s].device_arrays()   # pre-arena: private upload
        kk = min(k, index.subs[s].n)
        padded = _pow2(sel.size)   # pad for jit-cache reuse across fan-outs
        qs = q[sel]
        if padded > sel.size:
            qs = np.concatenate(
                [qs, np.repeat(qs[:1], padded - sel.size, axis=0)])
        ids, scores = H.hnsw_search(
            arrs, jnp.asarray(qs), metric=metric, k=kk, ef=ef)
        all_ids[sel, s, :kk] = np.asarray(ids)[: sel.size]
        all_scores[sel, s, :kk] = np.asarray(scores)[: sel.size]

    out_ids, out_scores = python_loop_merge(
        all_scores.reshape(b, -1), all_ids.reshape(b, -1), k)
    return out_ids, out_scores, mask


def python_loop_merge(flat_scores: np.ndarray, flat_ids: np.ndarray,
                      k: int):
    """The pre-arena per-query Python dedup merge (argsort + ``set``).

    Kept verbatim as the "before" side of the merge microbench — the
    fused pipeline replaces it with the ``merge_topk`` kernel.
    Dedupes replicated ids (MIPS replication may return one item twice).
    """
    b = flat_scores.shape[0]
    order = np.argsort(-flat_scores, axis=1)
    out_ids = np.full((b, k), -1, np.int64)
    out_scores = np.full((b, k), -np.inf, np.float32)
    for i in range(b):
        seen = set()
        j = 0
        for idx in order[i]:
            v = int(flat_ids[i, idx])
            if v < 0 or v in seen:
                continue
            seen.add(v)
            out_ids[i, j] = v
            out_scores[i, j] = flat_scores[i, idx]
            j += 1
            if j == k:
                break
    return out_ids, out_scores


# ---------------------------------------------------------------------------
# SPMD path (thin shard_map wrapper over the arena building blocks)
# ---------------------------------------------------------------------------


def make_pyramid_search_fn(mesh: Mesh, cfg: PyramidConfig, *, k: int,
                           batch: int, ef: Optional[int] = None,
                           max_iters: int = 400, naive: bool = False,
                           model_axis: str = "model",
                           data_axis: Optional[str] = None,
                           quantize: bool = False,
                           rerank_factor: int = 4,
                           index: Optional[PyramidIndex] = None):
    """Builds the jitted SPMD search step for a given mesh.

    The returned fn has signature
      fn(arena: ShardArena, meta: HNSWArrays, part_of_center [m],
         queries [B, d]) -> (ids [B, k], scores [B, k])
    with ``arena`` sharded over ``model`` on its leading (shard) axis and
    meta replicated. Capacity C = ceil(B * K / w * capacity_factor)
    (C = B for the naive baseline).

    When ``data_axis`` is given, the query batch is sharded over it (each
    data slice is an independent replica group serving its slice — the
    paper's replication axis) and ``batch`` must be the PER-REPLICA batch.

    With ``quantize=True`` the fn expects a ``QuantizedShardArena``
    (every leaf is shard-leading, so the same ``P(model_axis)`` sharding
    applies) and the on-device program searches/merges the top
    ``rerank_factor * k`` quantized candidates; the exact float32 rerank
    then runs host-side against ``index.rerank_table()`` — the
    full-precision copy lives with the coordinator (the paper's shared
    storage), never in device HBM — so ``index`` is required and the
    wrapper returns numpy ``(ids [B, k] int64, scores [B, k] f32)``.
    """
    metric = "ip" if cfg.is_mips else cfg.metric
    ef = ef or cfg.ef_search
    k_inner = k * rerank_factor if quantize else k
    ef = max(ef, k_inner)
    if quantize and index is None:
        raise ValueError(
            "make_pyramid_search_fn(quantize=True) needs index= for the "
            "exact float32 rerank table")
    w = cfg.num_shards
    n_model = mesh.shape[model_axis]
    assert w % n_model == 0, (w, n_model)
    w_local = w // n_model
    if naive:
        capacity = batch
    else:
        capacity = int(np.ceil(
            batch * cfg.branching_factor / w * cfg.capacity_factor))
        capacity = max(1, min(batch, capacity))

    def spmd(arena: ShardArena, meta: H.HNSWArrays,
             part_of_center: jnp.ndarray, queries: jnp.ndarray):
        my = jax.lax.axis_index(model_axis)
        b = queries.shape[0]

        if naive:
            mask = jnp.ones((b, w), dtype=jnp.bool_)
        else:
            mask, _ = route_queries.__wrapped__(
                meta, part_of_center, queries, metric=metric,
                branching_factor=cfg.branching_factor, num_shards=w,
                ef=max(64, cfg.branching_factor))

        # per-shard search on this device's local slice of the arena
        local_mask = jax.lax.dynamic_slice_in_dim(
            mask, my * w_local, w_local, axis=1)
        qidx, ids, scores = shard_search(
            arena, local_mask, queries, metric=metric, k=k_inner,
            ef=max(ef, k_inner), capacity=capacity, max_iters=max_iters,
            shard_axis="kernel", use_kernel=False)

        # coordinator merge: gather partials from all shards, then the
        # same scatter + dedup merge as the fused single-host pipeline
        # (jnp oracle: the interpret-mode kernel cannot run in shard_map)
        qidx = jax.lax.all_gather(qidx, model_axis, tiled=True)    # [w, C]
        ids = jax.lax.all_gather(ids, model_axis, tiled=True)  # [w, C, k]
        scores = jax.lax.all_gather(scores, model_axis, tiled=True)
        flat_s, flat_i = scatter_partials(qidx, ids, scores, b)
        top_scores, top_ids = merge_topk(flat_s, flat_i, k=k_inner,
                                         use_kernel=False)
        return top_ids, top_scores

    qspec = P(data_axis) if data_axis else P()
    if quantize:
        arena_spec = QuantizedShardArena(
            data=P(model_axis), ids=P(model_axis), bottom=P(model_axis),
            upper=P(model_axis), entry=P(model_axis),
            num_upper_levels=P(model_axis), scale=P(model_axis),
            zero=P(model_axis))
    else:
        arena_spec = ShardArena(
            data=P(model_axis), ids=P(model_axis), bottom=P(model_axis),
            upper=P(model_axis), entry=P(model_axis),
            num_upper_levels=P(model_axis))
    fn = shard_map(
        spmd, mesh=mesh,
        in_specs=(
            arena_spec,
            H.HNSWArrays(P(), P(), P(), P(), P(), P()),  # replicated meta
            P(),
            qspec,
        ),
        out_specs=(qspec, qspec),
        check_vma=False)
    jfn = jax.jit(fn)
    if not quantize:
        return jfn

    def reranked(arena, meta, part_of_center, queries):
        cand_ids, _ = jfn(arena, meta, part_of_center, queries)
        # resolve the table at CALL time (it is memoised on the index
        # and dropped by invalidate_device_cache): a caller that
        # add_items-ed and rebuilt the arena between calls must not
        # rerank new ids against a stale snapshot — they would silently
        # drop to (-1, -inf)
        table_ids, table_vecs = index.rerank_table()
        return Q.exact_rerank_np(
            np.asarray(queries), np.asarray(cand_ids), k,
            table_ids=table_ids, table_vecs=table_vecs, metric=metric)

    return reranked
