"""Pyramid index construction — Alg. 3 (Euclidean/angular) and Alg. 5 (MIPS).

The built artifact is a :class:`PyramidIndex`:
  * ``meta``        — the small meta-HNSW over k-means centers;
  * ``part_of_center`` [m] — graph-partition label of every meta vertex;
  * ``subs``        — w sub-HNSWs, one per partition, each holding the raw
                      vectors of its sub-dataset plus their *global* ids
                      (MIPS replication means one global id may appear in
                      several sub-datasets — Alg. 5 lines 12-15).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np
import jax.numpy as jnp

from repro.common.config import PyramidConfig
from repro.core import hnsw as H
from repro.core import metrics as M
from repro.core.kmeans import kmeans
from repro.core.partition import balance_stats, edge_cut, partition_graph
from repro.kernels.topk_distance import topk_similarity


@dataclasses.dataclass
class PyramidIndex:
    config: PyramidConfig
    meta: H.HNSWGraph                 # meta-HNSW over kmeans centers
    part_of_center: np.ndarray        # [m] int32: partition of each center
    subs: List[H.HNSWGraph]           # w sub-HNSWs (ids are global)
    build_stats: dict

    @property
    def num_shards(self) -> int:
        return len(self.subs)

    def arena(self):
        """The canonical device form (``repro.core.arena.ShardArena``),
        built once and shared by every consumer — engines, the reference
        search path and the SPMD program all read these same arrays."""
        if getattr(self, "_arena", None) is None:
            from repro.core.arena import ShardArena
            self._arena = ShardArena.from_index(self)
        return self._arena

    def meta_arrays(self) -> H.HNSWArrays:
        if getattr(self, "_meta_arrays", None) is None:
            self._meta_arrays = self.meta.device_arrays()
        return self._meta_arrays

    def sub_arrays(self, i: int) -> H.HNSWArrays:
        """Device view of shard ``i`` — a slice of the shared arena.

        Migration note: this used to upload a private per-shard copy
        (shape [n_i, ...]); it now returns the arena's equal-padded view
        (shape [n_pad, ...], isolated pad nodes). Searches behave
        identically; code that relied on ``data.shape[0] == subs[i].n``
        should read ``subs[i].n`` instead.
        """
        return self.arena().shard_view(i)

    def invalidate_device_cache(self) -> None:
        """Drop memoised device arrays after an in-place mutation of
        ``subs``/``meta`` (see ``repro.core.updates``)."""
        self._arena = None
        self._meta_arrays = None

    def __getstate__(self):
        # device caches are derived data: never pickled (save_index)
        state = dict(self.__dict__)
        state.pop("_arena", None)
        state.pop("_meta_arrays", None)
        return state


def _sample(x: np.ndarray, n_sample: int, rng) -> np.ndarray:
    if n_sample >= x.shape[0]:
        return x
    idx = rng.choice(x.shape[0], size=n_sample, replace=False)
    return x[idx]


def _assign_items(x: np.ndarray, meta_arrays: H.HNSWArrays,
                  part_of_center: np.ndarray, metric: str,
                  batch: int = 4096) -> np.ndarray:
    """Alg. 3 lines 7-10: nearest meta vertex -> its partition, per item."""
    n = x.shape[0]
    out = np.zeros(n, dtype=np.int32)
    for s in range(0, n, batch):
        qs = jnp.asarray(x[s: s + batch])
        ids, _ = H.hnsw_search(meta_arrays, qs, metric=metric, k=1, ef=32)
        out[s: s + batch] = part_of_center[np.asarray(ids)[:, 0]]
    return out


def build_pyramid_index(x: np.ndarray, cfg: PyramidConfig, *,
                        sample_queries: Optional[np.ndarray] = None,
                        verbose: bool = False) -> PyramidIndex:
    """Builds the full two-level Pyramid index (Alg. 3 / Alg. 5).

    Args:
      x: [n, d] dataset (raw; normalised internally for angular).
      cfg: index configuration; ``cfg.metric == 'ip'`` triggers Alg. 5.
      sample_queries: optional [B, d]: when given, center weights use query
        result frequency instead of cluster sizes (hot-item load balancing,
        Sec. III-A).
    """
    rng = np.random.default_rng(cfg.seed)
    x = M.preprocess_dataset(x, cfg.metric)
    n, d = x.shape
    m = min(cfg.meta_size, max(cfg.num_shards, n // 4))
    stats: dict = {"n": n, "d": d, "m": m, "w": cfg.num_shards}

    # -- Alg. 3 lines 3-5 / Alg. 5 lines 3-6: sample, kmeans, meta-HNSW ----
    sample = _sample(x, cfg.sample_size, rng)
    spherical = cfg.is_mips
    centers, counts = kmeans(sample, m, iters=cfg.kmeans_iters,
                             spherical=spherical, seed=cfg.seed)
    meta_metric = "ip" if cfg.is_mips else cfg.metric
    meta = H.build_hnsw(centers, metric=meta_metric,
                        max_degree=cfg.max_degree,
                        max_degree_upper=cfg.max_degree_upper,
                        ef_construction=cfg.ef_construction, seed=cfg.seed)

    # -- center weights: cluster sizes (or query-frequency when provided) --
    if sample_queries is not None:
        k_hot = 10
        ids, _ = H.search_numpy(meta, sample_queries, k=k_hot,
                                ef=cfg.ef_search)
        weights = np.bincount(ids[ids >= 0].reshape(-1), minlength=m) + 1.0
    else:
        weights = np.asarray(counts, dtype=np.float64) + 1.0

    # -- Alg. 3 line 6: balanced min-cut partition of the bottom layer -----
    part_of_center = partition_graph(
        meta.neighbors[0], weights, cfg.num_shards, seed=cfg.seed)
    stats["edge_cut"] = edge_cut(meta.neighbors[0], part_of_center)
    stats["balance"], stats["part_weights"] = balance_stats(
        weights, part_of_center, cfg.num_shards)

    # -- Alg. 3 lines 7-10: assign every item to a sub-dataset -------------
    meta_arrays = meta.device_arrays()
    item_part = _assign_items(x, meta_arrays, part_of_center, meta_metric)

    sub_ids: List[np.ndarray] = [
        np.where(item_part == i)[0] for i in range(cfg.num_shards)]

    # -- Alg. 5 lines 12-15: MIPS norm-replication -------------------------
    replicated = 0
    if cfg.is_mips and cfg.replication_r > 0:
        r = min(cfg.replication_r, n)
        # top-r MIPS neighbours of every meta vertex in the full dataset;
        # blocked Pallas scan (the paper suggests LSH here; exact scan is
        # affordable at our scale and strictly more faithful to recall).
        _, top_r = topk_similarity(
            jnp.asarray(centers), jnp.asarray(x), k=r, metric="ip")
        top_r = np.asarray(top_r)
        extra: List[set] = [set() for _ in range(cfg.num_shards)]
        for c in range(m):
            extra[part_of_center[c]].update(top_r[c].tolist())
        for i in range(cfg.num_shards):
            base = set(sub_ids[i].tolist())
            add = np.fromiter((v for v in extra[i] if v not in base),
                              dtype=np.int64, count=-1)
            replicated += add.size
            if add.size:
                sub_ids[i] = np.concatenate([sub_ids[i], add])
    stats["replicated_items"] = replicated
    stats["total_stored"] = int(sum(s.size for s in sub_ids))

    # -- Alg. 3 lines 11-12: build sub-HNSWs -------------------------------
    subs: List[H.HNSWGraph] = []
    for i in range(cfg.num_shards):
        ids_i = sub_ids[i]
        if ids_i.size == 0:  # degenerate partition: give it one random item
            ids_i = rng.choice(n, size=1)
            sub_ids[i] = ids_i
        sub = H.build_hnsw(
            x[ids_i], metric=meta_metric, max_degree=cfg.max_degree,
            max_degree_upper=cfg.max_degree_upper,
            ef_construction=cfg.ef_construction, seed=cfg.seed + 1 + i,
            ids=ids_i)
        subs.append(sub)
    stats["sub_sizes"] = [int(s.size) for s in sub_ids]
    if verbose:
        print(f"[pyramid] build stats: {stats}")
    return PyramidIndex(config=cfg, meta=meta,
                        part_of_center=part_of_center.astype(np.int32),
                        subs=subs, build_stats=stats)
