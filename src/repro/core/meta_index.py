"""Pyramid index construction — Alg. 3 (Euclidean/angular) and Alg. 5 (MIPS).

The built artifact is a :class:`PyramidIndex`:
  * ``meta``        — the small meta-HNSW over k-means centers;
  * ``part_of_center`` [m] — graph-partition label of every meta vertex;
  * ``subs``        — w sub-HNSWs, one per partition, each holding the raw
                      vectors of its sub-dataset plus their *global* ids
                      (MIPS replication means one global id may appear in
                      several sub-datasets — Alg. 5 lines 12-15).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np
import jax.numpy as jnp

from repro.common.config import PyramidConfig
from repro.core import hnsw as H


@dataclasses.dataclass
class PyramidIndex:
    config: PyramidConfig
    meta: H.HNSWGraph                 # meta-HNSW over kmeans centers
    part_of_center: np.ndarray        # [m] int32: partition of each center
    subs: List[H.HNSWGraph]           # w sub-HNSWs (ids are global)
    build_stats: dict

    @property
    def num_shards(self) -> int:
        return len(self.subs)

    def arena(self, dtype: str = "float32"):
        """The canonical device form, built once per storage dtype and
        shared by every consumer — engines, the reference search path
        and the SPMD program all read these same arrays.

        ``dtype="float32"`` (the default — unchanged from before) is a
        ``repro.core.arena.ShardArena``; ``dtype="int8"`` is the
        compressed ``QuantizedShardArena``, quantized host-side on this
        index's frozen grid (:meth:`quant_params`) so the device never
        holds a float32 copy of the vectors."""
        cache = getattr(self, "_arena", None)
        if not isinstance(cache, dict):   # None after invalidation
            cache = {}
            self._arena = cache
        if dtype not in cache:
            from repro.core.arena import QuantizedShardArena, ShardArena
            if dtype == "float32":
                cache[dtype] = ShardArena.from_index(self)
            elif dtype == "int8":
                cache[dtype] = QuantizedShardArena.from_index(
                    self, self.quant_params())
            else:
                raise ValueError(
                    f"arena dtype must be 'float32' or 'int8', "
                    f"got {dtype!r}")
        return cache[dtype]

    def quant_params(self):
        """This index's frozen int8 grid (``repro.core.quant.
        QuantParams``): derived from per-dimension min/max over all
        shards on first use, or attached from a store manifest
        (:meth:`attach_quant_params`). Deliberately NOT dropped by
        ``invalidate_device_cache`` — the grid stays frozen across
        ``add_items`` so appended rows (and their delta-log replay)
        quantize onto the identical grid, keeping rebuilt codes
        bit-identical to the live index's."""
        if getattr(self, "_quant_params", None) is None:
            from repro.core.quant import QuantParams
            self._quant_params = QuantParams.from_data(
                [g.data for g in self.subs if g.n])
        return self._quant_params

    def attach_quant_params(self, params) -> None:
        """Install a persisted grid (store load path) — reopening a
        quantized index must not re-derive params from post-replay data,
        or its codes would drift from the pre-restart engine's."""
        self._quant_params = params

    def rerank_table(self):
        """Host-side exact-rerank lookup: ``(sorted unique ids [N],
        float32 vectors [N, d])`` over every item in the index (MIPS
        replication deduped). This is the full-precision copy the
        quantized search reranks against — it lives in host memory, not
        HBM, which is the point of the compressed arena."""
        if getattr(self, "_rerank_table", None) is None:
            ids_all = np.concatenate(
                [np.asarray(g.ids, np.int64) for g in self.subs])
            vecs_all = np.concatenate(
                [np.asarray(g.data, np.float32) for g in self.subs])
            uniq, first = np.unique(ids_all, return_index=True)
            self._rerank_table = (uniq, np.ascontiguousarray(
                vecs_all[first]))
        return self._rerank_table

    def tags_arena(self) -> jnp.ndarray:
        """Device tag bitsets aligned with the arena stacking: ``[w,
        n_pad, 2]`` int32 word pairs (``repro.core.filters``), pad rows
        all-zero so they can never match a non-empty filter. Kept OUT of
        the arena pytree — adding a leaf would churn every SPMD
        partition spec — and memoised/invalidated alongside it."""
        if getattr(self, "_tags_arena", None) is None:
            from repro.core.filters import split_tag_words
            n_pad = max(1, max((g.n for g in self.subs), default=1))
            host = np.zeros((self.num_shards, n_pad), dtype=np.int64)
            for i, g in enumerate(self.subs):
                if g.n:
                    host[i, : g.n] = g.tags_or_zeros()
            self._tags_arena = jnp.asarray(split_tag_words(host))
        return self._tags_arena

    def tags_host(self) -> np.ndarray:
        """All item tag bitsets concatenated over shards ([sum n] int64,
        MIPS replication included) — the host-side view selectivity
        estimates read (``repro.core.filters.selectivity_np``)."""
        if getattr(self, "_tags_host", None) is None:
            parts = [g.tags_or_zeros() for g in self.subs]
            self._tags_host = (np.concatenate(parts) if parts
                               else np.zeros((0,), np.int64))
        return self._tags_host

    def meta_arrays(self) -> H.HNSWArrays:
        if getattr(self, "_meta_arrays", None) is None:
            self._meta_arrays = self.meta.device_arrays()
        return self._meta_arrays

    def sub_arrays(self, i: int) -> H.HNSWArrays:
        """Device view of shard ``i`` — a slice of the shared arena.

        Migration note: this used to upload a private per-shard copy
        (shape [n_i, ...]); it now returns the arena's equal-padded view
        (shape [n_pad, ...], isolated pad nodes). Searches behave
        identically; code that relied on ``data.shape[0] == subs[i].n``
        should read ``subs[i].n`` instead.
        """
        return self.arena().shard_view(i)

    def invalidate_device_cache(self) -> None:
        """Drop memoised device arrays after an in-place mutation of
        ``subs``/``meta`` (see ``repro.core.updates``). The quantization
        grid is NOT dropped: it is frozen state (see
        :meth:`quant_params`), so a rebuilt int8 arena requantizes the
        mutated data onto the same grid."""
        self._arena = None
        self._meta_arrays = None
        self._rerank_table = None
        self._tags_arena = None
        self._tags_host = None

    def delta_log(self):
        """The append-only insert journal this index is attached to, or
        ``None``. Set by :class:`repro.store.IndexStore` on publish/load;
        ``repro.core.updates.add_items`` writes through it so inserts
        survive a restart (replayed by ``IndexStore.load``)."""
        return getattr(self, "_delta_log", None)

    def attach_delta_log(self, log) -> None:
        self._delta_log = log

    def __getstate__(self):
        # device caches and the store attachment are derived/runtime
        # state: never pickled (legacy save_index) nor persisted; the
        # quantization grid DOES travel — it is frozen semantic state
        # (dropping it would re-derive a different grid after reload)
        state = dict(self.__dict__)
        state.pop("_arena", None)
        state.pop("_meta_arrays", None)
        state.pop("_rerank_table", None)
        state.pop("_tags_arena", None)
        state.pop("_tags_host", None)
        state.pop("_delta_log", None)
        return state


def _sample(x: np.ndarray, n_sample: int, rng) -> np.ndarray:
    if n_sample >= x.shape[0]:
        return x
    idx = rng.choice(x.shape[0], size=n_sample, replace=False)
    return x[idx]


def _assign_items(x: np.ndarray, meta_arrays: H.HNSWArrays,
                  part_of_center: np.ndarray, metric: str,
                  batch: int = 4096) -> np.ndarray:
    """Alg. 3 lines 7-10: nearest meta vertex -> its partition, per item."""
    n = x.shape[0]
    out = np.zeros(n, dtype=np.int32)
    for s in range(0, n, batch):
        qs = jnp.asarray(x[s: s + batch])
        ids, _ = H.hnsw_search(meta_arrays, qs, metric=metric, k=1, ef=32)
        out[s: s + batch] = part_of_center[np.asarray(ids)[:, 0]]
    return out


def build_pyramid_index(x: np.ndarray, cfg: PyramidConfig, *,
                        sample_queries: Optional[np.ndarray] = None,
                        verbose: bool = False) -> PyramidIndex:
    """Builds the full two-level Pyramid index (Alg. 3 / Alg. 5),
    sequentially.

    Thin wrapper over the staged builder in :mod:`repro.build` with the
    sub-HNSW fan-out pinned to the in-process sequential path; use
    :func:`repro.build.build_pyramid_index_parallel` to spread the
    per-partition builds over a process pool (bit-identical result).

    Args:
      x: [n, d] dataset (raw; normalised internally for angular).
      cfg: index configuration; ``cfg.metric == 'ip'`` triggers Alg. 5.
      sample_queries: optional [B, d]: when given, center weights use query
        result frequency instead of cluster sizes (hot-item load balancing,
        Sec. III-A).
    """
    from repro.build.planner import build_pyramid_index_parallel
    return build_pyramid_index_parallel(
        x, cfg, workers=0, sample_queries=sample_queries, verbose=verbose)
