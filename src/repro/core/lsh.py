"""Distributed LSH baseline (PLSH [26] stand-in).

The paper compares against LSH-based distributed systems (PLSH; not open
source). This is a faithful small-scale stand-in: random-projection
hashing (p-stable / SimHash family) with multi-table lookup, rows randomly
partitioned across shards and EVERY shard probed per query (PLSH's
broadcast model — no routing, the contrast to Pyramid's selective
dispatch).

Candidate generation is bucket lookup; candidates are reranked exactly
with the topk_distance Pallas kernel. Used by the fig9-style comparison
and available as a third system for ablations.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M
from repro.kernels.topk_distance import topk_similarity


@dataclasses.dataclass
class LSHTable:
    projections: np.ndarray    # [num_bits, d]
    offsets: np.ndarray        # [num_bits] (E2LSH-style, l2 only)
    width: float
    buckets: dict              # hash tuple -> np.ndarray of local ids


@dataclasses.dataclass
class LSHShard:
    ids: np.ndarray            # [n_local] global ids
    data: np.ndarray           # [n_local, d]
    tables: List[LSHTable]


@dataclasses.dataclass
class DistributedLSH:
    metric: str
    shards: List[LSHShard]
    num_bits: int
    num_tables: int


def _hash(table: LSHTable, x: np.ndarray, metric: str) -> np.ndarray:
    """[B, d] -> [B, num_bits] int codes."""
    proj = x @ table.projections.T
    if metric == "l2":
        return np.floor((proj + table.offsets) / table.width).astype(
            np.int32)
    return (proj > 0).astype(np.int32)   # SimHash for ip/angular


def build_lsh(x: np.ndarray, *, metric: str = "l2", num_shards: int = 8,
              num_tables: int = 8, num_bits: int = 12, width: float = 2.0,
              seed: int = 0) -> DistributedLSH:
    x = M.preprocess_dataset(x, metric)
    n, d = x.shape
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    shards = []
    for s in range(num_shards):
        local = perm[s::num_shards]
        data = x[local]
        tables = []
        for t in range(num_tables):
            trng = np.random.default_rng(seed * 1000 + s * 100 + t)
            proj = trng.normal(size=(num_bits, d)).astype(np.float32)
            off = trng.uniform(0, width, size=num_bits).astype(np.float32)
            table = LSHTable(proj, off, width, {})
            codes = _hash(table, data, metric)
            for i, code in enumerate(map(tuple, codes)):
                table.buckets.setdefault(code, []).append(i)
            table.buckets = {k: np.asarray(v, dtype=np.int64)
                             for k, v in table.buckets.items()}
            tables.append(table)
        shards.append(LSHShard(ids=local, data=data, tables=tables))
    return DistributedLSH(metric=metric, shards=shards,
                          num_bits=num_bits, num_tables=num_tables)


def search_lsh(index: DistributedLSH, queries: np.ndarray, k: int,
               max_candidates: int = 2048
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Probe ALL shards (PLSH broadcast), union buckets, exact rerank.

    Returns (ids [B, k], scores [B, k]); -1/-inf padded when fewer than k
    candidates hash into the probed buckets.
    """
    q = M.preprocess_queries(queries, index.metric)
    b = q.shape[0]
    out_ids = np.full((b, k), -1, np.int64)
    out_scores = np.full((b, k), -np.inf, np.float32)
    metric = "ip" if index.metric == "angular" else index.metric
    for i in range(b):
        cands: List[np.ndarray] = []
        gids: List[np.ndarray] = []
        for shard in index.shards:
            local: List[np.ndarray] = []
            for table in shard.tables:
                code = tuple(_hash(table, q[i: i + 1], index.metric)[0])
                hit = table.buckets.get(code)
                if hit is not None:
                    local.append(hit)
            if local:
                ulocal = np.unique(np.concatenate(local))
                cands.append(shard.data[ulocal])
                gids.append(shard.ids[ulocal])
        if not cands:
            continue
        cand = np.concatenate(cands)[:max_candidates]
        gid = np.concatenate(gids)[:max_candidates]
        kk = min(k, cand.shape[0])
        scores, idx = topk_similarity(
            jnp.asarray(q[i: i + 1]), jnp.asarray(cand), k=kk,
            metric=metric)
        out_ids[i, :kk] = gid[np.asarray(idx)[0]]
        out_scores[i, :kk] = np.asarray(scores)[0]
    return out_ids, out_scores
