"""Symmetric-int8 vector quantization for the compressed ShardArena.

The quantization grid is per-dimension affine: dimension ``j`` stores
codes ``c = clip(rint((x - zero[j]) / scale[j]), -127, 127)`` with the
zero-point at the dimension's value-range midpoint, so the int8 range is
used symmetrically around it and ``dequantize`` is one fused
multiply-add (``x_hat = c * scale + zero``).

Distance computation is *asymmetric* (ADC): queries stay float32 and are
scored against dequantized database rows — the
``repro.kernels.quant_distance`` family implements exactly
``similarity(q, dequantize(codes))`` for all three metrics, so the
quantized search differs from the float path only by the (bounded)
per-dimension rounding error, which the exact float32 rerank
(:func:`exact_rerank_np`) then removes from the top of the result list.

The grid is FROZEN once derived: ``repro.store`` persists it in the
version manifest and delta-log replay requantizes appended rows through
the same params, so a recovered engine's int8 codes are bit-identical to
the pre-crash engine's (see ``tests/test_quant.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple, Union

import numpy as np

from repro.core import metrics as M

# int8 code range: symmetric, 254 steps between the per-dim min and max
# (-128 is never produced, so negation/round-trips cannot saturate)
_LEVELS = 254.0
_CODE_MIN, _CODE_MAX = -127, 127


@dataclasses.dataclass
class QuantParams:
    """Frozen per-dimension int8 quantization grid.

    Attributes:
      scale: [d] float32, step size per dimension (always > 0).
      zero:  [d] float32, zero-point (value-range midpoint) per dimension.
    """

    scale: np.ndarray
    zero: np.ndarray

    def __post_init__(self):
        self.scale = np.ascontiguousarray(self.scale, np.float32)
        self.zero = np.ascontiguousarray(self.zero, np.float32)

    @property
    def d(self) -> int:
        return int(self.scale.shape[0])

    @classmethod
    def from_data(cls, data: Union[np.ndarray, Sequence[np.ndarray]]
                  ) -> "QuantParams":
        """Derive the grid from per-dimension min/max over ``data`` (one
        [n, d] array or a sequence of them, e.g. one per shard —
        accumulated without concatenating, so deriving params never
        doubles the dataset's host memory). Deterministic: a pure
        function of the data values."""
        if isinstance(data, np.ndarray):
            data = [data]
        lo = hi = None
        for block in data:
            block = np.asarray(block, np.float32)
            if block.size == 0:
                continue
            blo, bhi = block.min(axis=0), block.max(axis=0)
            lo = blo if lo is None else np.minimum(lo, blo)
            hi = bhi if hi is None else np.maximum(hi, bhi)
        if lo is None:
            raise ValueError("cannot derive QuantParams from empty data")
        lo64, hi64 = lo.astype(np.float64), hi.astype(np.float64)
        scale = np.maximum(hi64 - lo64, 1e-12) / _LEVELS
        zero = (lo64 + hi64) / 2.0
        return cls(scale=scale.astype(np.float32),
                   zero=zero.astype(np.float32))

    def quantize(self, x: np.ndarray) -> np.ndarray:
        """[*, d] float32 -> [*, d] int8 codes (rint = round-half-even,
        matching jnp semantics bit-for-bit)."""
        x = np.asarray(x, np.float32)
        codes = np.rint((x - self.zero) / self.scale)
        return np.clip(codes, _CODE_MIN, _CODE_MAX).astype(np.int8)

    def dequantize(self, codes: np.ndarray) -> np.ndarray:
        """[*, d] int8 codes -> [*, d] float32 reconstruction."""
        return (np.asarray(codes, np.float32) * self.scale
                + self.zero).astype(np.float32)

    # -- manifest (de)serialisation -----------------------------------------

    def to_manifest(self) -> Dict:
        """JSON-able form persisted in the store manifest. Python floats
        round-trip float32 values exactly through JSON repr, so a
        reopened index requantizes on the identical grid."""
        return {
            "dtype": "int8",
            "bits": 8,
            "scale": [float(v) for v in self.scale],
            "zero": [float(v) for v in self.zero],
        }

    @classmethod
    def from_manifest(cls, entry: Dict) -> "QuantParams":
        if entry.get("dtype") != "int8":
            raise ValueError(
                f"unsupported quantization dtype {entry.get('dtype')!r}")
        return cls(scale=np.asarray(entry["scale"], np.float32),
                   zero=np.asarray(entry["zero"], np.float32))


def exact_rerank_np(queries: np.ndarray, cand_ids: np.ndarray, k: int, *,
                    table_ids: np.ndarray, table_vecs: np.ndarray,
                    metric: str) -> Tuple[np.ndarray, np.ndarray]:
    """Exact float32 rerank of quantized-search candidates.

    Rescores each query's candidate list against the original
    full-precision vectors (``PyramidIndex.rerank_table()``) with the
    same similarity the float path uses, and keeps the k best. Stable on
    exact-score ties: tied candidates keep their incoming (quantized
    top-k) order, so the rerank is deterministic.

    Args:
      queries: [B, d] float32 *preprocessed* queries.
      cand_ids: [B, m] int external ids, -1 padded, deduped (the output
        of a ``merge_topk`` pass over quantized partials).
      k: neighbours to keep (k <= m for a meaningful rerank).
      table_ids: [N] int64 sorted unique external ids.
      table_vecs: [N, d] float32 vectors aligned with ``table_ids``.

    Returns (ids [B, k] int64, scores [B, k] float32) best-first,
    (-1, -inf) padded; scores are exact float32 similarities.
    """
    queries = np.asarray(queries, np.float32)
    cand_ids = np.asarray(cand_ids)
    b, m = cand_ids.shape
    out_ids = np.full((b, k), -1, np.int64)
    out_scores = np.full((b, k), -np.inf, np.float32)
    pos = np.searchsorted(table_ids, np.clip(cand_ids, 0, None))
    pos = np.clip(pos, 0, max(len(table_ids) - 1, 0))
    found = np.logical_and(cand_ids >= 0, table_ids[pos] == cand_ids)
    for i in range(b):
        vi = np.where(found[i])[0]
        if vi.size == 0:
            continue
        vecs = table_vecs[pos[i, vi]]
        s = M.similarity_matrix_np(queries[i][None, :], vecs, metric)[0]
        order = np.argsort(-s, kind="stable")[:k]
        out_ids[i, : order.size] = cand_ids[i, vi[order]]
        out_scores[i, : order.size] = s[order]
    return out_ids, out_scores
