"""The paper's public API (Sec. IV-A, Listings 1-3).

Thin, faithful wrappers over the futures-based client surface
(``repro.core.client``) so user code reads exactly like the paper:

    gc = GraphConstructor(data_path, name, metric)
    gc.build_graphs(para)

    coord = Coordinator(brokers, graph_path, name, metric)
    res = coord.execute(query, para)                 # sync
    coord.execute_async(query, para, callback)       # async + callback

    ex = Executor(brokers, graph_path_and_id, name, metric)
    ex.start(para)

New code should use :class:`repro.core.client.PyramidClient` directly
(see API.md); the classes here exist for fidelity with the paper's
listings and delegate everything to the client.

"brokers" is the in-process engine registry (our Kafka stand-in,
DESIGN.md §3); graph paths point at ``launch.build_index`` artifacts.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import threading
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.common.config import PyramidConfig
from repro.core.client import (PyramidClient, SearchFuture,  # noqa: F401
                               gather)
from repro.core.meta_index import PyramidIndex
from repro.launch.build_index import load_index
from repro.serving.engine import QueryResult, ServingEngine

logger = logging.getLogger(__name__)


@dataclasses.dataclass
class QueryPara:
    """Query-processing parameters (the paper's ``para``)."""
    k: int = 10
    branching_factor: Optional[int] = None   # K
    timeout_s: float = 60.0


@dataclasses.dataclass
class BuildPara:
    """Index-construction parameters (the paper's ``para``)."""
    meta_size: int = 1_000          # m
    num_shards: int = 16            # w
    sample_size: int = 20_000       # n'
    replication_r: int = 0          # r (MIPS, Alg. 5)
    max_degree: int = 32
    ef_construction: int = 100
    workers: int = 0                # >1: process-pool sub-HNSW fan-out


def _check_metric(index: PyramidIndex, metric: str) -> None:
    if not (index.config.metric == metric or
            (metric == "ip" and index.config.is_mips)):
        raise ValueError(
            f"index metric {index.config.metric} != {metric}")


class Brokers:
    """Stand-in for the Kafka broker list: owns one ServingEngine per
    dataset name. Clients/executors attach to it.

    Usable as a context manager::

        with Brokers() as brokers:
            client = brokers.open_client("wiki", path)
            ...
    # engines shut down on exit
    """

    def __init__(self):
        self._engines: Dict[str, ServingEngine] = {}
        self._lock = threading.Lock()

    # -- engine registry ---------------------------------------------------

    def engine_for(self, name: str, index: PyramidIndex, *,
                   replicas: Optional[int] = None,
                   **engine_kw) -> ServingEngine:
        """Get or create the engine serving ``name``.

        ``replicas=None`` means "attach to whatever is running". When an
        engine already exists, a conflicting request is never silently
        ignored: a different index config raises, a different replica
        count logs a structured warning (the running group is kept —
        resize explicitly via ``engine.scale``). Extra kwargs (e.g.
        ``registry=``/``tracer=`` for observability, ``quantize=True``)
        pass through to the :class:`ServingEngine` constructor and only
        apply when this call actually creates the engine.
        """
        with self._lock:   # checks under the lock: a concurrent
            eng = self._engines.get(name)   # replace_index must not hand
            if eng is not None:             # back a stale engine
                return self._check_attach(name, eng, index, replicas)
        # engine startup (array builds, thread spawns, jit warmup) is
        # expensive: build outside the lock, install with a re-check
        new = ServingEngine(index, replicas=replicas or 1, **engine_kw)
        with self._lock:
            eng = self._engines.get(name)
            if eng is None:
                self._engines[name] = new
                return new
        new.shutdown()   # lost the creation race: don't leak threads
        with self._lock:
            return self._check_attach(name, eng, index, replicas)

    def _check_attach(self, name: str, eng: ServingEngine,
                      index: PyramidIndex,
                      replicas: Optional[int]) -> ServingEngine:
        """Attach to a running engine — never silently: a conflicting
        index config raises, a conflicting replica count warns."""
        if index.config != eng.index.config:
            raise ValueError(
                f"brokers: engine '{name}' already serves an index "
                f"with config {eng.index.config}; refusing to attach "
                f"a mismatched index (config {index.config}). Use "
                f"replace_index() to hot-swap.")
        if replicas is not None and replicas != eng.replicas:
            logger.warning(
                "brokers.engine_for: engine=%s requested_replicas=%d "
                "configured_replicas=%d — request ignored; use "
                "engine.scale(shard, n) to resize the running group "
                "(live counts: engine.stats()['replicas'])",
                name, replicas, eng.replicas)
        return eng

    def get_engine(self, name: str) -> ServingEngine:
        with self._lock:
            if name not in self._engines:
                raise KeyError(
                    f"brokers: no engine named '{name}' "
                    f"(known: {sorted(self._engines)})")
            return self._engines[name]

    def replace_index(self, name: str,
                      index) -> Optional[ServingEngine]:
        """Hot-swap ``name``'s engine onto a freshly built index (the
        paper's ``refresh()`` notification). The replacement engine is
        started *before* the old one is torn down — carrying over the
        old engine's *live* per-shard replica counts (which ``scale()``
        may have grown past the constructor setting) — and clients
        opened via :meth:`open_client` resolve it on their next call.

        ``index`` may be a built :class:`PyramidIndex` or a *store
        path*: a ``str``/``PathLike`` is opened as a
        :class:`repro.store.IndexStore` and its latest published version
        (plus delta-log replay) becomes the replacement — the paper's
        "constructor publishes to HDFS, serving layer refreshes" flow.

        If ``name`` has no running engine there is nothing to swap:
        returns ``None`` and the next ``open_client`` / ``engine_for``
        lazily starts on the fresh index (no engine is spawned for a
        dataset nobody is serving)."""
        if isinstance(index, (str, os.PathLike)):
            with self._lock:   # nothing to swap? don't pay a full store
                running = name in self._engines   # load just to drop it
            if not running:
                return None
            from repro.store import IndexStore
            index = IndexStore(str(index)).load()
        with self._lock:
            old = self._engines.get(name)
        if old is None:
            return None
        # the replacement inherits the old engine's registry and tracer:
        # hedge/expiry/swap counters stay monotonic across hot-swaps
        # (registration is idempotent) and one trace spans the swap
        new = ServingEngine(index, replicas=old.replicas,
                            registry=old.obs, tracer=old.tracer)
        for s in range(min(old.w, new.w)):
            live = old.replica_count(s)
            if live >= 1 and live != new.replica_count(s):
                new.scale(s, live)
        with self._lock:
            current = self._engines.get(name)
            if current is old:   # won the race: install
                self._engines[name] = new
            else:   # lost to a concurrent replace_index or shutdown()
                loser = new
        if current is old:
            if old is not None:
                old.drain()     # in-flight futures finish on the old
                old.shutdown()  # engine; only then tear it down
            return new
        loser.shutdown()   # never installed: don't leak its threads
        if current is not None:
            return current
        raise RuntimeError(
            f"brokers: engine '{name}' was removed (brokers shut down?) "
            f"during replace_index")

    def close_engine(self, name: str) -> bool:
        """Shut down and deregister ONE engine (the tenant manager's
        eviction path). Returns whether an engine was actually closed;
        clients bound via :meth:`open_client` fail their next call with
        ``KeyError`` until the name is served again."""
        with self._lock:
            eng = self._engines.pop(name, None)
        if eng is None:
            return False
        eng.drain()
        eng.shutdown()
        return True

    def attach_maintenance(self, name: str, store, **opts):
        """Create a :class:`repro.store.maintenance.Compactor` wired to
        this broker entry: it folds ``name``'s delta log into new
        versions of ``store`` and hot-swaps the engine through
        :meth:`replace_index`. The compactor is installed on the
        running engine (drain-hook step clock +
        ``stats()['maintenance']``) when one exists; call ``.start()``
        on the result for the background thread, or drive
        ``run_once()``/``tick()`` deterministically."""
        from repro.store import IndexStore
        from repro.store.maintenance import Compactor
        if not isinstance(store, IndexStore):
            store = IndexStore(str(store))
        eng = None
        with self._lock:
            eng = self._engines.get(name)
        index = eng.index if eng is not None else store.load()
        if eng is not None:   # share the serving observability plane:
            opts.setdefault("registry", eng.obs)   # one scrape / trace
            opts.setdefault("tracer", eng.tracer)  # covers both
        compactor = Compactor(store, index, brokers=self, name=name,
                              **opts)
        if eng is not None:
            compactor.install(eng)
        return compactor

    # -- client surface ----------------------------------------------------

    def open_client(self, name: str, path: str, *,
                    metric: Optional[str] = None,
                    replicas: Optional[int] = None) -> PyramidClient:
        """Return a :class:`PyramidClient` session bound to this broker
        entry — the client tracks ``replace_index`` hot-swaps.

        ``path`` is only read when ``name`` is not yet served (the first
        session pays the index load; later sessions attach to the
        running engine and validate against *its* index)."""
        with self._lock:
            eng = self._engines.get(name)
        index = eng.index if eng is not None else load_index(path)
        if metric is not None:
            _check_metric(index, metric)
        self.engine_for(name, index, replicas=replicas)
        return PyramidClient(
            engine_resolver=lambda: self.get_engine(name), name=name)

    def shutdown(self):
        with self._lock:
            engines = list(self._engines.values())
            self._engines.clear()
        for e in engines:
            e.shutdown()

    def __enter__(self) -> "Brokers":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


class Coordinator:
    """Listing 1. Receives queries, routes via the meta-HNSW, merges.

    Shim over :class:`PyramidClient`: ``execute*`` submit through the
    client and block on the returned futures."""

    def __init__(self, brokers: Brokers, graph_path: str, name: str,
                 metric: str, replicas: int = 1):
        self.index = load_index(graph_path)
        _check_metric(self.index, metric)
        self.name = name
        self.engine = brokers.engine_for(name, self.index,
                                         replicas=replicas)
        # resolve through the brokers so a replace_index hot-swap (the
        # paper's refresh) keeps this coordinator working
        self.client = PyramidClient(
            engine_resolver=lambda: brokers.get_engine(name), name=name)

    def execute(self, query: np.ndarray, para: QueryPara) -> QueryResult:
        """Synchronous top-k search for ONE query vector."""
        return self.client.search(
            query, para.k,
            branching_factor=para.branching_factor).result(para.timeout_s)

    def execute_batch(self, queries: np.ndarray,
                      para: QueryPara) -> List[QueryResult]:
        """Synchronous batch search, one result per query (submit order).

        The whole batch shares one ``para.timeout_s`` deadline; a query
        missing it raises ``TimeoutError`` — a short result list can no
        longer be returned silently.
        """
        futures = self.client.search_batch(
            queries, para.k, branching_factor=para.branching_factor)
        return gather(futures, para.timeout_s)

    def execute_async(self, query: np.ndarray, para: QueryPara,
                      callback: Callable[[QueryResult], None]) -> None:
        """Returns immediately; ``callback`` fires with the final result
        (no per-query OS thread — delivery rides the engine's merger)."""
        fut = self.client.search(query, para.k,
                                 branching_factor=para.branching_factor)

        def deliver(f):
            if f.exception() is None:
                callback(f.result(0))
            else:   # failed future (e.g. engine shutdown): no result to
                logger.warning(   # deliver — don't raise into the merger
                    "execute_async: query %d failed: %s", f.query_id,
                    f.exception())

        fut.add_done_callback(deliver)


class Executor:
    """Listing 2. In the paper a standalone process serving one sub-HNSW;
    here executors live inside the engine — ``start`` grows the replica
    group for this dataset and ``stop`` shrinks it back, both through
    the public ``engine.scale`` API (elastic scalability, Sec. IV-B)."""

    def __init__(self, brokers: Brokers, graph_path: str, name: str,
                 metric: str, shard_id: Optional[int] = None):
        self.index = load_index(graph_path)
        _check_metric(self.index, metric)
        self.name = name
        self.brokers = brokers
        self.shard_id = shard_id
        self._started: List[int] = []

    def start(self, para: Optional[QueryPara] = None) -> None:
        engine = self.brokers.engine_for(self.name, self.index)
        shards = ([self.shard_id] if self.shard_id is not None
                  else range(engine.w))
        for s in shards:
            engine.scale(s, engine.replica_count(s) + 1)
            self._started.append(s)

    def stop(self) -> None:
        engine = self.brokers.engine_for(self.name, self.index)
        for s in self._started:
            engine.scale(s, max(1, engine.replica_count(s) - 1))
        self._started.clear()


class GraphConstructor:
    """Listing 3. Builds (and refreshes) the meta-HNSW + sub-HNSWs.

    The paper's constructor builds sub-HNSWs in parallel across the
    cluster and persists them to shared storage; here ``para.workers``
    fans the per-partition builds over a process pool
    (:func:`repro.build.build_pyramid_index_parallel`, bit-identical to
    sequential) and ``build_graphs`` publishes a version into the
    :class:`repro.store.IndexStore` at ``out_path``."""

    def __init__(self, data: np.ndarray, metric: str, out_path: str):
        self.data = data
        self.metric = metric
        self.out_path = out_path
        self._index: Optional[PyramidIndex] = None

    def build_graphs(self, para: BuildPara) -> PyramidIndex:
        from repro.build import build_pyramid_index_parallel
        cfg = PyramidConfig(
            metric=self.metric, num_shards=para.num_shards,
            meta_size=para.meta_size,
            sample_size=min(para.sample_size, len(self.data)),
            max_degree=para.max_degree,
            max_degree_upper=max(para.max_degree // 2, 4),
            ef_construction=para.ef_construction,
            replication_r=para.replication_r)
        self._index = build_pyramid_index_parallel(
            self.data, cfg, workers=para.workers)
        from repro.store import IndexStore
        IndexStore(self.out_path).publish(self._index)
        return self._index

    def refresh(self, new_data: np.ndarray, para: BuildPara,
                brokers: Optional[Brokers] = None,
                name: Optional[str] = None) -> PyramidIndex:
        """Re-read the dataset, rebuild, notify coordinators/executors
        (the paper's ``refresh()``): the engine for ``name`` is
        hot-swapped onto the fresh index via
        :meth:`Brokers.replace_index` — no private state is touched and
        clients bound through ``open_client`` keep working."""
        self.data = new_data
        index = self.build_graphs(para)
        if brokers is not None and name is not None:
            brokers.replace_index(name, index)
        return index
