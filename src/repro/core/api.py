"""The paper's public API (Sec. IV-A, Listings 1-3).

Thin, faithful wrappers over the engine/index internals so user code reads
exactly like the paper:

    gc = GraphConstructor(data_path, name, metric)
    gc.build_graphs(para)

    coord = Coordinator(brokers, graph_path, name, metric)
    res = coord.execute(query, para)                 # sync
    coord.execute_async(query, para, callback)       # async + callback

    ex = Executor(brokers, graph_path_and_id, name, metric)
    ex.start(para)

"brokers" is the in-process engine (our Kafka stand-in, DESIGN.md §3);
graph paths point at ``launch.build_index`` artifacts.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, List, Optional

import numpy as np

from repro.common.config import PyramidConfig
from repro.core.meta_index import PyramidIndex, build_pyramid_index
from repro.launch.build_index import load_index, save_index
from repro.serving.engine import QueryResult, ServingEngine


@dataclasses.dataclass
class QueryPara:
    """Query-processing parameters (the paper's ``para``)."""
    k: int = 10
    branching_factor: Optional[int] = None   # K
    timeout_s: float = 60.0


@dataclasses.dataclass
class BuildPara:
    """Index-construction parameters (the paper's ``para``)."""
    meta_size: int = 1_000          # m
    num_shards: int = 16            # w
    sample_size: int = 20_000       # n'
    replication_r: int = 0          # r (MIPS, Alg. 5)
    max_degree: int = 32
    ef_construction: int = 100


class Brokers:
    """Stand-in for the Kafka broker list: owns one ServingEngine per
    dataset name. Executors/coordinators attach to it."""

    def __init__(self):
        self._engines = {}
        self._lock = threading.Lock()

    def engine_for(self, name: str, index: PyramidIndex, *,
                   replicas: int = 1) -> ServingEngine:
        with self._lock:
            if name not in self._engines:
                self._engines[name] = ServingEngine(index,
                                                    replicas=replicas)
            return self._engines[name]

    def shutdown(self):
        with self._lock:
            for e in self._engines.values():
                e.shutdown()
            self._engines.clear()


class Coordinator:
    """Listing 1. Receives queries, routes via the meta-HNSW, merges."""

    def __init__(self, brokers: Brokers, graph_path: str, name: str,
                 metric: str, replicas: int = 1):
        self.index = load_index(graph_path)
        assert (self.index.config.metric == metric or
                (metric == "ip" and self.index.config.is_mips)), \
            f"index metric {self.index.config.metric} != {metric}"
        self.name = name
        self.engine = brokers.engine_for(name, self.index,
                                         replicas=replicas)

    def execute(self, query: np.ndarray, para: QueryPara) -> QueryResult:
        """Synchronous top-k search for ONE query vector."""
        res = self.execute_batch(query[None, :], para)
        return res[0]

    def execute_batch(self, queries: np.ndarray,
                      para: QueryPara) -> List[QueryResult]:
        qids = self.engine.submit(queries, k=para.k,
                                  branching_factor=para.branching_factor)
        got = self.engine.collect(len(qids), timeout=para.timeout_s)
        by_id = {r.query_id: r for r in got}
        return [by_id[q] for q in qids if q in by_id]

    def execute_async(self, query: np.ndarray, para: QueryPara,
                      callback: Callable[[QueryResult], None]) -> None:
        """Returns immediately; ``callback`` fires with the final result."""

        def run():
            callback(self.execute(query, para))

        threading.Thread(target=run, daemon=True).start()


class Executor:
    """Listing 2. In the paper a standalone process serving one sub-HNSW;
    here executors live inside the engine — ``start`` scales the replica
    group for this dataset (elastic scalability, Sec. IV-B)."""

    def __init__(self, brokers: Brokers, graph_path: str, name: str,
                 metric: str, shard_id: Optional[int] = None):
        self.index = load_index(graph_path)
        self.name = name
        self.brokers = brokers
        self.shard_id = shard_id
        self._started = []

    def start(self, para: Optional[QueryPara] = None) -> None:
        engine = self.brokers.engine_for(self.name, self.index)
        shards = ([self.shard_id] if self.shard_id is not None
                  else range(engine.w))
        for s in shards:
            replica = sum(1 for n in engine.executors if f"-s{s}-" in n)
            engine._spawn(s, replica)
            self._started.append((s, replica))

    def stop(self) -> None:
        engine = self.brokers.engine_for(self.name, self.index)
        for s, r in self._started:
            name = f"exec-s{s}-r{r}"
            if name in engine.executors:
                engine.kill_executor(name)
        self._started.clear()


class GraphConstructor:
    """Listing 3. Builds (and refreshes) the meta-HNSW + sub-HNSWs."""

    def __init__(self, data: np.ndarray, metric: str, out_path: str):
        self.data = data
        self.metric = metric
        self.out_path = out_path
        self._index: Optional[PyramidIndex] = None

    def build_graphs(self, para: BuildPara) -> PyramidIndex:
        cfg = PyramidConfig(
            metric=self.metric, num_shards=para.num_shards,
            meta_size=para.meta_size,
            sample_size=min(para.sample_size, len(self.data)),
            max_degree=para.max_degree,
            max_degree_upper=max(para.max_degree // 2, 4),
            ef_construction=para.ef_construction,
            replication_r=para.replication_r)
        self._index = build_pyramid_index(self.data, cfg)
        save_index(self._index, self.out_path)
        return self._index

    def refresh(self, new_data: np.ndarray, para: BuildPara,
                brokers: Optional[Brokers] = None,
                name: Optional[str] = None) -> PyramidIndex:
        """Re-read the dataset, rebuild, notify coordinators/executors
        (the paper's ``refresh()``): the engine for ``name`` is torn down
        and lazily rebuilt on next use with the fresh index."""
        self.data = new_data
        index = self.build_graphs(para)
        if brokers is not None and name is not None:
            with brokers._lock:
                eng = brokers._engines.pop(name, None)
            if eng is not None:
                eng.shutdown()
        return index
