"""ShardArena — the single canonical device form of a PyramidIndex.

Every consumer of a built index (the single-host reference path, the
threaded serving engine, the SPMD ``shard_map`` program) used to carry its
own device representation: per-shard ``HNSWArrays`` uploads with per-shard
jit compiles here, a stacked array pytree there. The arena unifies them:

  * all w sub-HNSWs are stacked on a leading shard axis, equal-padded with
    isolated nodes (all -1 neighbours, id -1, zero vector) that the walk
    can never reach nor return;
  * it is built ONCE per index (``PyramidIndex.arena()`` memoises) and
    shared by every engine/executor/search path — one HBM copy, and one
    jit compile for all shards because every shard view has equal shapes;
  * ``arena_search`` is the fused route -> per-shard capacity-bounded beam
    search (vmapped over the shard axis) -> dedup-top-k merge pipeline,
    entirely on device, with the merge running as the ``merge_topk``
    Pallas kernel.

The per-stage helpers (``shard_search``, ``scatter_partials``) are the
building blocks the SPMD path wraps in ``shard_map`` — the three search
paths differ only in *where* the stages run, never in what they compute.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hnsw as H
from repro.core.router import route_queries
from repro.kernels.merge_topk import merge_topk


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardArena:
    """All w sub-HNSWs stacked on a leading shard axis.

    Padding: graphs are padded to the max sub-dataset size with isolated
    nodes (all -1 neighbours, id -1, zero vector) which can never be
    reached by the walk nor returned (ids filtered by the merge).
    """

    data: jnp.ndarray     # [w, n_pad, d]
    ids: jnp.ndarray      # [w, n_pad] (-1 pad)
    bottom: jnp.ndarray   # [w, n_pad, M0]
    upper: jnp.ndarray    # [w, L, n_pad, Mu]
    entry: jnp.ndarray    # [w]
    num_upper_levels: jnp.ndarray  # [w]

    def __post_init__(self):
        self._views: Dict[int, H.HNSWArrays] = {}

    def tree_flatten(self):
        return (self.data, self.ids, self.bottom, self.upper, self.entry,
                self.num_upper_levels), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_shards(self) -> int:
        return self.data.shape[0]

    def shard(self, i) -> H.HNSWArrays:
        """Uncached view of shard ``i`` (safe on traced values, e.g.
        inside ``shard_map``/``vmap`` where ``i`` indexes local slots)."""
        return H.HNSWArrays(
            data=self.data[i], ids=self.ids[i], bottom=self.bottom[i],
            upper=self.upper[i], entry=self.entry[i],
            num_upper_levels=self.num_upper_levels[i])

    def shard_view(self, i: int) -> H.HNSWArrays:
        """Memoised concrete view of shard ``i``: every executor replica
        serving the shard shares ONE set of device arrays (host-side use
        only — never call with traced operands)."""
        if i not in self._views:
            self._views[i] = self.shard(i)
        return self._views[i]

    @classmethod
    def from_index(cls, index) -> "ShardArena":
        """Stack ``index.subs`` into one equal-padded device structure.

        Builds the stacked buffers host-side straight from the
        ``HNSWGraph`` fields (same layout as ``device_arrays``) so the
        arena costs ONE device upload — no per-shard upload/download
        round trip. Prefer ``index.arena()`` (memoised) over calling
        this directly.
        """
        subs = index.subs
        n_pad = max(g.n for g in subs)
        l_pad = max(1, max(g.max_level for g in subs))
        mu = max([lv.shape[1] for g in subs for lv in g.neighbors[1:]],
                 default=1)
        m0 = max(g.neighbors[0].shape[1] for g in subs)
        d = subs[0].d
        w = len(subs)

        data = np.zeros((w, n_pad, d), np.float32)
        ids = np.full((w, n_pad), -1, np.int32)
        bottom = np.full((w, n_pad, m0), -1, np.int32)
        upper = np.full((w, l_pad, n_pad, mu), -1, np.int32)
        entry = np.zeros((w,), np.int32)
        nul = np.zeros((w,), np.int32)
        for i, g in enumerate(subs):
            n = g.n
            data[i, :n] = g.data
            ids[i, :n] = g.ids
            bottom[i, :n, : g.neighbors[0].shape[1]] = g.neighbors[0]
            for lvl in range(1, g.max_level + 1):
                lv = g.neighbors[lvl]
                upper[i, lvl - 1, :n, : lv.shape[1]] = lv
            entry[i] = int(g.entry)
            nul[i] = int(g.max_level)
        return cls(
            data=jnp.asarray(data), ids=jnp.asarray(ids),
            bottom=jnp.asarray(bottom), upper=jnp.asarray(upper),
            entry=jnp.asarray(entry), num_upper_levels=jnp.asarray(nul))


# ---------------------------------------------------------------------------
# Fused pipeline stages (shared by arena_search and the SPMD wrapper)
# ---------------------------------------------------------------------------


def shard_search(arena: ShardArena, mask: jnp.ndarray, queries: jnp.ndarray,
                 *, metric: str, k: int, ef: int, capacity: int,
                 max_iters: int = 400, shard_axis: str = "vmap"):
    """Capacity-bounded beam search mapped over the shard axis.

    Each shard drains its <= ``capacity`` assigned queries from ``mask``
    (``jnp.nonzero(..., size=C)`` = static-shape queue draining; overflow
    and empty slots point at the dummy row B and are invalidated).

    Args:
      arena: the shards to search — all of them (local slice inside SPMD).
      mask: [B, w_arena] bool routing mask aligned with ``arena``.
      queries: [B, d] preprocessed queries.
      shard_axis: "vmap" batches the shard axis (right on TPU, where the
        graph gathers stay one MXU/VPU-friendly program); "map" lowers it
        to a sequential ``lax.map`` — XLA:CPU specialises gathers from a
        2-D table far better than batched gathers from the stacked 3-D
        table (~2x on the CPU reference path), and the per-shard loop is
        sequential on one core anyway.

    Returns (qidx [w, C] i32, ids [w, C, k] i32, scores [w, C, k] f32).
    """
    b = queries.shape[0]

    def one_shard(data, ids_, bottom, upper, entry, nul, shard_mask):
        g = H.HNSWArrays(data=data, ids=ids_, bottom=bottom, upper=upper,
                         entry=entry, num_upper_levels=nul)
        qidx = jnp.nonzero(shard_mask, size=capacity, fill_value=b)[0]
        slot_valid = qidx < b
        qs = queries[jnp.clip(qidx, 0, b - 1)]               # [C, d]
        ids_out, scores_out = jax.vmap(lambda qv: H.search_one(
            g, qv, metric=metric, k=k, ef=ef, max_iters=max_iters))(qs)
        ids_out = jnp.where(slot_valid[:, None], ids_out, -1)
        scores_out = jnp.where(slot_valid[:, None], scores_out, -jnp.inf)
        return qidx.astype(jnp.int32), ids_out, scores_out

    leaves = (arena.data, arena.ids, arena.bottom, arena.upper,
              arena.entry, arena.num_upper_levels, mask.T)
    if shard_axis == "map":
        return jax.lax.map(lambda t: one_shard(*t), leaves)
    return jax.vmap(one_shard)(*leaves)


def scatter_partials(qidx: jnp.ndarray, ids: jnp.ndarray,
                     scores: jnp.ndarray, b: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter per-shard partials back to query rows.

    Args: qidx [w, C], ids [w, C, k], scores [w, C, k] (the dummy row b
    absorbs invalid slots and is sliced off).
    Returns (scores [B, w*k] f32, ids [B, w*k] i32) ready for the merge.
    """
    w, _, k = ids.shape
    out_s = jnp.full((b + 1, w, k), -jnp.inf, jnp.float32)
    out_i = jnp.full((b + 1, w, k), -1, jnp.int32)
    shard_col = jnp.arange(w)[:, None]          # broadcast against [w, C]
    out_s = out_s.at[qidx, shard_col].set(scores)
    out_i = out_i.at[qidx, shard_col].set(ids)
    return out_s[:b].reshape(b, w * k), out_i[:b].reshape(b, w * k)


def _search_scatter_merge(arena: ShardArena, mask: jnp.ndarray,
                          queries: jnp.ndarray, *, metric: str, k: int,
                          ef: int, capacity: int, max_iters: int,
                          use_kernel: bool, shard_axis: str):
    """The shared post-routing pipeline body: shard_search -> scatter ->
    dedup merge. Both jitted entry points delegate here."""
    b = queries.shape[0]
    qidx, ids, scores = shard_search(
        arena, mask, queries, metric=metric, k=k, ef=ef,
        capacity=capacity, max_iters=max_iters, shard_axis=shard_axis)
    flat_s, flat_i = scatter_partials(qidx, ids, scores, b)
    top_s, top_i = merge_topk(flat_s, flat_i, k=k, use_kernel=use_kernel)
    return top_i, top_s


@functools.partial(jax.jit, static_argnames=(
    "metric", "k", "ef", "branching_factor", "capacity", "max_iters",
    "naive", "use_kernel", "shard_axis"))
def _fused_routed(arena: ShardArena, meta: H.HNSWArrays,
                  part_of_center: jnp.ndarray, queries: jnp.ndarray, *,
                  metric: str, k: int, ef: int, branching_factor: int,
                  capacity: int, max_iters: int, naive: bool,
                  use_kernel: bool, shard_axis: str):
    """route -> shard_search -> scatter -> merge, one jitted program."""
    b = queries.shape[0]
    w = arena.data.shape[0]
    if naive:
        mask = jnp.ones((b, w), dtype=jnp.bool_)
    else:
        mask, _ = route_queries.__wrapped__(
            meta, part_of_center, queries, metric=metric,
            branching_factor=branching_factor, num_shards=w,
            ef=max(64, branching_factor))
    top_i, top_s = _search_scatter_merge(
        arena, mask, queries, metric=metric, k=k, ef=ef,
        capacity=capacity, max_iters=max_iters, use_kernel=use_kernel,
        shard_axis=shard_axis)
    return top_i, top_s, mask


@functools.partial(jax.jit, static_argnames=(
    "metric", "k", "ef", "capacity", "max_iters", "use_kernel",
    "shard_axis"))
def _fused_masked(arena: ShardArena, mask: jnp.ndarray,
                  queries: jnp.ndarray, *, metric: str, k: int, ef: int,
                  capacity: int, max_iters: int, use_kernel: bool,
                  shard_axis: str):
    """shard_search -> scatter -> merge with a caller-provided mask."""
    return _search_scatter_merge(
        arena, mask, queries, metric=metric, k=k, ef=ef,
        capacity=capacity, max_iters=max_iters, use_kernel=use_kernel,
        shard_axis=shard_axis)


def arena_search(arena: ShardArena, meta: H.HNSWArrays,
                 part_of_center: jnp.ndarray, queries: jnp.ndarray, *,
                 metric: str, k: int, ef: int = 100,
                 branching_factor: int = 4,
                 capacity: Optional[int] = None,
                 capacity_factor: float = 2.0, max_iters: int = 400,
                 naive: bool = False, use_kernel: bool = True,
                 mask: Optional[jnp.ndarray] = None,
                 shard_axis: Optional[str] = None):
    """Fused distributed search over a device-resident arena (Alg. 4).

    Routes through the replicated meta-HNSW, beam-searches the <= K
    routed shards per query under a per-shard capacity bound, and merges
    partials with the dedup-top-k kernel — one jitted program, no host
    round-trips between the stages.

    Args:
      queries: [B, d] *preprocessed* queries (see ``M.preprocess_queries``).
      capacity: per-shard query slots; defaults to
        ``ceil(B * K / w * capacity_factor)`` (B when ``naive``) — the
        paper's throughput mechanism realised as a FLOP bound.
      naive: search every shard (the HNSW-naive baseline of Sec. III).
      mask: optional precomputed [B, w] routing mask; skips the routing
        stage (the reference path uses this to guarantee zero drops).
      shard_axis: "vmap" | "map" shard-axis strategy (see
        :func:`shard_search`); default "map" on CPU, "vmap" elsewhere.

    Returns (ids [B, k] i32, scores [B, k] f32, mask [B, w] bool).
    """
    b = queries.shape[0]
    w = arena.num_shards
    if shard_axis is None:
        shard_axis = "map" if jax.default_backend() == "cpu" else "vmap"
    if capacity is None:
        if naive:
            capacity = b
        else:
            capacity = int(np.ceil(
                b * branching_factor / w * capacity_factor))
    capacity = max(1, min(b, int(capacity)))
    if mask is not None:
        ids, scores = _fused_masked(
            arena, jnp.asarray(mask), queries, metric=metric, k=k, ef=ef,
            capacity=capacity, max_iters=max_iters, use_kernel=use_kernel,
            shard_axis=shard_axis)
        return ids, scores, mask
    return _fused_routed(
        arena, meta, part_of_center, queries, metric=metric, k=k, ef=ef,
        branching_factor=branching_factor, capacity=capacity,
        max_iters=max_iters, naive=naive, use_kernel=use_kernel,
        shard_axis=shard_axis)
