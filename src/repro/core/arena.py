"""ShardArena — the single canonical device form of a PyramidIndex.

Every consumer of a built index (the single-host reference path, the
threaded serving engine, the SPMD ``shard_map`` program) used to carry its
own device representation: per-shard ``HNSWArrays`` uploads with per-shard
jit compiles here, a stacked array pytree there. The arena unifies them:

  * all w sub-HNSWs are stacked on a leading shard axis, equal-padded with
    isolated nodes (all -1 neighbours, id -1, zero vector) that the walk
    can never reach nor return;
  * it is built ONCE per index (``PyramidIndex.arena()`` memoises) and
    shared by every engine/executor/search path — one HBM copy, and one
    jit compile for all shards because every shard view has equal shapes;
  * ``arena_search`` is the fused route -> per-shard capacity-bounded beam
    search (vmapped over the shard axis) -> dedup-top-k merge pipeline,
    entirely on device, with the merge running as the ``merge_topk``
    Pallas kernel.

The per-stage helpers (``shard_search``, ``scatter_partials``) are the
building blocks the SPMD path wraps in ``shard_map`` — the three search
paths differ only in *where* the stages run, never in what they compute.

A :class:`QuantizedShardArena` is the int8-compressed twin
(``index.arena(dtype="int8")``): same stacked layout, ~4x smaller HBM
vector payload, asymmetric float32-query x int8-database distances
(``repro.kernels.quant_distance``) inside the identical pipeline.
Callers that want float-path recall rerank the top ``rerank_factor * k``
candidates exactly (``repro.core.quant.exact_rerank_np``) — see
``search_single_host(quantize=True)``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hnsw as H
from repro.core.router import route_queries
from repro.kernels.beam_search import beam_search
from repro.kernels.merge_topk import merge_topk


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardArena:
    """All w sub-HNSWs stacked on a leading shard axis.

    Padding: graphs are padded to the max sub-dataset size with isolated
    nodes (all -1 neighbours, id -1, zero vector) which can never be
    reached by the walk nor returned (ids filtered by the merge).
    """

    data: jnp.ndarray     # [w, n_pad, d]
    ids: jnp.ndarray      # [w, n_pad] (-1 pad)
    bottom: jnp.ndarray   # [w, n_pad, M0]
    upper: jnp.ndarray    # [w, L, n_pad, Mu]
    entry: jnp.ndarray    # [w]
    num_upper_levels: jnp.ndarray  # [w]

    def __post_init__(self):
        self._views: Dict[int, H.HNSWArrays] = {}

    def tree_flatten(self):
        return (self.data, self.ids, self.bottom, self.upper, self.entry,
                self.num_upper_levels), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_shards(self) -> int:
        return self.data.shape[0]

    @property
    def vector_nbytes(self) -> int:
        """Bytes of the vector payload (what quantization compresses;
        adjacency/ids are common to both arena forms)."""
        return int(self.data.nbytes)

    @property
    def total_nbytes(self) -> int:
        return int(sum(leaf.nbytes for leaf in self.tree_flatten()[0]))

    def shard(self, i) -> H.HNSWArrays:
        """Uncached view of shard ``i`` (safe on traced values, e.g.
        inside ``shard_map``/``vmap`` where ``i`` indexes local slots)."""
        return H.HNSWArrays(
            data=self.data[i], ids=self.ids[i], bottom=self.bottom[i],
            upper=self.upper[i], entry=self.entry[i],
            num_upper_levels=self.num_upper_levels[i])

    def as_graph(self) -> H.HNSWArrays:
        """Reinterpret already-sliced leaves as one graph — for use
        inside ``vmap``/``lax.map`` over the shard axis, where every
        leaf has lost its leading ``w`` dimension."""
        return H.HNSWArrays(
            data=self.data, ids=self.ids, bottom=self.bottom,
            upper=self.upper, entry=self.entry,
            num_upper_levels=self.num_upper_levels)

    def shard_view(self, i: int) -> H.HNSWArrays:
        """Memoised concrete view of shard ``i``: every executor replica
        serving the shard shares ONE set of device arrays (host-side use
        only — never call with traced operands)."""
        if i not in self._views:
            self._views[i] = self.shard(i)
        return self._views[i]

    @classmethod
    def from_index(cls, index) -> "ShardArena":
        """Stack ``index.subs`` into one equal-padded device structure.

        Builds the stacked buffers host-side straight from the
        ``HNSWGraph`` fields (same layout as ``device_arrays``) so the
        arena costs ONE device upload — no per-shard upload/download
        round trip. Prefer ``index.arena()`` (memoised) over calling
        this directly.
        """
        st = _stack_host(index)
        return cls(
            data=jnp.asarray(st["data"]), ids=jnp.asarray(st["ids"]),
            bottom=jnp.asarray(st["bottom"]),
            upper=jnp.asarray(st["upper"]),
            entry=jnp.asarray(st["entry"]),
            num_upper_levels=jnp.asarray(st["num_upper_levels"]))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedShardArena:
    """Int8-compressed arena: same stacked layout as :class:`ShardArena`
    but ``data`` holds codes on a per-dimension affine grid
    (``repro.core.quant.QuantParams``) — the HBM vector payload shrinks
    ~4x, which is what lets a device serve a dataset its HBM could not
    hold in float32.

    ``scale``/``zero`` are the GLOBAL grid tiled per shard ([w, d]), so
    every leaf is shard-leading — the SPMD program shards all leaves
    over the ``model`` axis with one spec, and ``vmap``/``lax.map`` over
    the shard axis map the whole pytree uniformly. Quantization happens
    host-side at build, so no float32 copy of the vectors ever reaches
    the device.
    """

    data: jnp.ndarray     # [w, n_pad, d] int8 codes
    ids: jnp.ndarray      # [w, n_pad] (-1 pad)
    bottom: jnp.ndarray   # [w, n_pad, M0]
    upper: jnp.ndarray    # [w, L, n_pad, Mu]
    entry: jnp.ndarray    # [w]
    num_upper_levels: jnp.ndarray  # [w]
    scale: jnp.ndarray    # [w, d] f32 (global grid, tiled per shard)
    zero: jnp.ndarray     # [w, d] f32

    def __post_init__(self):
        self._views: Dict[int, H.QuantHNSWArrays] = {}

    def tree_flatten(self):
        return (self.data, self.ids, self.bottom, self.upper, self.entry,
                self.num_upper_levels, self.scale, self.zero), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_shards(self) -> int:
        return self.data.shape[0]

    @property
    def vector_nbytes(self) -> int:
        return int(self.data.nbytes + self.scale.nbytes
                   + self.zero.nbytes)

    @property
    def total_nbytes(self) -> int:
        return int(sum(leaf.nbytes for leaf in self.tree_flatten()[0]))

    def shard(self, i) -> H.QuantHNSWArrays:
        return H.QuantHNSWArrays(
            data=self.data[i], ids=self.ids[i], bottom=self.bottom[i],
            upper=self.upper[i], entry=self.entry[i],
            num_upper_levels=self.num_upper_levels[i],
            scale=self.scale[i], zero=self.zero[i])

    def as_graph(self) -> H.QuantHNSWArrays:
        return H.QuantHNSWArrays(
            data=self.data, ids=self.ids, bottom=self.bottom,
            upper=self.upper, entry=self.entry,
            num_upper_levels=self.num_upper_levels, scale=self.scale,
            zero=self.zero)

    def shard_view(self, i: int) -> H.QuantHNSWArrays:
        if i not in self._views:
            self._views[i] = self.shard(i)
        return self._views[i]

    @classmethod
    def from_index(cls, index, params) -> "QuantizedShardArena":
        """Quantize ``index.subs`` onto ``params``' grid and stack.

        The codes are produced host-side from the float graph data
        (``QuantParams.quantize`` row by shard), so building a quantized
        arena never uploads a float32 copy of the vectors — the device
        only ever sees int8. Prefer ``index.arena(dtype="int8")``
        (memoised) over calling this directly.
        """
        st = _stack_host(index, quantize=params.quantize)
        w = st["data"].shape[0]
        scale = np.tile(params.scale[None, :], (w, 1))
        zero = np.tile(params.zero[None, :], (w, 1))
        return cls(
            data=jnp.asarray(st["data"]), ids=jnp.asarray(st["ids"]),
            bottom=jnp.asarray(st["bottom"]),
            upper=jnp.asarray(st["upper"]),
            entry=jnp.asarray(st["entry"]),
            num_upper_levels=jnp.asarray(st["num_upper_levels"]),
            scale=jnp.asarray(scale), zero=jnp.asarray(zero))


def _stack_host(index, quantize=None) -> Dict[str, np.ndarray]:
    """Stack ``index.subs`` into equal-padded host arrays (the shared
    body of both ``from_index`` builders). ``quantize`` maps each
    shard's [n, d] float rows to its stored dtype (int8 codes for the
    quantized arena); pad rows stay zero in either dtype — they are
    unreachable (no neighbours, id -1), so their code values are inert.
    """
    subs = index.subs
    # an all-deleted shard has n == 0: give it one pad row (id -1, no
    # neighbours) so the walk lands on an inert slot the merges filter
    n_pad = max(1, max(g.n for g in subs))
    l_pad = max(1, max(g.max_level for g in subs))
    mu = max([lv.shape[1] for g in subs for lv in g.neighbors[1:]],
             default=1)
    m0 = max(g.neighbors[0].shape[1] for g in subs)
    d = subs[0].d
    w = len(subs)

    data = np.zeros((w, n_pad, d),
                    np.int8 if quantize is not None else np.float32)
    ids = np.full((w, n_pad), -1, np.int32)
    bottom = np.full((w, n_pad, m0), -1, np.int32)
    upper = np.full((w, l_pad, n_pad, mu), -1, np.int32)
    entry = np.zeros((w,), np.int32)
    nul = np.zeros((w,), np.int32)
    for i, g in enumerate(subs):
        n = g.n
        data[i, :n] = quantize(g.data) if quantize is not None else g.data
        ids[i, :n] = g.ids
        bottom[i, :n, : g.neighbors[0].shape[1]] = g.neighbors[0]
        for lvl in range(1, g.max_level + 1):
            lv = g.neighbors[lvl]
            upper[i, lvl - 1, :n, : lv.shape[1]] = lv
        entry[i] = int(g.entry) if n else 0  # empty shard: enter pad row
        nul[i] = int(g.max_level)
    return {"data": data, "ids": ids, "bottom": bottom, "upper": upper,
            "entry": entry, "num_upper_levels": nul}


# ---------------------------------------------------------------------------
# Fused pipeline stages (shared by arena_search and the SPMD wrapper)
# ---------------------------------------------------------------------------


def shard_search(arena: ShardArena, mask: jnp.ndarray, queries: jnp.ndarray,
                 *, metric: str, k: int, ef: int, capacity: int,
                 max_iters: int = 400, shard_axis: str = "kernel",
                 use_kernel: bool = True,
                 tag_words: Optional[jnp.ndarray] = None,
                 filter_words: Optional[jnp.ndarray] = None):
    """Capacity-bounded beam search mapped over the shard axis.

    Each shard drains its <= ``capacity`` assigned queries from ``mask``
    (``jnp.nonzero(..., size=C)`` = static-shape queue draining; overflow
    and empty slots point at the dummy row B and are invalidated).

    Args:
      arena: the shards to search — all of them (local slice inside SPMD).
      mask: [B, w_arena] bool routing mask aligned with ``arena``.
      queries: [B, d] preprocessed queries.
      shard_axis: "kernel" (default) runs every (shard, slot) pair
        through ONE fused beam-walk op (``repro.kernels.beam_search``) —
        the Pallas kernel on TPU, the flattened batched oracle elsewhere.
        It retires the old backend split ("map" on CPU, "vmap" on TPU)
        behind one strategy: all w * C rows walk in one loop whose trip
        count is the global max. "vmap" / "map" keep the per-query
        ``while_loop`` batched / sequentially mapped over the shard axis
        (the roofline's measured baselines; "map"'s per-shard early
        termination keeps it the fastest multi-shard path on CPU — see
        API.md "Fused beam search" for the honest numbers — but it is w
        sequential dispatches that cannot feed the Pallas kernel).
      use_kernel: allow the Pallas kernel ("kernel" strategy on TPU).
        Must be False inside ``shard_map`` — same rule as ``merge_topk``.
      tag_words / filter_words: optional metadata alive-mask
        (``repro.core.filters``): [w, n_pad, 2] i32 item tag words
        aligned with the arena stacking (``PyramidIndex.tags_arena``)
        and [B, 2] i32 per-query filter words. Dead candidates leave
        each shard as (-inf, -1) — the per-shard partials are already
        filtered BEFORE the cross-shard merge, so a filtered query
        fills its k from live matches only.

    Returns (qidx [w, C] i32, ids [w, C, k] i32, scores [w, C, k] f32).

    Works identically over a float :class:`ShardArena` and a
    :class:`QuantizedShardArena` — every strategy maps the arena
    *pytree* (every leaf is shard-leading); the quantized arena routes
    its frozen grid into the dequantize-scoring variant of the walk, so
    the representation-specific distance is preserved.
    """
    b = queries.shape[0]
    # per-slot filter words follow the same queue-drain gather as the
    # queries: a dummy row of zero words absorbs invalid slots, so
    # overflow/empty slots always walk unfiltered (their results are
    # invalidated below anyway)
    fw_pad = None
    if tag_words is not None and filter_words is not None:
        fw_pad = jnp.concatenate(
            [filter_words.astype(jnp.int32),
             jnp.zeros((1, 2), jnp.int32)], axis=0)          # [B+1, 2]

    if shard_axis == "kernel":
        # drain each shard's queue, then walk ALL (shard, slot) rows in
        # one fused op — same math as vmap(search_one) per slot
        qidx = jax.vmap(
            lambda col: jnp.nonzero(col, size=capacity, fill_value=b)[0])(
                mask.T)                                      # [w, C]
        slot_valid = qidx < b
        qs = queries[jnp.clip(qidx, 0, b - 1)]               # [w, C, d]
        entries = jax.vmap(lambda sl, qrow: jax.vmap(
            lambda qv: H._greedy_descend(
                sl.as_graph(), qv, metric, max_steps=64))(qrow))(
                    arena, qs)                               # [w, C]
        scale = getattr(arena, "scale", None)
        efb = max(ef, k)
        scores, nodes = beam_search(
            arena.data, arena.bottom, qs, entries, metric=metric,
            ef=efb, max_iters=max_iters,
            scale=None if scale is None else scale[0],
            zero=None if scale is None else arena.zero[0],
            use_kernel=use_kernel,
            tag_words=tag_words,
            filter_words=None if fw_pad is None else fw_pad[qidx])
        kk = min(k, scores.shape[-1])
        top_scores, idx = jax.lax.top_k(scores, kk)
        top_nodes = jnp.take_along_axis(nodes, idx, axis=2)
        ids_out = jax.vmap(lambda ids_s, tn: jnp.where(
            tn >= 0, ids_s[jnp.clip(tn, 0)], -1))(arena.ids, top_nodes)
        if kk < k:  # shards smaller than k: pad
            w = qidx.shape[0]
            pad = k - kk
            ids_out = jnp.concatenate(
                [ids_out, jnp.full((w, capacity, pad), -1, jnp.int32)],
                axis=2)
            top_scores = jnp.concatenate(
                [top_scores,
                 jnp.full((w, capacity, pad), -jnp.inf, jnp.float32)],
                axis=2)
        ids_out = jnp.where(slot_valid[:, :, None], ids_out, -1)
        scores_out = jnp.where(
            slot_valid[:, :, None], top_scores, -jnp.inf)
        return qidx.astype(jnp.int32), ids_out, scores_out

    def one_shard(arena_slice, shard_mask, tw=None):
        g = arena_slice.as_graph()
        qidx = jnp.nonzero(shard_mask, size=capacity, fill_value=b)[0]
        slot_valid = qidx < b
        qs = queries[jnp.clip(qidx, 0, b - 1)]               # [C, d]
        if tw is None:
            ids_out, scores_out = jax.vmap(lambda qv: H.search_one(
                g, qv, metric=metric, k=k, ef=ef,
                max_iters=max_iters))(qs)
        else:
            ids_out, scores_out = jax.vmap(
                lambda qv, f: H.search_one(
                    g, qv, metric=metric, k=k, ef=ef,
                    max_iters=max_iters, tag_words=tw,
                    filter_words=f))(qs, fw_pad[qidx])
        ids_out = jnp.where(slot_valid[:, None], ids_out, -1)
        scores_out = jnp.where(slot_valid[:, None], scores_out, -jnp.inf)
        return qidx.astype(jnp.int32), ids_out, scores_out

    if fw_pad is None:
        if shard_axis == "map":
            return jax.lax.map(lambda t: one_shard(*t), (arena, mask.T))
        return jax.vmap(one_shard)(arena, mask.T)
    if shard_axis == "map":
        return jax.lax.map(lambda t: one_shard(*t),
                           (arena, mask.T, tag_words))
    return jax.vmap(one_shard)(arena, mask.T, tag_words)


def scatter_partials(qidx: jnp.ndarray, ids: jnp.ndarray,
                     scores: jnp.ndarray, b: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter per-shard partials back to query rows.

    Args: qidx [w, C], ids [w, C, k], scores [w, C, k] (the dummy row b
    absorbs invalid slots and is sliced off).
    Returns (scores [B, w*k] f32, ids [B, w*k] i32) ready for the merge.
    """
    w, _, k = ids.shape
    out_s = jnp.full((b + 1, w, k), -jnp.inf, jnp.float32)
    out_i = jnp.full((b + 1, w, k), -1, jnp.int32)
    shard_col = jnp.arange(w)[:, None]          # broadcast against [w, C]
    out_s = out_s.at[qidx, shard_col].set(scores)
    out_i = out_i.at[qidx, shard_col].set(ids)
    return out_s[:b].reshape(b, w * k), out_i[:b].reshape(b, w * k)


def _search_scatter_merge(arena: ShardArena, mask: jnp.ndarray,
                          queries: jnp.ndarray, *, metric: str, k: int,
                          ef: int, capacity: int, max_iters: int,
                          use_kernel: bool, shard_axis: str,
                          tag_words=None, filter_words=None):
    """The shared post-routing pipeline body: shard_search -> scatter ->
    dedup merge. Both jitted entry points delegate here. With
    ``tag_words``/``filter_words`` the per-shard partials arrive already
    alive-masked (pre-merge filtering), so the merge needs no extra
    mask."""
    b = queries.shape[0]
    qidx, ids, scores = shard_search(
        arena, mask, queries, metric=metric, k=k, ef=ef,
        capacity=capacity, max_iters=max_iters, shard_axis=shard_axis,
        use_kernel=use_kernel, tag_words=tag_words,
        filter_words=filter_words)
    flat_s, flat_i = scatter_partials(qidx, ids, scores, b)
    top_s, top_i = merge_topk(flat_s, flat_i, k=k, use_kernel=use_kernel)
    return top_i, top_s


@functools.partial(jax.jit, static_argnames=(
    "metric", "k", "ef", "branching_factor", "capacity", "max_iters",
    "naive", "use_kernel", "shard_axis"))
def _fused_routed(arena: ShardArena, meta: H.HNSWArrays,
                  part_of_center: jnp.ndarray, queries: jnp.ndarray, *,
                  metric: str, k: int, ef: int, branching_factor: int,
                  capacity: int, max_iters: int, naive: bool,
                  use_kernel: bool, shard_axis: str,
                  tag_words=None, filter_words=None):
    """route -> shard_search -> scatter -> merge, one jitted program."""
    b = queries.shape[0]
    w = arena.data.shape[0]
    if naive:
        mask = jnp.ones((b, w), dtype=jnp.bool_)
    else:
        mask, _ = route_queries.__wrapped__(
            meta, part_of_center, queries, metric=metric,
            branching_factor=branching_factor, num_shards=w,
            ef=max(64, branching_factor))
    top_i, top_s = _search_scatter_merge(
        arena, mask, queries, metric=metric, k=k, ef=ef,
        capacity=capacity, max_iters=max_iters, use_kernel=use_kernel,
        shard_axis=shard_axis, tag_words=tag_words,
        filter_words=filter_words)
    return top_i, top_s, mask


@functools.partial(jax.jit, static_argnames=(
    "metric", "k", "ef", "capacity", "max_iters", "use_kernel",
    "shard_axis"))
def _fused_masked(arena: ShardArena, mask: jnp.ndarray,
                  queries: jnp.ndarray, *, metric: str, k: int, ef: int,
                  capacity: int, max_iters: int, use_kernel: bool,
                  shard_axis: str, tag_words=None, filter_words=None):
    """shard_search -> scatter -> merge with a caller-provided mask."""
    return _search_scatter_merge(
        arena, mask, queries, metric=metric, k=k, ef=ef,
        capacity=capacity, max_iters=max_iters, use_kernel=use_kernel,
        shard_axis=shard_axis, tag_words=tag_words,
        filter_words=filter_words)


def arena_search(arena: ShardArena, meta: H.HNSWArrays,
                 part_of_center: jnp.ndarray, queries: jnp.ndarray, *,
                 metric: str, k: int, ef: int = 100,
                 branching_factor: int = 4,
                 capacity: Optional[int] = None,
                 capacity_factor: float = 2.0, max_iters: int = 400,
                 naive: bool = False, use_kernel: bool = True,
                 mask: Optional[jnp.ndarray] = None,
                 shard_axis: Optional[str] = None,
                 tag_words: Optional[jnp.ndarray] = None,
                 filter_words: Optional[jnp.ndarray] = None):
    """Fused distributed search over a device-resident arena (Alg. 4).

    Routes through the replicated meta-HNSW, beam-searches the <= K
    routed shards per query under a per-shard capacity bound, and merges
    partials with the dedup-top-k kernel — one jitted program, no host
    round-trips between the stages.

    Args:
      queries: [B, d] *preprocessed* queries (see ``M.preprocess_queries``).
      capacity: per-shard query slots; defaults to
        ``ceil(B * K / w * capacity_factor)`` (B when ``naive``) — the
        paper's throughput mechanism realised as a FLOP bound.
      naive: search every shard (the HNSW-naive baseline of Sec. III).
      mask: optional precomputed [B, w] routing mask; skips the routing
        stage (the reference path uses this to guarantee zero drops).
      shard_axis: "kernel" | "vmap" | "map" shard-axis strategy (see
        :func:`shard_search`); defaults to "kernel" — ONE strategy on
        every backend (the op layer picks Pallas on TPU, the fused
        oracle elsewhere), retiring the old CPU "map" special case.
      tag_words / filter_words: optional metadata alive-mask (see
        :func:`shard_search`): routing stays filter-blind, the per-shard
        walk emits only alive candidates, the merge fills k from those.
        Callers size ``ef``/``k`` for low selectivity via
        ``repro.core.filters.inflation`` (``search_single_host`` does).

    Returns (ids [B, k] i32, scores [B, k] f32, mask [B, w] bool).
    """
    b = queries.shape[0]
    w = arena.num_shards
    if shard_axis is None:
        shard_axis = "kernel"
    if capacity is None:
        if naive:
            capacity = b
        else:
            capacity = int(np.ceil(
                b * branching_factor / w * capacity_factor))
    capacity = max(1, min(b, int(capacity)))
    if mask is not None:
        ids, scores = _fused_masked(
            arena, jnp.asarray(mask), queries, metric=metric, k=k, ef=ef,
            capacity=capacity, max_iters=max_iters, use_kernel=use_kernel,
            shard_axis=shard_axis, tag_words=tag_words,
            filter_words=filter_words)
        return ids, scores, mask
    return _fused_routed(
        arena, meta, part_of_center, queries, metric=metric, k=k, ef=ef,
        branching_factor=branching_factor, capacity=capacity,
        max_iters=max_iters, naive=naive, use_kernel=use_kernel,
        shard_axis=shard_axis, tag_words=tag_words,
        filter_words=filter_words)
