"""Lloyd's k-means and spherical k-means (Alg. 3 line 4 / Alg. 5 line 5).

Two execution paths:
  * ``kmeans`` — single-host JAX (used by tests, small builds);
  * ``kmeans_distributed`` — shard_map over the data axis; each shard assigns
    its local rows (via the topk_distance kernel, k=1) and contributes
    per-center sums/counts through ``psum`` — the paper's "workers conduct
    distributed kmeans together" (Sec. III-A distributed workflow).

Spherical k-means (for MIPS, [35]) normalises centers to unit norm each
iteration and assigns by inner product.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.jax_compat import shard_map
from repro.kernels.topk_distance import topk_similarity


def _init_centers(x: jnp.ndarray, m: int, seed: int) -> jnp.ndarray:
    """k-means++ style seeding, simplified: random distinct rows."""
    key = jax.random.PRNGKey(seed)
    idx = jax.random.choice(key, x.shape[0], shape=(m,), replace=False)
    return x[idx]


def _assign(x: jnp.ndarray, centers: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Nearest center per row ([n] int32). Uses the Pallas scan kernel."""
    _, ids = topk_similarity(x, centers, k=1, metric=metric)
    return ids[:, 0]


def _update(x, assign, m):
    one_hot = jax.nn.one_hot(assign, m, dtype=x.dtype)       # [n, m]
    sums = one_hot.T @ x                                      # [m, d]
    counts = jnp.sum(one_hot, axis=0)                         # [m]
    return sums, counts


def _finish_update(centers, sums, counts, spherical: bool):
    new = sums / jnp.maximum(counts[:, None], 1.0)
    new = jnp.where(counts[:, None] > 0, new, centers)  # keep empty centers
    if spherical:
        new = new / (jnp.linalg.norm(new, axis=-1, keepdims=True) + 1e-12)
    return new


@functools.partial(jax.jit, static_argnames=("m", "iters", "spherical"))
def _kmeans_jit(x, init_centers, *, m, iters, spherical):
    metric = "ip" if spherical else "l2"

    def body(centers, _):
        a = _assign(x, centers, metric)
        sums, counts = _update(x, a, m)
        return _finish_update(centers, sums, counts, spherical), counts

    centers, counts = jax.lax.scan(body, init_centers, None, length=iters)
    return centers, counts[-1]


def kmeans(x: np.ndarray, m: int, *, iters: int = 12, spherical: bool = False,
           seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (centers [m, d] f32, counts [m] — size of each cluster)."""
    x = jnp.asarray(x, jnp.float32)
    if spherical:
        x = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)
    init = _init_centers(x, m, seed)
    if spherical:
        init = init / (jnp.linalg.norm(init, axis=-1, keepdims=True) + 1e-12)
    centers, counts = _kmeans_jit(x, init, m=m, iters=iters,
                                  spherical=spherical)
    return np.asarray(centers), np.asarray(counts)


def kmeans_distributed(x_global: jnp.ndarray, m: int, mesh: Mesh, *,
                       data_axis: str = "data", iters: int = 12,
                       spherical: bool = False, seed: int = 0):
    """Distributed k-means: rows sharded over ``data_axis``.

    Per iteration each shard computes local assignments and psums the
    per-center statistics — identical math to ``kmeans`` (tested against it).
    """
    metric = "ip" if spherical else "l2"
    if spherical:
        x_global = x_global / (
            jnp.linalg.norm(x_global, axis=-1, keepdims=True) + 1e-12)
    init = _init_centers(x_global, m, seed)
    if spherical:
        init = init / (jnp.linalg.norm(init, axis=-1, keepdims=True) + 1e-12)

    other_axes = tuple(a for a in mesh.axis_names if a != data_axis)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(data_axis, None), P(None, None)),
        out_specs=(P(None, None), P(None)),
        check_vma=False)
    def step(x_local, centers):
        a = _assign(x_local, centers, metric)
        sums, counts = _update(x_local, a, m)
        sums = jax.lax.psum(sums, data_axis)
        counts = jax.lax.psum(counts, data_axis)
        return _finish_update(centers, sums, counts, spherical), counts

    centers = init
    counts = None
    step_j = jax.jit(step)
    for _ in range(iters):
        centers, counts = step_j(x_global, centers)
    del other_axes
    return centers, counts
