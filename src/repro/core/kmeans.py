"""Lloyd's k-means and spherical k-means (Alg. 3 line 4 / Alg. 5 line 5).

Two execution paths:
  * ``kmeans`` — single-host JAX (used by tests, small builds);
  * ``kmeans_distributed`` — shard_map over the data axis; each shard assigns
    its local rows (via the topk_distance kernel, k=1) and contributes
    per-center sums/counts through ``psum`` — the paper's "workers conduct
    distributed kmeans together" (Sec. III-A distributed workflow).

Spherical k-means (for MIPS, [35]) normalises centers to unit norm each
iteration and assigns by inner product.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.jax_compat import shard_map
from repro.kernels.topk_distance import topk_similarity


def _init_centers(x: jnp.ndarray, m: int, seed: int, *,
                  method: str = "uniform") -> jnp.ndarray:
    """Initial centers.

    ``method="uniform"`` (the default) samples m *uniform random
    distinct* rows — it is NOT k-means++ (an older docstring overclaimed
    this). ``method="kmeans++"`` runs true D²-weighted seeding (Arthur &
    Vassilvitskii 2007): each next center is drawn with probability
    proportional to its squared distance from the nearest center so far.

    When ``m > n`` (more centers than rows — tiny samples do this)
    distinct sampling is impossible: all n rows are used and the
    remaining ``m - n`` slots are topped up with replacement so callers
    always get m centers (``_finish_update`` keeps duplicate/empty
    centers stable during iteration).
    """
    n = x.shape[0]
    key = jax.random.PRNGKey(seed)
    if method == "kmeans++":
        return _kmeanspp_init(x, m, key)
    if method != "uniform":
        raise ValueError(f"unknown init method {method!r}; "
                         "one of ('uniform', 'kmeans++')")
    if m > n:
        k1, k2 = jax.random.split(key)
        idx = jnp.concatenate([
            jax.random.permutation(k1, n),
            jax.random.choice(k2, n, shape=(m - n,), replace=True)])
    else:
        idx = jax.random.choice(key, n, shape=(m,), replace=False)
    return x[idx]


def _kmeanspp_init(x: jnp.ndarray, m: int, key) -> jnp.ndarray:
    """True k-means++ (D² sampling). O(m·n·d) — same complexity class as
    one Lloyd iteration, so enabling it roughly costs one extra iter."""
    n, d = x.shape
    key, k0 = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    centers = jnp.zeros((m, d), x.dtype).at[0].set(x[first])
    d2 = jnp.sum((x - x[first]) ** 2, axis=-1)

    def body(i, state):
        centers, d2, key = state
        key, kk = jax.random.split(key)
        total = jnp.sum(d2)
        # all-zero D² (m > #distinct rows): fall back to uniform so the
        # draw stays well-defined instead of dividing by zero
        probs = jnp.where(total > 0, d2 / jnp.maximum(total, 1e-30),
                          jnp.full((n,), 1.0 / n, x.dtype))
        idx = jax.random.choice(kk, n, p=probs)
        c = x[idx]
        centers = centers.at[i].set(c)
        d2 = jnp.minimum(d2, jnp.sum((x - c) ** 2, axis=-1))
        return centers, d2, key

    centers, _, _ = jax.lax.fori_loop(1, m, body, (centers, d2, key))
    return centers


def _assign(x: jnp.ndarray, centers: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Nearest center per row ([n] int32). Uses the Pallas scan kernel."""
    _, ids = topk_similarity(x, centers, k=1, metric=metric)
    return ids[:, 0]


def _update(x, assign, m):
    one_hot = jax.nn.one_hot(assign, m, dtype=x.dtype)       # [n, m]
    sums = one_hot.T @ x                                      # [m, d]
    counts = jnp.sum(one_hot, axis=0)                         # [m]
    return sums, counts


def _finish_update(centers, sums, counts, spherical: bool):
    new = sums / jnp.maximum(counts[:, None], 1.0)
    new = jnp.where(counts[:, None] > 0, new, centers)  # keep empty centers
    if spherical:
        new = new / (jnp.linalg.norm(new, axis=-1, keepdims=True) + 1e-12)
    return new


@functools.partial(jax.jit, static_argnames=("m", "iters", "spherical"))
def _kmeans_jit(x, init_centers, *, m, iters, spherical):
    metric = "ip" if spherical else "l2"

    def body(centers, _):
        a = _assign(x, centers, metric)
        sums, counts = _update(x, a, m)
        return _finish_update(centers, sums, counts, spherical), counts

    centers, counts = jax.lax.scan(body, init_centers, None, length=iters)
    return centers, counts[-1]


def kmeans(x: np.ndarray, m: int, *, iters: int = 12, spherical: bool = False,
           seed: int = 0, init: str = "uniform"
           ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (centers [m, d] f32, counts [m] — size of each cluster).

    ``init`` selects the seeding: ``"uniform"`` (distinct random rows)
    or ``"kmeans++"`` (D²-weighted, see :func:`_init_centers`).
    """
    x = jnp.asarray(x, jnp.float32)
    if spherical:
        x = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)
    centers0 = _init_centers(x, m, seed, method=init)
    if spherical:
        centers0 = centers0 / (
            jnp.linalg.norm(centers0, axis=-1, keepdims=True) + 1e-12)
    centers, counts = _kmeans_jit(x, centers0, m=m, iters=iters,
                                  spherical=spherical)
    return np.asarray(centers), np.asarray(counts)


def kmeans_distributed(x_global: jnp.ndarray, m: int, mesh: Mesh, *,
                       data_axis: str = "data", iters: int = 12,
                       spherical: bool = False, seed: int = 0,
                       init: str = "uniform"):
    """Distributed k-means: rows sharded over ``data_axis``.

    Per iteration each shard computes local assignments and psums the
    per-center statistics — identical math to ``kmeans`` (tested against it).
    """
    metric = "ip" if spherical else "l2"
    if spherical:
        x_global = x_global / (
            jnp.linalg.norm(x_global, axis=-1, keepdims=True) + 1e-12)
    init = _init_centers(x_global, m, seed, method=init)
    if spherical:
        init = init / (jnp.linalg.norm(init, axis=-1, keepdims=True) + 1e-12)

    other_axes = tuple(a for a in mesh.axis_names if a != data_axis)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(data_axis, None), P(None, None)),
        out_specs=(P(None, None), P(None)),
        check_vma=False)
    def step(x_local, centers):
        a = _assign(x_local, centers, metric)
        sums, counts = _update(x_local, a, m)
        sums = jax.lax.psum(sums, data_axis)
        counts = jax.lax.psum(counts, data_axis)
        return _finish_update(centers, sums, counts, spherical), counts

    centers = init
    counts = None
    step_j = jax.jit(step)
    for _ in range(iters):
        centers, counts = step_j(x_global, centers)
    del other_axes
    return centers, counts
