"""Query -> sub-HNSW routing (Alg. 4 lines 4-6).

Routing searches the (replicated, small) meta-HNSW for the query's top-K
meta neighbours and marks the partitions containing them. This is exactly
top-K expert routing: downstream we reuse the same capacity-based dispatch
machinery as the MoE layers (DESIGN.md §3/§4).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import hnsw as H


@functools.partial(jax.jit, static_argnames=("metric", "branching_factor",
                                             "num_shards", "ef"))
def route_queries(meta: H.HNSWArrays, part_of_center: jnp.ndarray,
                  queries: jnp.ndarray, *, metric: str,
                  branching_factor: int, num_shards: int,
                  ef: int = 64) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (mask [B, w] bool — shard s must serve query b,
    meta_ids [B, K] — the routed meta vertices)."""
    k = branching_factor
    meta_ids, _ = H.hnsw_search(meta, queries, metric=metric, k=k,
                                ef=max(ef, k))
    parts = part_of_center[jnp.clip(meta_ids, 0)]          # [B, K]
    parts = jnp.where(meta_ids >= 0, parts, -1)
    onehot = jax.nn.one_hot(
        jnp.clip(parts, 0), num_shards, dtype=jnp.bool_)
    onehot = jnp.logical_and(onehot, (parts >= 0)[..., None])
    return jnp.any(onehot, axis=1), meta_ids


def access_rate(mask: jnp.ndarray) -> float:
    """Fraction of sub-HNSWs touched per query (paper Fig. 5 metric)."""
    return float(jnp.mean(jnp.sum(mask, axis=1) / mask.shape[1]))
