"""Query -> sub-HNSW routing (Alg. 4 lines 4-6).

Routing searches the (replicated, small) meta-HNSW for the query's top-K
meta neighbours and marks the partitions containing them. This is exactly
top-K expert routing: downstream we reuse the same capacity-based dispatch
machinery as the MoE layers (DESIGN.md §3/§4).
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional, Set, Tuple

import jax
import jax.numpy as jnp

from repro.core import hnsw as H

_EF_RAISED_WARNED: Set[Tuple[int, int]] = set()


def effective_ef(ef: int, branching_factor: int) -> int:
    """The beam width routing actually searches with: the meta search
    cannot return K = ``branching_factor`` neighbours from a narrower
    beam, so ``ef`` is raised to K when the caller's value is smaller.
    Exposed so serving surfaces (``ServingEngine.stats()['routing']``)
    can report the real value instead of the requested one."""
    return max(ef, branching_factor)


@functools.partial(jax.jit, static_argnames=("metric", "branching_factor",
                                             "num_shards", "ef"))
def _route_queries(meta: H.HNSWArrays, part_of_center: jnp.ndarray,
                   queries: jnp.ndarray, *, metric: str,
                   branching_factor: int, num_shards: int,
                   ef: int = 64) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = branching_factor
    # use_kernel=False: routing is traced inside shard_map by the SPMD
    # path (via ``route_queries.__wrapped__``), where Pallas cannot run
    meta_ids, _ = H.hnsw_search(meta, queries, metric=metric, k=k,
                                ef=max(ef, k), use_kernel=False)
    parts = part_of_center[jnp.clip(meta_ids, 0)]          # [B, K]
    parts = jnp.where(meta_ids >= 0, parts, -1)
    onehot = jax.nn.one_hot(
        jnp.clip(parts, 0), num_shards, dtype=jnp.bool_)
    onehot = jnp.logical_and(onehot, (parts >= 0)[..., None])
    return jnp.any(onehot, axis=1), meta_ids


def route_queries(meta: H.HNSWArrays, part_of_center: jnp.ndarray,
                  queries: jnp.ndarray, *, metric: str,
                  branching_factor: int, num_shards: int,
                  ef: int = 64) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (mask [B, w] bool — shard s must serve query b,
    meta_ids [B, K] — the routed meta vertices).

    ``ef`` below ``branching_factor`` is raised to it (a K-wide result
    needs a K-wide beam); that used to happen silently — now it warns
    once per (ef, K) combination and the effective value is available
    via :func:`effective_ef` / the engine's ``stats()['routing']``.
    """
    eff = effective_ef(ef, branching_factor)
    if eff != ef and (ef, branching_factor) not in _EF_RAISED_WARNED:
        _EF_RAISED_WARNED.add((ef, branching_factor))
        warnings.warn(
            f"route_queries: requested ef={ef} is narrower than "
            f"branching_factor K={branching_factor}; searching the "
            f"meta-HNSW with effective ef={eff}",
            RuntimeWarning, stacklevel=2)
    return _route_queries(meta, part_of_center, queries, metric=metric,
                          branching_factor=branching_factor,
                          num_shards=num_shards, ef=eff)


# call sites already inside a jitted program (the fused arena pipeline,
# the SPMD shard_map body) trace the un-jitted core directly
route_queries.__wrapped__ = _route_queries.__wrapped__


def access_rate(mask: jnp.ndarray) -> float:
    """Fraction of sub-HNSWs touched per query (paper Fig. 5 metric)."""
    return float(jnp.mean(jnp.sum(mask, axis=1) / mask.shape[1]))


def refresh_centroids(index, *, seed: Optional[int] = None):
    """Recompute the routing layer from the CURRENT items (in place).

    Under sustained inserts/deletes the live data drifts away from the
    kmeans centroids frozen at build time and routing recall/balance
    decay. This re-runs the build-time routing stages — sample →
    kmeans++ → meta-HNSW → balanced min-cut partition → item
    reassignment — over today's vectors, then rebuilds every sub-HNSW
    through ``shard_seed`` (``w`` stays fixed; split/merge changes it,
    see ``repro.build.planner``). Deterministic given ``seed``
    (defaults to the config seed), so replay/recovery via the store
    reproduces the identical index. Expensive (a full rebuild minus
    preprocessing) — the maintenance compactor triggers it only when
    drift crosses its threshold, never on the serving path.
    """
    import numpy as np

    from repro.core.kmeans import kmeans
    from repro.core.meta_index import _assign_items, _sample
    from repro.core.partition import balance_stats, partition_graph

    cfg = index.config
    seed = cfg.seed if seed is None else seed
    live = [g for g in index.subs if g.n]
    if not live:
        return index
    x = np.concatenate([g.data for g in live])
    ids = np.concatenate([g.ids for g in live])
    # MIPS norm-replication stores one id in several shards: collapse
    # to one row per global id before re-partitioning
    _, first = np.unique(ids, return_index=True)
    first = np.sort(first)
    x, ids = x[first], ids[first]
    n = x.shape[0]
    m = min(cfg.meta_size, max(cfg.num_shards, n // 4))
    rng = np.random.default_rng(seed)
    sample = _sample(x, cfg.sample_size, rng)
    centers, counts = kmeans(sample, m, iters=cfg.kmeans_iters,
                             spherical=cfg.is_mips, seed=seed,
                             init="kmeans++")
    metric = "ip" if cfg.is_mips else cfg.metric
    meta = H.build_hnsw(np.asarray(centers, np.float32), metric=metric,
                        max_degree=cfg.max_degree,
                        max_degree_upper=cfg.max_degree_upper,
                        ef_construction=cfg.ef_construction, seed=seed)
    weights = np.asarray(counts, dtype=np.float64) + 1.0
    part_of_center = partition_graph(
        meta.neighbors[0], weights, cfg.num_shards, seed=seed)
    item_part = _assign_items(
        x, meta.device_arrays(), part_of_center, metric)
    for s in range(cfg.num_shards):
        sel = item_part == s
        index.subs[s] = H.build_hnsw(
            x[sel], metric=metric, max_degree=cfg.max_degree,
            max_degree_upper=cfg.max_degree_upper,
            ef_construction=cfg.ef_construction,
            seed=H.shard_seed(cfg.seed, s), ids=ids[sel])
    index.meta = meta
    index.part_of_center = part_of_center.astype(np.int32)
    index.build_stats["sub_sizes"] = [g.n for g in index.subs]
    index.build_stats["total_stored"] = sum(g.n for g in index.subs)
    index.build_stats["balance"], _ = balance_stats(
        weights, part_of_center, cfg.num_shards)
    index.build_stats["centroid_refreshes"] = 1 + int(
        index.build_stats.get("centroid_refreshes", 0))
    index.invalidate_device_cache()
    return index
