"""Array-based HNSW: offline numpy construction + jit-able JAX search.

TPU adaptation (DESIGN.md §3): the original HNSW is a pointer-chasing walk
with hash-set visited tracking and binary heaps — none of which vectorise.
We keep the *algorithm* (Alg. 1 / Alg. 2 of the paper) but re-express it:

  * adjacency is a fixed-degree int32 array per level, padded with -1;
  * the search beam W is a pair of sorted (score, id) arrays of size ef;
  * candidate selection = masked argmax, beam merge = ``jax.lax.top_k`` over
    the concatenation of the old beam and the newly-scored neighbours;
  * the visited set is a per-query bitmask;
  * the whole walk is a ``lax.while_loop`` whose body does one beam expansion
    (gather M neighbours -> score -> merge), vmapped over the query batch so
    the neighbour scoring is matmul-shaped for the MXU.

Construction runs host-side in numpy (index building is an offline batch job
in the paper too); only search must be jit-able for serving.
"""
from __future__ import annotations

import dataclasses
import heapq
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filters as F
from repro.core import metrics as M
from repro.kernels.beam_search import beam_search

NEG_INF = np.float32(-np.inf)


def shard_seed(base: int, shard: int) -> int:
    """Construction seed for sub-HNSW ``shard`` of an index seeded with
    ``base``. Every path that (re)builds a shard — the sequential build,
    the process-pool fan-out (``repro.build``), and incremental rebuilds
    (``repro.core.updates``) — must derive its seed here, so a shard's
    graph is bit-identical no matter which path produced it (the store's
    manifest checksums depend on it)."""
    return base + 1 + shard


# ---------------------------------------------------------------------------
# Graph container
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HNSWGraph:
    """An HNSW index in array form.

    Attributes:
      data:       [n, d] float32 item vectors (dataset order).
      ids:        [n] int64 external ids (global ids when this is a sub-HNSW).
      neighbors:  list over levels; level l is an int32 array [n, M_l] padded
                  with -1. Level 0 is the bottom layer with all items.
      levels:     [n] int32, highest level of each node.
      entry:      int, entry vertex (node with the highest level).
      metric:     similarity function name.
      tags:       optional [n] int64 metadata tag bitsets (dataset order,
                  aligned with ``ids``) for filtered search
                  (``repro.core.filters``); ``None`` == all zeros ==
                  item matches no non-empty filter.
    """

    data: np.ndarray
    ids: np.ndarray
    neighbors: List[np.ndarray]
    levels: np.ndarray
    entry: int
    metric: str
    tags: Optional[np.ndarray] = None

    def tags_or_zeros(self) -> np.ndarray:
        """The tag bitsets, materialising zeros for untagged graphs."""
        if self.tags is None:
            return np.zeros((self.n,), dtype=np.int64)
        return np.asarray(self.tags, dtype=np.int64)

    @property
    def n(self) -> int:
        return int(self.data.shape[0])

    @property
    def d(self) -> int:
        return int(self.data.shape[1])

    @property
    def max_level(self) -> int:
        return len(self.neighbors) - 1

    def device_arrays(self) -> "HNSWArrays":
        """Stack upper levels into one padded array for the JAX search."""
        m_upper = max([lv.shape[1] for lv in self.neighbors[1:]], default=1)
        if self.max_level >= 1:
            upper = np.full(
                (self.max_level, self.n, m_upper), -1, dtype=np.int32)
            for l in range(1, self.max_level + 1):
                lv = self.neighbors[l]
                upper[l - 1, :, : lv.shape[1]] = lv
        else:
            upper = np.full((1, self.n, m_upper), -1, dtype=np.int32)
        return HNSWArrays(
            data=jnp.asarray(self.data, jnp.float32),
            ids=jnp.asarray(self.ids, jnp.int32),
            bottom=jnp.asarray(self.neighbors[0], jnp.int32),
            upper=jnp.asarray(upper, jnp.int32),
            entry=jnp.asarray(self.entry, jnp.int32),
            num_upper_levels=jnp.asarray(self.max_level, jnp.int32),
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class HNSWArrays:
    """Device-resident arrays consumed by the jitted search.

    The graph container owns the *scoring* of its own rows
    (:meth:`score_nodes`): the beam search gathers node indices and asks
    the graph for similarities, so a compressed graph representation
    (:class:`QuantHNSWArrays`) plugs into the identical walk by
    overriding one method instead of forking the search.
    """

    data: jnp.ndarray        # [n, d] f32
    ids: jnp.ndarray         # [n] i32 external ids
    bottom: jnp.ndarray      # [n, M0] i32
    upper: jnp.ndarray       # [L, n, Mu] i32 (L >= 1; all -1 rows for absent)
    entry: jnp.ndarray       # scalar i32
    num_upper_levels: jnp.ndarray  # scalar i32

    def tree_flatten(self):
        children = (self.data, self.ids, self.bottom, self.upper,
                    self.entry, self.num_upper_levels)
        return children, None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def score_nodes(self, q: jnp.ndarray, nodes: jnp.ndarray,
                    metric: str) -> jnp.ndarray:
        """Similarity of one query against graph rows.

        Args: q [d] f32; nodes [m] i32 row indices (pre-clipped to
        valid range — callers mask invalid slots on the result).
        Returns [m] f32 similarities (larger = more similar).
        """
        return M.similarity_matrix(q[None, :], self.data[nodes], metric)[0]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantHNSWArrays:
    """Int8-compressed twin of :class:`HNSWArrays`.

    ``data`` holds int8 codes on a per-dimension affine grid
    (``repro.core.quant.QuantParams``); scoring is asymmetric — the
    float32 query against dequantized rows, via the
    ``repro.kernels.quant_distance`` oracle semantics — so the identical
    beam-search walk runs over a ~4x smaller HBM vector payload. The
    adjacency/ids fields are bit-identical to the float graph's.
    """

    data: jnp.ndarray        # [n, d] int8 codes
    ids: jnp.ndarray         # [n] i32 external ids
    bottom: jnp.ndarray      # [n, M0] i32
    upper: jnp.ndarray       # [L, n, Mu] i32
    entry: jnp.ndarray       # scalar i32
    num_upper_levels: jnp.ndarray  # scalar i32
    scale: jnp.ndarray       # [d] f32 per-dimension step
    zero: jnp.ndarray        # [d] f32 per-dimension zero-point

    def tree_flatten(self):
        children = (self.data, self.ids, self.bottom, self.upper,
                    self.entry, self.num_upper_levels, self.scale,
                    self.zero)
        return children, None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def score_nodes(self, q: jnp.ndarray, nodes: jnp.ndarray,
                    metric: str) -> jnp.ndarray:
        """Asymmetric quantized scoring: float32 ``q`` against the
        dequantized code rows (same signature/contract as
        ``HNSWArrays.score_nodes``)."""
        from repro.kernels.quant_distance import quant_scores_ref
        return quant_scores_ref(q[None, :], self.data[nodes], self.scale,
                                self.zero, metric=metric)[0]


# ---------------------------------------------------------------------------
# Construction (numpy, Alg. 2)
# ---------------------------------------------------------------------------


class _Builder:
    """Incremental HNSW builder (host-side)."""

    def __init__(self, d: int, metric: str, m: int, m_upper: int,
                 ef_construction: int, seed: int, capacity: int):
        self.metric = metric
        self.m0 = m
        self.mu = m_upper
        self.efc = ef_construction
        self.rng = np.random.default_rng(seed)
        self.ml = 1.0 / np.log(max(m, 2))
        self.data = np.zeros((capacity, d), dtype=np.float32)
        self.levels = np.zeros(capacity, dtype=np.int32)
        self.n = 0
        self.entry = -1
        self.max_level = -1
        # adjacency: list over levels of [capacity, M_l] int32
        self.adj: List[np.ndarray] = []

    def _ensure_level(self, level: int) -> None:
        while len(self.adj) <= level:
            m = self.m0 if len(self.adj) == 0 else self.mu
            self.adj.append(
                np.full((self.data.shape[0], m), -1, dtype=np.int32))

    def _search_layer(self, q: np.ndarray, entry_points: List[Tuple[float, int]],
                      level: int, ef: int) -> List[Tuple[float, int]]:
        """Alg. 1 Search-Level. Returns up to ef (sim, id) best-first."""
        visited = set()
        cand: List[Tuple[float, int]] = []   # max-heap via negated sim
        best: List[Tuple[float, int]] = []   # min-heap of (sim, id)
        for sim, node in entry_points:
            if node in visited:
                continue
            visited.add(node)
            heapq.heappush(cand, (-sim, node))
            heapq.heappush(best, (sim, node))
        adj = self.adj[level]
        while cand:
            neg_sim, node = heapq.heappop(cand)
            if -neg_sim < best[0][0] and len(best) >= ef:
                break
            nbrs = adj[node]
            nbrs = nbrs[nbrs >= 0]
            fresh = [v for v in nbrs if v not in visited]
            if not fresh:
                continue
            visited.update(fresh)
            fresh_arr = np.asarray(fresh, dtype=np.int64)
            sims = M.similarity_matrix_np(
                q[None, :], self.data[fresh_arr], self.metric)[0]
            for v, s in zip(fresh, sims):
                s = float(s)
                if len(best) < ef or s > best[0][0]:
                    heapq.heappush(cand, (-s, v))
                    heapq.heappush(best, (s, v))
                    if len(best) > ef:
                        heapq.heappop(best)
        return sorted(best, reverse=True)

    def _select_heuristic(self, q: np.ndarray,
                          cand: List[Tuple[float, int]], m: int) -> List[int]:
        """HNSW neighbour-selection heuristic (Malkov & Yashunin Alg. 4).

        Keeps a *diverse* neighbour set: candidate e joins only if it is
        more similar to q than to any already-selected neighbour. This keeps
        long-range edges between clusters — without it, well-separated
        clusters become disconnected graph components and recall collapses.
        Pruned candidates backfill remaining slots (keepPrunedConnections).
        """
        ordered = sorted(cand, reverse=True)
        selected: List[int] = []
        for sim, v in ordered:
            if len(selected) == m:
                break
            if selected:
                sims_to_sel = M.similarity_matrix_np(
                    self.data[v][None, :],
                    self.data[np.asarray(selected)], self.metric)[0]
                if np.any(sims_to_sel > sim):
                    continue
            selected.append(v)
        if len(selected) < m:
            chosen = set(selected)
            for _, v in ordered:
                if v not in chosen:
                    selected.append(v)
                    chosen.add(v)
                    if len(selected) == m:
                        break
        return selected

    def _connect(self, node: int, neighbors: List[int], level: int) -> None:
        m = self.m0 if level == 0 else self.mu
        adj = self.adj[level]
        adj[node, : len(neighbors[:m])] = neighbors[:m]
        # add reverse edges, pruning to degree m with the diversity heuristic
        for v in neighbors[:m]:
            row = adj[v]
            slot = np.where(row < 0)[0]
            if slot.size:
                row[slot[0]] = node
            else:
                cand_ids = np.append(row, node)
                sims = M.similarity_matrix_np(
                    self.data[v][None, :], self.data[cand_ids], self.metric)[0]
                keep = self._select_heuristic(
                    self.data[v], list(zip(sims.tolist(), cand_ids.tolist())), m)
                adj[v] = np.asarray(keep, dtype=np.int32)

    def add(self, x: np.ndarray) -> int:
        node = self.n
        self.data[node] = x
        level = int(-np.log(self.rng.uniform(low=1e-12, high=1.0)) * self.ml)
        self.levels[node] = level
        self._ensure_level(level)
        self.n += 1
        if self.entry < 0:
            self.entry = node
            self.max_level = level
            return node
        # greedy descent through layers above `level` (search factor 1)
        sim_e = float(M.similarity_matrix_np(
            x[None, :], self.data[self.entry][None, :], self.metric)[0, 0])
        eps = [(sim_e, self.entry)]
        for l in range(self.max_level, level, -1):
            eps = self._search_layer(x, eps, l, ef=1)[:1]
        # insert with beam efC in layers min(level, max_level)..0
        for l in range(min(level, self.max_level), -1, -1):
            found = self._search_layer(x, eps, l, ef=self.efc)
            m = self.m0 if l == 0 else self.mu
            nbrs = self._select_heuristic(x, found, m)
            self._connect(node, nbrs, l)
            eps = found
        if level > self.max_level:
            self.max_level = level
            self.entry = node
        return node


def build_hnsw(data: np.ndarray,
               metric: str = "l2",
               max_degree: int = 32,
               max_degree_upper: int = 16,
               ef_construction: int = 100,
               seed: int = 0,
               ids: Optional[np.ndarray] = None,
               tags: Optional[np.ndarray] = None) -> HNSWGraph:
    """Alg. 2: sequential-insert HNSW construction (host-side).

    ``tags`` ([n] int64 bitsets, dataset order) are carried as metadata —
    they never influence construction, so tagged and untagged builds of
    the same data are graph-identical.
    """
    data = np.ascontiguousarray(data, dtype=np.float32)
    n, d = data.shape
    if n == 0:
        return empty_hnsw(d, metric=metric, max_degree=max_degree)
    b = _Builder(d, metric, max_degree, max_degree_upper,
                 ef_construction, seed, capacity=n)
    for i in range(n):
        b.add(data[i])
    neighbors = [b.adj[l][:n] for l in range(len(b.adj))] or [
        np.full((n, max_degree), -1, dtype=np.int32)]
    if ids is None:
        ids = np.arange(n, dtype=np.int64)
    if tags is not None:
        tags = np.asarray(tags, dtype=np.int64)
    return HNSWGraph(
        data=data, ids=np.asarray(ids), neighbors=neighbors,
        levels=b.levels[:n], entry=b.entry, metric=metric, tags=tags)


def empty_hnsw(d: int, *, metric: str = "l2",
               max_degree: int = 32) -> HNSWGraph:
    """A zero-item sub-HNSW (entry = -1). Deleting every item of a shard
    leaves this — the shard keeps its routing slot (meta centers still
    label it) but contributes nothing: searches skip it, and the arena
    stacks it as a single pad row (id -1) that every merge filters."""
    return HNSWGraph(
        data=np.zeros((0, d), dtype=np.float32),
        ids=np.zeros((0,), dtype=np.int64),
        neighbors=[np.full((0, max_degree), -1, dtype=np.int32)],
        levels=np.zeros((0,), dtype=np.int32),
        entry=-1, metric=metric,
        tags=np.zeros((0,), dtype=np.int64))


# ---------------------------------------------------------------------------
# Search (JAX, Alg. 1)
# ---------------------------------------------------------------------------


def _score_one(q: jnp.ndarray, x: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Similarity of one query against [m, d] candidates -> [m].

    Float-only helper; the walk itself scores through
    ``g.score_nodes`` so quantized graphs plug in transparently."""
    return M.similarity_matrix(q[None, :], x, metric)[0]


def _greedy_descend(g: HNSWArrays, q: jnp.ndarray, metric: str,
                    max_steps: int) -> jnp.ndarray:
    """Greedy walk through the upper layers (search factor 1). Returns the
    bottom-layer entry node for this query."""

    def level_step(carry, level_idx):
        node = carry
        # level_idx counts down is handled by caller ordering; adjacency
        # row of an absent node is all -1 so the walk is a no-op there.
        adj_l = jax.lax.dynamic_index_in_dim(
            g.upper, level_idx, axis=0, keepdims=False)  # [n, Mu]

        def walk_cond(state):
            cur, cur_sim, moved, steps = state
            return jnp.logical_and(moved, steps < max_steps)

        def walk_body(state):
            cur, cur_sim, _, steps = state
            nbrs = adj_l[cur]                                   # [Mu]
            valid = nbrs >= 0
            sims = jnp.where(
                valid, g.score_nodes(q, jnp.clip(nbrs, 0), metric),
                -jnp.inf)
            j = jnp.argmax(sims)
            better = sims[j] > cur_sim
            new_cur = jnp.where(better, nbrs[j], cur)
            new_sim = jnp.where(better, sims[j], cur_sim)
            return new_cur, new_sim, better, steps + 1

        sim0 = g.score_nodes(q, node[None], metric)[0]
        node, _, _, _ = jax.lax.while_loop(
            walk_cond, walk_body, (node, sim0, jnp.bool_(True), jnp.int32(0)))
        return node, ()

    # iterate levels from top (index L-1) down to 0 of `upper`
    num_levels = g.upper.shape[0]
    levels = jnp.arange(num_levels - 1, -1, -1, dtype=jnp.int32)
    # mask out levels above num_upper_levels (graph may be shallower)
    def masked_step(node, lvl):
        active = lvl < g.num_upper_levels
        new_node, _ = level_step(node, jnp.where(active, lvl, 0))
        return jnp.where(active, new_node, node), ()

    node, _ = jax.lax.scan(masked_step, g.entry.astype(jnp.int32), levels)
    return node


def _beam_search_bottom(g: HNSWArrays, q: jnp.ndarray, entry: jnp.ndarray,
                        metric: str, ef: int, max_iters: int):
    """Best-first beam search on the bottom layer (Alg. 1 Search-Level with
    search factor ef). Returns (scores [ef], node_ids [ef]) best-first."""
    n, m0 = g.bottom.shape
    ef = min(ef, n)

    visited = jnp.zeros((n,), dtype=jnp.bool_).at[entry].set(True)
    beam_ids = jnp.full((ef,), -1, dtype=jnp.int32).at[0].set(entry)
    beam_scores = jnp.full((ef,), -jnp.inf, jnp.float32).at[0].set(
        g.score_nodes(q, entry[None], metric)[0])
    expanded = jnp.zeros((ef,), dtype=jnp.bool_)

    def cond(state):
        beam_scores, beam_ids, expanded, visited, it = state
        has_unexpanded = jnp.any(jnp.logical_and(~expanded, beam_ids >= 0))
        return jnp.logical_and(has_unexpanded, it < max_iters)

    def body(state):
        beam_scores, beam_ids, expanded, visited, it = state
        # pick the best unexpanded beam entry
        sel_scores = jnp.where(jnp.logical_and(~expanded, beam_ids >= 0),
                               beam_scores, -jnp.inf)
        j = jnp.argmax(sel_scores)
        node = beam_ids[j]
        expanded = expanded.at[j].set(True)
        # gather + score its neighbours
        nbrs = g.bottom[node]                              # [M0]
        valid = jnp.logical_and(nbrs >= 0, ~visited[jnp.clip(nbrs, 0)])
        sims = jnp.where(
            valid, g.score_nodes(q, jnp.clip(nbrs, 0), metric), -jnp.inf)
        visited = visited.at[jnp.clip(nbrs, 0)].set(
            jnp.logical_or(visited[jnp.clip(nbrs, 0)], nbrs >= 0))
        # merge into beam: top-ef of (beam ∪ neighbours)
        all_scores = jnp.concatenate([beam_scores, sims])
        all_ids = jnp.concatenate([beam_ids, jnp.where(valid, nbrs, -1)])
        all_expanded = jnp.concatenate(
            [expanded, jnp.zeros((m0,), dtype=jnp.bool_)])
        top_scores, idx = jax.lax.top_k(all_scores, ef)
        return (top_scores, all_ids[idx], all_expanded[idx], visited, it + 1)

    state = (beam_scores, beam_ids, expanded, visited, jnp.int32(0))
    beam_scores, beam_ids, _, _, _ = jax.lax.while_loop(cond, body, state)
    return beam_scores, beam_ids


def search_one(g: HNSWArrays, q: jnp.ndarray, *, metric: str, k: int,
               ef: int, max_iters: int = 400, max_steps: int = 64,
               tag_words: Optional[jnp.ndarray] = None,
               filter_words: Optional[jnp.ndarray] = None):
    """One query against one graph: greedy descend through the upper
    layers, bottom-layer beam search, top-k, node -> external-id
    translation, (-1, -inf) padding when the graph is smaller than k.

    ``tag_words`` ([n, 2] i32 word-split bitsets) + ``filter_words``
    ([2] i32) apply the metadata alive-mask (``repro.core.filters``) on
    the walk's candidate emission — navigation stays unfiltered (a
    filtered beam would disconnect the graph), dead candidates become
    (-inf, -1) before the top-k, so a filtered query can never
    under-fill against live matches.

    This is THE per-query search core — ``hnsw_search`` (engine path) and
    the fused arena pipeline (``repro.core.arena.shard_search``) both
    call it, so their semantics cannot drift. Trace-time only (call
    under jit/vmap). Returns (ids [k] i32, scores [k] f32) best-first.
    """
    ef = max(ef, k)
    entry = _greedy_descend(g, q, metric, max_steps=max_steps)
    scores, nodes = _beam_search_bottom(g, q, entry, metric, ef, max_iters)
    if tag_words is not None and filter_words is not None:
        alive = F.alive_words(tag_words[jnp.clip(nodes, 0)], filter_words)
        scores = jnp.where(alive, scores, -jnp.inf)
        nodes = jnp.where(alive, nodes, -1)
    kk = min(k, scores.shape[0])
    top_scores, idx = jax.lax.top_k(scores, kk)
    top_nodes = nodes[idx]
    ext = jnp.where(top_nodes >= 0, g.ids[jnp.clip(top_nodes, 0)], -1)
    if kk < k:  # graph smaller than k: pad
        pad = k - kk
        ext = jnp.concatenate([ext, jnp.full((pad,), -1, jnp.int32)])
        top_scores = jnp.concatenate(
            [top_scores, jnp.full((pad,), -jnp.inf, jnp.float32)])
    return ext, top_scores


def search_batch(g: HNSWArrays, queries: jnp.ndarray, *, metric: str,
                 k: int, ef: int, max_iters: int = 400,
                 max_steps: int = 64, use_kernel: bool = True,
                 tag_words: Optional[jnp.ndarray] = None,
                 filter_words: Optional[jnp.ndarray] = None):
    """Batched search through the fused beam-walk op
    (``repro.kernels.beam_search``): greedy upper-layer descent per query
    (cheap, stays in XLA), then ONE fused bottom-layer walk for the whole
    batch — the Pallas kernel on TPU, the batched jnp oracle elsewhere.

    Bit-identical to ``vmap(search_one)``: the op freezes finished rows
    so the shared loop matches the per-query ``while_loop``, and its
    scoring lowers to the same per-row dots as ``score_nodes``. Trace-
    time only (call under jit). Returns (ids [B, k], scores [B, k])
    best-first with (-1, -inf) padding.

    ``tag_words`` ([n, 2] i32) + ``filter_words`` ([B, 2] i32, one
    filter per query) route the metadata alive-mask through the fused
    op — candidates whose bitset misses the filter come back (-inf, -1)
    before the top-k here (same contract as ``search_one``).
    """
    ef = max(ef, k)
    entries = jax.vmap(
        lambda qv: _greedy_descend(g, qv, metric, max_steps=max_steps))(
            queries)
    scale = getattr(g, "scale", None)
    zero = getattr(g, "zero", None)
    scores, nodes = beam_search(
        g.data[None], g.bottom[None], queries[None], entries[None],
        metric=metric, ef=ef, max_iters=max_iters, scale=scale, zero=zero,
        use_kernel=use_kernel,
        tag_words=None if tag_words is None else tag_words[None],
        filter_words=None if filter_words is None else filter_words[None])
    scores, nodes = scores[0], nodes[0]                # [B, ef']
    kk = min(k, scores.shape[1])
    top_scores, idx = jax.lax.top_k(scores, kk)
    top_nodes = jnp.take_along_axis(nodes, idx, axis=1)
    ext = jnp.where(top_nodes >= 0, g.ids[jnp.clip(top_nodes, 0)], -1)
    if kk < k:  # graph smaller than k: pad
        b = queries.shape[0]
        pad = k - kk
        ext = jnp.concatenate(
            [ext, jnp.full((b, pad), -1, jnp.int32)], axis=1)
        top_scores = jnp.concatenate(
            [top_scores, jnp.full((b, pad), -jnp.inf, jnp.float32)],
            axis=1)
    return ext, top_scores


@partial(jax.jit, static_argnames=("metric", "k", "ef", "max_iters",
                                   "impl", "use_kernel"))
def hnsw_search(g: HNSWArrays, queries: jnp.ndarray, *, metric: str,
                k: int, ef: int = 100, max_iters: int = 400,
                impl: str = "fused", use_kernel: bool = True,
                tag_words: Optional[jnp.ndarray] = None,
                filter_words: Optional[jnp.ndarray] = None):
    """Batched HNSW search (Alg. 1).

    Args:
      g: device arrays of one HNSW graph.
      queries: [B, d] float32.
      k: neighbours to return.
      ef: bottom-layer search factor (l in the paper).
      max_iters: hard bound on beam expansions (while_loop trip bound).
      impl: "fused" (default) runs the whole batch through the fused
        beam-walk op; "loop" keeps the per-query vmapped ``while_loop``
        (the roofline's baseline). Results are identical.
      use_kernel: allow the Pallas kernel on TPU ("fused" only). Must be
        False when traced inside ``shard_map`` (e.g. the SPMD router).
      tag_words / filter_words: optional metadata alive-mask — [n, 2]
        i32 item tag words and [B, 2] i32 per-query filter words
        (``repro.core.filters.split_tag_words``); a query whose filter
        words are zero runs unfiltered.

    Returns:
      (ids [B, k] int32 external ids (-1 pad), scores [B, k] f32) best-first.
    """
    if impl == "fused":
        return search_batch(g, queries, metric=metric, k=k, ef=ef,
                            max_iters=max_iters, use_kernel=use_kernel,
                            tag_words=tag_words, filter_words=filter_words)
    if tag_words is None or filter_words is None:
        return jax.vmap(lambda q: search_one(
            g, q, metric=metric, k=k, ef=ef, max_iters=max_iters))(queries)
    return jax.vmap(lambda q, fw: search_one(
        g, q, metric=metric, k=k, ef=ef, max_iters=max_iters,
        tag_words=tag_words, filter_words=fw))(queries, filter_words)


def search_numpy(graph: HNSWGraph, queries: np.ndarray, k: int,
                 ef: int = 100, *, filter_tags=None
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side reference search (used during index building, Alg. 3 line 8,
    and as an oracle in tests).

    ``filter_tags`` (scalar int64, or [B] per query) applies the
    metadata alive-mask of ``repro.core.filters`` on the walk's
    candidate set — the same navigate-unfiltered / emit-filtered
    contract as the device paths.
    """
    b = _Builder.__new__(_Builder)  # reuse _search_layer without re-init
    b.metric = graph.metric
    b.data = graph.data
    b.adj = graph.neighbors
    nq = queries.shape[0]
    out_ids = np.full((nq, k), -1, dtype=np.int64)
    out_scores = np.full((nq, k), -np.inf, dtype=np.float32)
    if graph.n == 0:
        return out_ids, out_scores
    filters = None
    if filter_tags is not None:
        filters = np.broadcast_to(
            np.asarray(filter_tags, dtype=np.int64), (nq,))
        tags = graph.tags_or_zeros()
    for i, q in enumerate(np.asarray(queries, dtype=np.float32)):
        sim_e = float(M.similarity_matrix_np(
            q[None, :], graph.data[graph.entry][None, :], graph.metric)[0, 0])
        eps = [(sim_e, graph.entry)]
        for l in range(graph.max_level, 0, -1):
            eps = b._search_layer(q, eps, l, ef=1)[:1]
        found = b._search_layer(q, eps, 0, ef=max(ef, k))
        if filters is not None and filters[i] != 0:
            found = [(s, v) for s, v in found
                     if F.alive_np(tags[v], filters[i])]
        for j, (s, v) in enumerate(found[:k]):
            out_ids[i, j] = graph.ids[v]
            out_scores[i, j] = s
    return out_ids, out_scores
