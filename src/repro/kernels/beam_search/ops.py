"""Dispatch layer for the fused beam-search op.

``beam_search`` picks the Pallas kernel on TPU and the jnp oracle
everywhere else (same convention as ``merge_topk`` / ``quant_scores``).
Inside ``shard_map`` callers must force ``use_kernel=False`` — Pallas
calls cannot be traced there.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.beam_search.kernel import beam_search_pallas
from repro.kernels.beam_search.ref import beam_search_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def beam_impl() -> str:
    """Which implementation ``beam_search`` dispatches to here."""
    return "pallas-kernel" if _on_tpu() else "xla-oracle"


def _apply_filter(scores: jnp.ndarray, nodes: jnp.ndarray,
                  tag_words: jnp.ndarray, filter_words: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Metadata alive-mask on the walk's emitted candidates.

    The navigation beam runs unfiltered (masking mid-walk would
    disconnect the graph); here — identically after the kernel and the
    oracle — candidates whose tag bitset misses the query's filter are
    demoted to the (-inf, -1) padding convention, so downstream top-k
    and merges see them exactly like structural pad slots.

    tag_words: [S, n, 2] i32 word-split item bitsets; filter_words:
    [S, C, 2] i32 per-slot filters (zero words == no filtering).
    """
    from repro.core.filters import alive_words
    # [S, C, ef', 2] gather of the candidates' tag words, per graph slot
    cand = jax.vmap(lambda tw, nd: tw[jnp.clip(nd, 0)])(tag_words, nodes)
    alive = alive_words(cand, filter_words[:, :, None, :])
    return (jnp.where(alive, scores, -jnp.inf),
            jnp.where(alive, nodes, -1))


def beam_search(data: jnp.ndarray, bottom: jnp.ndarray,
                queries: jnp.ndarray, entries: jnp.ndarray, *,
                metric: str, ef: int, max_iters: int,
                scale: Optional[jnp.ndarray] = None,
                zero: Optional[jnp.ndarray] = None,
                use_kernel: bool = True, block_q: int = 8,
                interpret: bool = False,
                tag_words: Optional[jnp.ndarray] = None,
                filter_words: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused bottom-layer beam walk over a stack of graphs.

    See ``ref.beam_search_ref`` for the shared shape/semantics contract:
    data [S, n, d] (f32, or int8 with scale/zero), bottom [S, n, M0],
    queries [S, C, d], entries [S, C] -> (scores [S, C, ef'],
    local nodes [S, C, ef']) best-first, (-inf, -1) padded.

    ``tag_words`` ([S, n, 2] i32) + ``filter_words`` ([S, C, 2] i32)
    apply the metadata alive-mask of ``repro.core.filters`` to the
    emitted candidates — same post-walk masking for kernel and oracle,
    so filtered results stay implementation-identical.
    """
    if not use_kernel or not _on_tpu():
        out_s, out_i = beam_search_ref(
            data, bottom, queries, entries, metric=metric, ef=ef,
            max_iters=max_iters, scale=scale, zero=zero)
    else:
        out_s, out_i = beam_search_pallas(
            data, bottom, queries, entries, metric=metric, ef=ef,
            max_iters=max_iters, scale=scale, zero=zero, block_q=block_q,
            interpret=interpret)
        # kernel pads with the finite NEG_INF sentinel; restore -inf
        out_s = jnp.where(out_i >= 0, out_s, -jnp.inf)
    if tag_words is not None and filter_words is not None:
        out_s, out_i = _apply_filter(out_s, out_i, tag_words, filter_words)
    return out_s, out_i
