"""Dispatch layer for the fused beam-search op.

``beam_search`` picks the Pallas kernel on TPU and the jnp oracle
everywhere else (same convention as ``merge_topk`` / ``quant_scores``).
Inside ``shard_map`` callers must force ``use_kernel=False`` — Pallas
calls cannot be traced there.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.beam_search.kernel import beam_search_pallas
from repro.kernels.beam_search.ref import beam_search_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def beam_impl() -> str:
    """Which implementation ``beam_search`` dispatches to here."""
    return "pallas-kernel" if _on_tpu() else "xla-oracle"


def beam_search(data: jnp.ndarray, bottom: jnp.ndarray,
                queries: jnp.ndarray, entries: jnp.ndarray, *,
                metric: str, ef: int, max_iters: int,
                scale: Optional[jnp.ndarray] = None,
                zero: Optional[jnp.ndarray] = None,
                use_kernel: bool = True, block_q: int = 8,
                interpret: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused bottom-layer beam walk over a stack of graphs.

    See ``ref.beam_search_ref`` for the shared shape/semantics contract:
    data [S, n, d] (f32, or int8 with scale/zero), bottom [S, n, M0],
    queries [S, C, d], entries [S, C] -> (scores [S, C, ef'],
    local nodes [S, C, ef']) best-first, (-inf, -1) padded.
    """
    if not use_kernel or not _on_tpu():
        return beam_search_ref(data, bottom, queries, entries,
                               metric=metric, ef=ef, max_iters=max_iters,
                               scale=scale, zero=zero)
    out_s, out_i = beam_search_pallas(data, bottom, queries, entries,
                                      metric=metric, ef=ef,
                                      max_iters=max_iters, scale=scale,
                                      zero=zero, block_q=block_q,
                                      interpret=interpret)
    # kernel pads with the finite NEG_INF sentinel; restore -inf
    return jnp.where(out_i >= 0, out_s, -jnp.inf), out_i
