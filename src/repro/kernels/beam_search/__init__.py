"""Fused bottom-layer beam walk: Pallas kernel + jnp oracle + numpy twin."""
from repro.kernels.beam_search.kernel import beam_search_pallas
from repro.kernels.beam_search.ops import beam_impl, beam_search
from repro.kernels.beam_search.ref import (beam_search_np, beam_search_ref,
                                           beam_search_stats)

__all__ = [
    "beam_impl",
    "beam_search",
    "beam_search_np",
    "beam_search_pallas",
    "beam_search_ref",
    "beam_search_stats",
]
