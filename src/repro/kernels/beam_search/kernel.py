"""Pallas TPU kernel: fused bottom-layer HNSW beam walk.

One grid step owns a (graph, query-block) pair and runs the ENTIRE beam
walk without leaving the core: the shard's vector tile and adjacency
live in VMEM for the whole walk, beam scores/ids and the expansion
frontier ride the ``lax.while_loop`` carry (registers/VMEM), and the
per-query visited set is a packed int32 bitmask in VMEM scratch —
nothing round-trips through HBM between expansions, which is the whole
point versus the XLA ``while_loop``-of-gathers baseline.

Per iteration, entirely in-core:
  * masked-argmax selection of the best unexpanded beam entry
    (``merge_topk``'s rounds idiom, not ``lax.top_k``);
  * neighbour-row gather as a one-hot matmul against the VMEM tile
    (MXU-friendly; integer adjacency values are exact in f32 below 2^24);
  * visited-bitmask test (arithmetic shift + mask on packed words) and a
    bitwise-OR update that is safe under duplicate neighbour slots;
  * ``score_nodes``-equivalent distances — float32 rows, or int8 codes
    dequantized ONCE per grid step on the frozen grid (FMA amortized
    over every iteration of the walk);
  * beam merge: ``ef`` masked-argmax rounds over (beam ∪ neighbours).

Scores use the same NEG_INF sentinel as ``merge_topk`` (TPU vector
units dislike real infinities); ``ops.beam_search`` normalizes padding
back to -inf so callers see the reference contract.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.jax_compat import CompilerParams as _CompilerParams

NEG_INF = -3.0e38  # finite -inf stand-in (matches merge_topk)
_EPS = 1e-12       # angular-metric guard (matches repro.core.metrics)


def _gather_rows(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Gather rows of a VMEM-resident f32 table via one-hot matmul:
    table [n, c], idx [r] (pre-clipped to [0, n)) -> [r, c]. Exactly one
    unit term per output row, so values are copied exactly."""
    n = table.shape[0]
    onehot = (idx[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (idx.shape[0], n), 1)).astype(jnp.float32)
    return jax.lax.dot_general(
        onehot, table, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _score_pairs(q: jnp.ndarray, rows: jnp.ndarray,
                 metric: str) -> jnp.ndarray:
    """Per-pair similarities: q [bq, d], rows [bq, m, d] -> [bq, m],
    with the exact formulas of ``repro.core.metrics.similarity_matrix``
    (higher is better)."""
    dot = jnp.sum(q[:, None, :] * rows, axis=-1)
    if metric == "ip":
        return dot
    if metric == "l2":
        qn = jnp.sum(q * q, axis=-1)[:, None]
        xn = jnp.sum(rows * rows, axis=-1)
        return 2.0 * dot - qn - xn
    if metric == "angular":
        qn = jnp.sqrt(jnp.sum(q * q, axis=-1))[:, None] + _EPS
        xn = jnp.sqrt(jnp.sum(rows * rows, axis=-1)) + _EPS
        return dot / (qn * xn)
    raise ValueError(f"unknown metric: {metric}")


def _beam_kernel(q_ref, e_ref, data_ref, adj_ref, scale_ref, zero_ref,
                 out_s_ref, out_i_ref, visited_ref, *, metric: str,
                 ef: int, max_iters: int, quantized: bool):
    bq = q_ref.shape[1]
    n, m0 = adj_ref.shape[1], adj_ref.shape[2]
    w = visited_ref.shape[1]

    q = q_ref[0]
    entry = e_ref[0]                                  # [bq] i32
    # the shard tile, resident for the whole walk; int8 codes are
    # dequantized once here and every iteration reuses the f32 tile
    x = data_ref[0].astype(jnp.float32)
    if quantized:
        x = x * scale_ref[...] + zero_ref[...]
    adjf = adj_ref[0].astype(jnp.float32)             # [n, m0]

    # visited bitmask: packed int32 words, bit (node & 31) of word
    # (node >> 5); seeded with the entry node (1 << 31 lands in the sign
    # bit — fine, the mask is pure bit storage)
    word_iota = jax.lax.broadcasted_iota(jnp.int32, (bq, w), 1)
    visited_ref[...] = jnp.where(
        word_iota == (entry[:, None] >> 5),
        jnp.left_shift(jnp.int32(1), entry[:, None] & 31), 0)

    e_score = _score_pairs(q, _gather_rows(x, entry)[:, None, :],
                           metric)[:, 0]
    cols_ef = jax.lax.broadcasted_iota(jnp.int32, (bq, ef), 1)
    beam_s = jnp.where(cols_ef == 0, e_score[:, None], NEG_INF)
    beam_i = jnp.where(cols_ef == 0, entry[:, None], -1)
    expanded = jnp.zeros((bq, ef), jnp.int32)
    cand_cols = jax.lax.broadcasted_iota(jnp.int32, (bq, ef + m0), 1)

    def cond(carry):
        beam_s, beam_i, expanded, it = carry
        live = jnp.logical_and(expanded == 0, beam_i >= 0)
        return jnp.logical_and(jnp.any(live), it < max_iters)

    def body(carry):
        beam_s, beam_i, expanded, it = carry
        live = jnp.logical_and(expanded == 0, beam_i >= 0)
        active = jnp.any(live, axis=1)                # [bq]
        # select best unexpanded beam entry (ties -> lowest position)
        sel = jnp.where(live, beam_s, NEG_INF)
        j = jnp.argmax(sel, axis=1)
        selmask = cols_ef == j[:, None]
        node = jnp.max(jnp.where(selmask, beam_i, -1), axis=1)
        expanded = jnp.where(
            jnp.logical_and(selmask, active[:, None]), 1, expanded)
        node_c = jnp.clip(node, 0)
        nbrs = _gather_rows(adjf, node_c).astype(jnp.int32)   # [bq, m0]
        nbr_c = jnp.clip(nbrs, 0)
        # visited test: gather each neighbour's word (one-hot over the
        # word axis), then extract its bit — arithmetic shift + mask is
        # correct even when the word's sign bit is set
        vis = visited_ref[...]
        woh = (nbr_c[:, :, None] >> 5) == jax.lax.broadcasted_iota(
            jnp.int32, (bq, m0, w), 2)
        words = jnp.sum(jnp.where(woh, vis[:, None, :], 0), axis=2)
        seen = jnp.bitwise_and(jnp.right_shift(words, nbr_c & 31), 1)
        valid = jnp.logical_and(
            jnp.logical_and(nbrs >= 0, seen == 0), active[:, None])
        # mark all real neighbours visited; per-slot bitwise OR (NOT a
        # sum) so duplicate slots in one adjacency row stay correct
        mark = jnp.logical_and(nbrs >= 0, active[:, None])
        bits = jnp.left_shift(jnp.int32(1), nbr_c & 31)
        newvis = vis
        for m in range(m0):
            newvis = jnp.bitwise_or(newvis, jnp.where(
                jnp.logical_and(woh[:, m, :], mark[:, m][:, None]),
                bits[:, m][:, None], 0))
        visited_ref[...] = newvis
        # score gathered neighbour rows against the resident tile
        rows = _gather_rows(x, nbr_c.reshape(bq * m0)).reshape(
            bq, m0, -1)
        sims = jnp.where(valid, _score_pairs(q, rows, metric), NEG_INF)
        # merge: ef masked-argmax rounds over (beam ∪ neighbours) —
        # same rounds idiom as merge_topk, ties to the lower slot, old
        # beam ordered before new candidates (== lax.top_k ordering)
        cand_s = jnp.concatenate([beam_s, sims], axis=1)
        cand_i = jnp.concatenate(
            [beam_i, jnp.where(valid, nbrs, -1)], axis=1)
        cand_e = jnp.concatenate(
            [expanded, jnp.zeros((bq, m0), jnp.int32)], axis=1)
        work = cand_s
        ns, ni, ne = [], [], []
        for _ in range(ef):
            jj = jnp.argmax(work, axis=1)
            pick = cand_cols == jj[:, None]
            best_s = jnp.max(jnp.where(pick, work, NEG_INF), axis=1)
            # once only sentinels remain argmax re-picks a retired slot;
            # dead picks must come back as (-1, NEG_INF, unexpanded) —
            # same `alive` idiom as merge_topk
            alive = best_s > NEG_INF / 2
            ns.append(jnp.where(alive, best_s, NEG_INF))
            ni.append(jnp.where(
                alive, jnp.max(jnp.where(pick, cand_i, -1), axis=1), -1))
            ne.append(jnp.where(
                alive, jnp.max(jnp.where(pick, cand_e, 0), axis=1), 0))
            work = jnp.where(pick, NEG_INF, work)
        keep = active[:, None]
        return (jnp.where(keep, jnp.stack(ns, axis=1), beam_s),
                jnp.where(keep, jnp.stack(ni, axis=1), beam_i),
                jnp.where(keep, jnp.stack(ne, axis=1), expanded),
                it + 1)

    beam_s, beam_i, _, _ = jax.lax.while_loop(
        cond, body, (beam_s, beam_i, expanded, jnp.int32(0)))
    out_s_ref[...] = beam_s[None]
    out_i_ref[...] = beam_i[None]


@functools.partial(
    jax.jit,
    static_argnames=("metric", "ef", "max_iters", "block_q", "interpret"))
def beam_search_pallas(data: jnp.ndarray, bottom: jnp.ndarray,
                       queries: jnp.ndarray, entries: jnp.ndarray, *,
                       metric: str, ef: int, max_iters: int,
                       scale: Optional[jnp.ndarray] = None,
                       zero: Optional[jnp.ndarray] = None,
                       block_q: int = 8, interpret: bool = False):
    """Fused beam walk over a stack of graphs (see ``ref.py`` for the
    shared contract). Grid is (graphs, query blocks); each step loads
    its shard tile + adjacency into VMEM once and walks ``block_q``
    queries to completion. Scores of padded slots come back as NEG_INF
    (the ops layer normalizes them to -inf)."""
    s, n, d = data.shape
    m0 = bottom.shape[2]
    c = queries.shape[1]
    ef = min(ef, n)
    quantized = data.dtype == jnp.int8

    block_q = max(1, min(block_q, c))
    pc = -(-c // block_q) * block_q
    qp = jnp.zeros((s, pc, d), jnp.float32)
    qp = qp.at[:, :c].set(queries.astype(jnp.float32))
    # pad entries with node 0: padded lanes compute a real (discarded)
    # walk, which keeps every gather index in range
    ep = jnp.zeros((s, pc), jnp.int32).at[:, :c].set(
        entries.astype(jnp.int32))
    w_words = -(-n // 32)
    if scale is None:
        scale = jnp.ones((d,), jnp.float32)
        zero = jnp.zeros((d,), jnp.float32)

    kernel = functools.partial(_beam_kernel, metric=metric, ef=ef,
                               max_iters=max_iters, quantized=quantized)
    out_s, out_i = pl.pallas_call(
        kernel,
        grid=(s, pc // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda si, qi: (si, qi, 0)),
            pl.BlockSpec((1, block_q), lambda si, qi: (si, qi)),
            pl.BlockSpec((1, n, d), lambda si, qi: (si, 0, 0)),
            pl.BlockSpec((1, n, m0), lambda si, qi: (si, 0, 0)),
            pl.BlockSpec((1, d), lambda si, qi: (0, 0)),
            pl.BlockSpec((1, d), lambda si, qi: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, ef), lambda si, qi: (si, qi, 0)),
            pl.BlockSpec((1, block_q, ef), lambda si, qi: (si, qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, pc, ef), jnp.float32),
            jax.ShapeDtypeStruct((s, pc, ef), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((block_q, w_words), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(qp, ep, data, bottom,
      jnp.asarray(scale, jnp.float32).reshape(1, d),
      jnp.asarray(zero, jnp.float32).reshape(1, d))
    return out_s[:, :c], out_i[:, :c]
