"""References for the fused bottom-layer beam walk: jnp oracle + numpy
twin.

The walk is Alg. 1 Search-Level with search factor ``ef`` on the bottom
layer, batched over a stack of graphs: every (graph, slot) pair runs the
EXACT per-query semantics of ``repro.core.hnsw._beam_search_bottom`` —
best-unexpanded selection by masked argmax (ties to the lowest beam
position), neighbour scoring through the graph's own distance
(float32 rows, or dequantize-int8 on the frozen grid of
``repro.core.quant.QuantParams``), visited-set masking, and a
``top_k``-ordered beam merge — but as ONE batched loop over all
``S * C`` rows instead of ``vmap``-of-``while_loop`` per shard.

Semantics shared by every implementation (kernel / jnp / numpy):
  * a row expands exactly one beam entry per iteration while it has any
    unexpanded entry and fewer than ``max_iters`` expansions; finished
    rows are frozen (their state never changes), so the batched loop is
    bit-identical to the per-query ``lax.while_loop`` it replaces;
  * neighbour slots < 0 are adjacency padding and never scored, never
    visited, never enter the beam;
  * the merged beam is sorted best-first with ``lax.top_k`` tie-breaking
    (equal scores keep the lower concatenation position: old beam before
    new neighbours);
  * output is (scores [S, C, ef'], node ids [S, C, ef']) best-first with
    ef' = min(ef, n), padded with (-inf, -1); node ids are LOCAL row
    indices of each graph — callers translate to external ids.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M
from repro.kernels.quant_distance import quant_scores_np, quant_scores_ref


def _walk_ref(data: jnp.ndarray, bottom: jnp.ndarray, queries: jnp.ndarray,
              entries: jnp.ndarray, *, metric: str, ef: int, max_iters: int,
              scale: Optional[jnp.ndarray], zero: Optional[jnp.ndarray]):
    """Shared oracle body; returns (scores, nodes, iters) stacked
    [S, C, ...] with ``iters`` = expansions actually executed per row
    (the roofline's analytic op counts use it)."""
    s, n, d = data.shape
    m0 = bottom.shape[2]
    c = queries.shape[1]
    ef = min(ef, n)
    bsz = s * c

    # flatten the graph stack once; per-row offsets turn local node
    # indices into rows of the flattened tables at gather time, so the
    # whole stack walks in ONE batched loop (no lax.map over shards)
    data_f = data.reshape(s * n, d)
    bottom_f = bottom.reshape(s * n, m0)
    q = queries.reshape(bsz, d).astype(jnp.float32)
    ent = entries.reshape(bsz).astype(jnp.int32)
    off = (jnp.arange(bsz, dtype=jnp.int32) // c) * n
    rows_idx = jnp.arange(bsz)

    if scale is not None:
        scale = jnp.asarray(scale, jnp.float32).reshape(-1)
        zero = jnp.asarray(zero, jnp.float32).reshape(-1)

    def score_rows(rows: jnp.ndarray) -> jnp.ndarray:
        # [bsz, m, d] gathered rows -> [bsz, m]; vmapped row-wise so the
        # dot lowering matches ``score_nodes`` under the per-query walk
        # (bit-identical scores => bit-identical beam decisions)
        if scale is not None:
            return jax.vmap(lambda qv, rv: quant_scores_ref(
                qv[None, :], rv, scale, zero, metric=metric)[0])(q, rows)
        return jax.vmap(lambda qv, rv: M.similarity_matrix(
            qv[None, :], rv, metric)[0])(q, rows)

    visited = jnp.zeros((bsz, n), jnp.bool_).at[rows_idx, ent].set(True)
    beam_i = jnp.full((bsz, ef), -1, jnp.int32).at[:, 0].set(ent)
    e_scores = score_rows(data_f[ent + off][:, None, :])[:, 0]
    beam_s = jnp.full((bsz, ef), -jnp.inf,
                      jnp.float32).at[:, 0].set(e_scores)
    expanded = jnp.zeros((bsz, ef), jnp.bool_)
    iters = jnp.zeros((bsz,), jnp.int32)
    cols = jnp.arange(ef)[None, :]

    def cond(state):
        beam_s, beam_i, expanded, visited, iters, it = state
        live = jnp.logical_and(~expanded, beam_i >= 0)
        return jnp.logical_and(jnp.any(live), it < max_iters)

    def body(state):
        beam_s, beam_i, expanded, visited, iters, it = state
        live = jnp.logical_and(~expanded, beam_i >= 0)
        active = jnp.any(live, axis=1)                       # [bsz]
        # select the best unexpanded beam entry per row
        sel = jnp.where(live, beam_s, -jnp.inf)
        j = jnp.argmax(sel, axis=1)
        node = jnp.take_along_axis(beam_i, j[:, None], axis=1)[:, 0]
        marked = jnp.logical_or(expanded, jnp.logical_and(
            cols == j[:, None], active[:, None]))
        # gather + score its neighbours
        nbrs = bottom_f[jnp.clip(node, 0) + off]             # [bsz, m0]
        nbr_rows = jnp.clip(nbrs, 0)
        seen = jnp.take_along_axis(visited, nbr_rows, axis=1)
        valid = jnp.logical_and(
            jnp.logical_and(nbrs >= 0, ~seen), active[:, None])
        sims = jnp.where(
            valid, score_rows(data_f[nbr_rows + off[:, None]]), -jnp.inf)
        visited = visited.at[rows_idx[:, None], nbr_rows].max(
            jnp.logical_and(nbrs >= 0, active[:, None]))
        # merge into beam: top-ef of (beam ∪ neighbours)
        all_s = jnp.concatenate([beam_s, sims], axis=1)
        all_i = jnp.concatenate([beam_i, jnp.where(valid, nbrs, -1)],
                                axis=1)
        all_e = jnp.concatenate(
            [marked, jnp.zeros((bsz, m0), jnp.bool_)], axis=1)
        top_s, idx = jax.lax.top_k(all_s, ef)
        keep = active[:, None]
        return (jnp.where(keep, top_s, beam_s),
                jnp.where(keep, jnp.take_along_axis(all_i, idx, axis=1),
                          beam_i),
                jnp.where(keep, jnp.take_along_axis(all_e, idx, axis=1),
                          marked),
                visited, iters + active.astype(jnp.int32), it + 1)

    state = (beam_s, beam_i, expanded, visited, iters, jnp.int32(0))
    beam_s, beam_i, _, _, iters, _ = jax.lax.while_loop(cond, body, state)
    return (beam_s.reshape(s, c, ef), beam_i.reshape(s, c, ef),
            iters.reshape(s, c))


def beam_search_ref(data: jnp.ndarray, bottom: jnp.ndarray,
                    queries: jnp.ndarray, entries: jnp.ndarray, *,
                    metric: str, ef: int, max_iters: int,
                    scale: Optional[jnp.ndarray] = None,
                    zero: Optional[jnp.ndarray] = None):
    """Fused bottom-layer beam walk oracle.

    Args:
      data: [S, n, d] graph rows — float32, or int8 codes when
        ``scale``/``zero`` are given (frozen-grid dequantize scoring).
      bottom: [S, n, M0] i32 bottom-layer adjacency, -1 padded.
      queries: [S, C, d] float32 (preprocessed) queries per graph slot.
      entries: [S, C] i32 bottom-layer entry node per slot (the greedy
        upper-layer descent stays outside — it is a few cheap steps).
      ef: beam width (clamped to n); max_iters: expansion bound per row.

    Returns (scores [S, C, ef'] f32, nodes [S, C, ef'] i32) best-first,
    (-inf, -1) padded, ef' = min(ef, n); nodes are graph-local rows.
    """
    scores, nodes, _ = _walk_ref(data, bottom, queries, entries,
                                 metric=metric, ef=ef, max_iters=max_iters,
                                 scale=scale, zero=zero)
    return scores, nodes


def beam_search_stats(data, bottom, queries, entries, *, metric: str,
                      ef: int, max_iters: int, scale=None, zero=None):
    """Oracle walk that also returns per-row expansion counts
    [S, C] i32 — ``benchmarks/roofline.py`` derives its analytic
    FLOP/byte counts from the expansions a workload actually executes."""
    return _walk_ref(jnp.asarray(data), jnp.asarray(bottom),
                     jnp.asarray(queries), jnp.asarray(entries),
                     metric=metric, ef=ef, max_iters=max_iters,
                     scale=scale, zero=zero)


def beam_search_np(data: np.ndarray, bottom: np.ndarray,
                   queries: np.ndarray, entries: np.ndarray, *,
                   metric: str, ef: int, max_iters: int,
                   scale: Optional[np.ndarray] = None,
                   zero: Optional[np.ndarray] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Numpy twin of :func:`beam_search_ref` (per-row Python loop; the
    independent host-side oracle the kernel tests triangulate against)."""
    data = np.asarray(data)
    bottom = np.asarray(bottom)
    queries = np.asarray(queries, np.float32)
    entries = np.asarray(entries)
    s, n, _ = data.shape
    m0 = bottom.shape[2]
    c = queries.shape[1]
    ef = min(ef, n)
    out_s = np.full((s, c, ef), -np.inf, np.float32)
    out_i = np.full((s, c, ef), -1, np.int32)
    for si in range(s):
        adj = bottom[si]
        codes = data[si]
        for ci in range(c):
            q = queries[si, ci]

            def score(rows_sel):
                if scale is not None:
                    return quant_scores_np(q[None, :], codes[rows_sel],
                                           scale, zero, metric=metric)[0]
                return M.similarity_matrix_np(
                    q[None, :], codes[rows_sel].astype(np.float32),
                    metric)[0]

            e = int(entries[si, ci])
            visited = np.zeros(n, bool)
            visited[e] = True
            beam_s = np.full(ef, -np.inf, np.float32)
            beam_i = np.full(ef, -1, np.int32)
            expanded = np.zeros(ef, bool)
            beam_s[0] = score(np.asarray([e]))[0]
            beam_i[0] = e
            for _ in range(max_iters):
                live = ~expanded & (beam_i >= 0)
                if not live.any():
                    break
                j = int(np.argmax(np.where(live, beam_s, -np.inf)))
                node = int(beam_i[j])
                expanded[j] = True
                nbrs = adj[node]
                rows_sel = np.clip(nbrs, 0, n - 1)
                valid = (nbrs >= 0) & ~visited[rows_sel]
                sims = np.where(valid, score(rows_sel),
                                -np.inf).astype(np.float32)
                visited[nbrs[nbrs >= 0]] = True
                all_s = np.concatenate([beam_s, sims])
                all_i = np.concatenate(
                    [beam_i, np.where(valid, nbrs, -1).astype(np.int32)])
                all_e = np.concatenate([expanded, np.zeros(m0, bool)])
                # stable descending sort == lax.top_k tie-breaking
                order = np.argsort(-all_s, kind="stable")[:ef]
                beam_s = all_s[order].astype(np.float32)
                beam_i = all_i[order]
                expanded = all_e[order]
            out_s[si, ci] = beam_s
            out_i[si, ci] = beam_i
    return out_s, out_i
