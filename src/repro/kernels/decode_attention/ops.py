"""Public op: flash-decode attention (Pallas on TPU, oracle elsewhere)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import flash_decode_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 pos: jnp.ndarray, *, block_s: int = 512,
                 use_kernel: bool = True) -> jnp.ndarray:
    """Single-token GQA attention over a KV cache. Returns [B, H, hd] f32."""
    if not use_kernel or k.shape[1] < 16:
        return decode_attention_ref(q, k, v, pos)
    return flash_decode_pallas(
        q, k, v, pos, block_s=block_s,
        interpret=jax.default_backend() != "tpu")
