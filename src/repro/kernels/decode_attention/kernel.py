"""Pallas TPU kernel: flash-decode — single-token GQA attention over a
long KV cache with online softmax.

Targets the memory-bound long-context decode identified in EXPERIMENTS.md
§Roofline (after the ring-cache work, reading the global-layer caches IS
the bottleneck): the cache is streamed HBM -> VMEM once in ``block_s`` row
tiles; running (max, sum, acc) live in VMEM scratch, so probabilities
never round-trip to HBM and the only cache traffic is the single
streaming read.

Grid: (B * KV, S_blocks), sequential in the S dimension (scratch carries
the online-softmax state). Each program handles all G = H/KV query heads
of one (batch row, kv head) pair — MXU-shaped [G, hd] x [hd, block_s].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.jax_compat import CompilerParams as _CompilerParams

NEG_INF = -3.0e38


def _flash_decode_kernel(pos_ref, q_ref, k_ref, v_ref, out_ref,
                         m_ref, l_ref, acc_ref, *, block_s: int,
                         scale: float):
    s_idx = pl.program_id(1)
    num_s = pl.num_programs(1)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # [G, hd]
    k = k_ref[0].astype(jnp.float32)          # [block_s, hd]
    v = v_ref[0].astype(jnp.float32)          # [block_s, hd]
    pos = pos_ref[0]

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # [G, block_s]
    kpos = s_idx * block_s + jax.lax.broadcasted_iota(
        jnp.int32, scores.shape, 1)
    scores = jnp.where(kpos <= pos, scores, NEG_INF)

    m_prev = m_ref[...]                        # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    # guard: all-masked block keeps m at NEG_INF; exp(NEG_INF-NEG_INF)
    # would be NaN, so rescale only when finite
    rescale = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
    p = jnp.exp(jnp.where(scores > NEG_INF / 2, scores - m_new, NEG_INF))
    l_new = l_ref[...] * rescale + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_ref[...] * rescale + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)    # [G, hd]

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(s_idx == num_s - 1)
    def _flush():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        pos: jnp.ndarray, *, block_s: int = 512,
                        interpret: bool = False) -> jnp.ndarray:
    """q: [B, H, hd]; k, v: [B, S, KV, hd]; pos: [B] -> out [B, H, hd] f32."""
    b, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    groups = h // kvh
    block_s = min(block_s, s)
    ps = -(-s // block_s) * block_s
    if ps != s:
        pad = ps - s
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded rows are masked by kpos <= pos (pos < s always)
    # layout: one program per (b, kv head): q [B*KV, G, hd],
    # k/v [B*KV, S, hd]
    qr = q.reshape(b, kvh, groups, hd).reshape(b * kvh, groups, hd)
    kr = jnp.moveaxis(k, 2, 1).reshape(b * kvh, ps, hd)
    vr = jnp.moveaxis(v, 2, 1).reshape(b * kvh, ps, hd)
    posr = jnp.repeat(pos, kvh)

    kernel = functools.partial(
        _flash_decode_kernel, block_s=block_s, scale=hd ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=(b * kvh, ps // block_s),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, groups, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_s, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_s, hd), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, groups, hd), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, groups, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((groups, 1), jnp.float32),
            pltpu.VMEM((groups, 1), jnp.float32),
            pltpu.VMEM((groups, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(posr, qr, kr, vr)
    return out.reshape(b, kvh, groups, hd).reshape(b, h, hd)
