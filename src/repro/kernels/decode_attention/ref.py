"""Pure-jnp oracle for the flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         pos: jnp.ndarray) -> jnp.ndarray:
    """Single-token GQA attention against a KV cache.

    Args:
      q:   [B, H, hd] query heads for the current token.
      k,v: [B, S, KV, hd] cache (positions > pos are invalid).
      pos: [B] int32 current position (cache rows 0..pos inclusive valid).

    Returns [B, H, hd] attention output (f32).
    """
    b, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    groups = h // kvh
    qg = q.reshape(b, kvh, groups, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bkgh,bskh->bkgs", qg, kf) * (hd ** -0.5)
    valid = jnp.arange(s)[None, :] <= pos[:, None]              # [B, S]
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, vf)
    return out.reshape(b, h, hd)
