from repro.kernels.quant_distance.ops import quant_impl, quant_scores
from repro.kernels.quant_distance.ref import (dequantize_jnp,
                                              quant_scores_np,
                                              quant_scores_ref)

__all__ = ["dequantize_jnp", "quant_impl", "quant_scores",
           "quant_scores_np", "quant_scores_ref"]
