"""Pallas TPU kernel: asymmetric float32-query x int8-database distances.

The quantized arena's distance scan: each grid step dequantizes one
[block_n, d] int8 tile in VMEM (one fused multiply-add on the VPU) and
scores a [block_q, d] float32 query tile against it on the MXU — the
int8 codes are what crosses HBM, so the scan moves ~4x fewer bytes than
the float path on the same memory-bandwidth-bound hot loop.

Grid is 2-D over (query blocks, database blocks), fully parallel; the
scale/zero vectors ride along replicated ([1, d] blocks). Metric
formulas mirror ``repro.core.metrics.similarity_matrix`` exactly
(including the angular epsilon) so kernel / jnp oracle / numpy twin
share one semantics — same three-implementation contract as
``merge_topk``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common.jax_compat import CompilerParams as _CompilerParams

_EPS = 1e-12  # angular epsilon, identical to repro.core.metrics


def _quant_distance_kernel(q_ref, c_ref, s_ref, z_ref, out_ref, *,
                           metric: str):
    q = q_ref[...]                                     # [bq, d] f32
    x = c_ref[...].astype(jnp.float32) * s_ref[...] + z_ref[...]  # [bn, d]
    dot = jax.lax.dot_general(
        q, x, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # [bq, bn]
    if metric == "l2":
        qn = jnp.sum(q * q, axis=-1, keepdims=True)
        xn = jnp.sum(x * x, axis=-1)
        out_ref[...] = 2.0 * dot - qn - xn[None, :]
    elif metric == "ip":
        out_ref[...] = dot
    elif metric == "angular":
        qn = jnp.sqrt(jnp.sum(q * q, axis=-1, keepdims=True)) + _EPS
        xn = jnp.sqrt(jnp.sum(x * x, axis=-1)) + _EPS
        out_ref[...] = dot / (qn * xn[None, :])
    else:
        raise ValueError(f"unknown metric {metric!r}")


@functools.partial(jax.jit, static_argnames=("metric", "block_q",
                                             "block_n", "interpret"))
def quant_distance_pallas(q: jnp.ndarray, codes: jnp.ndarray,
                          scale: jnp.ndarray, zero: jnp.ndarray, *,
                          metric: str, block_q: int = 128,
                          block_n: int = 512, interpret: bool = False):
    """Blocked asymmetric distance scan.

    Args:
      q: [B, d] f32 preprocessed queries.
      codes: [n, d] int8 database codes.
      scale: [d] f32 per-dimension step.
      zero: [d] f32 per-dimension zero-point.

    Returns [B, n] f32 similarities. Padding rows/columns introduced for
    the block grid are computed-and-trimmed (pad queries are zeros, pad
    codes are zero codes); callers mask invalid rows themselves.
    """
    b, d = q.shape
    n = codes.shape[0]
    assert codes.shape == (n, d), (codes.shape, q.shape)

    block_q = min(block_q, max(8, b))
    block_n = min(block_n, max(8, n))
    pb = -(-b // block_q) * block_q
    pn = -(-n // block_n) * block_n
    qp = jnp.zeros((pb, d), jnp.float32).at[:b].set(q.astype(jnp.float32))
    cp = jnp.zeros((pn, d), jnp.int8).at[:n].set(codes)

    kernel = functools.partial(_quant_distance_kernel, metric=metric)
    out = pl.pallas_call(
        kernel,
        grid=(pb // block_q, pn // block_n),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pb, pn), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(qp, cp, scale.reshape(1, d).astype(jnp.float32),
      zero.reshape(1, d).astype(jnp.float32))
    return out[:b, :n]
