"""References for the asymmetric int8 distance: jnp oracle + numpy twin.

Asymmetric distance computation (ADC): the query stays float32, the
database row is an int8 code vector on a per-dimension affine grid
(``repro.core.quant.QuantParams``). Every implementation computes
EXACTLY ``similarity(q, dequantize(codes))`` with the metric formulas of
``repro.core.metrics`` — including the angular epsilon — so the
quantized search differs from the float path only by the grid's rounding
error, never by a drifted distance definition.

Semantics shared by every implementation (kernel / jnp / numpy):
  * dequantization is the fused multiply-add ``x_hat = c * scale + zero``;
  * l2 similarity is ``2 q.x_hat - ||q||^2 - ||x_hat||^2`` (matmul
    shaped), ip is ``q.x_hat``, angular normalises both sides with the
    metrics module's ``+ 1e-12`` epsilon;
  * no masking: callers (the beam search, the padded kernel launch)
    mask invalid rows themselves, as they do on the float path.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M


def dequantize_jnp(codes: jnp.ndarray, scale: jnp.ndarray,
                   zero: jnp.ndarray) -> jnp.ndarray:
    """[*, d] int8 codes -> [*, d] float32 rows (trace-friendly twin of
    ``QuantParams.dequantize``)."""
    return codes.astype(jnp.float32) * scale + zero


def quant_scores_ref(q: jnp.ndarray, codes: jnp.ndarray,
                     scale: jnp.ndarray, zero: jnp.ndarray, *,
                     metric: str) -> jnp.ndarray:
    """Asymmetric similarity oracle.

    Args:
      q: [B, d] float32 (preprocessed) queries.
      codes: [n, d] int8 database codes.
      scale: [d] float32 per-dimension step.
      zero: [d] float32 per-dimension zero-point.

    Returns [B, n] float32 similarities (larger = more similar).
    """
    x_hat = dequantize_jnp(codes, scale, zero)
    return M.similarity_matrix(q, x_hat, metric)


def quant_scores_np(q: np.ndarray, codes: np.ndarray, scale: np.ndarray,
                    zero: np.ndarray, *, metric: str) -> np.ndarray:
    """Numpy twin of :func:`quant_scores_ref` (host-side validation and
    the exact-rerank tests' independent oracle)."""
    x_hat = (np.asarray(codes, np.float32) * np.asarray(scale, np.float32)
             + np.asarray(zero, np.float32))
    return M.similarity_matrix_np(np.asarray(q, np.float32), x_hat, metric)
