"""Public op: asymmetric int8 distance scan (Pallas on TPU, jnp oracle
elsewhere).

``quant_scores`` is THE scoring primitive of the quantized arena: the
quantized beam search (``repro.core.hnsw.QuantHNSWArrays.score_nodes``)
inlines the oracle semantics on its gathered neighbour tiles (a kernel
launch inside the vmapped while_loop walk would defeat fusion — the same
reason the SPMD path calls ``merge_topk`` with ``use_kernel=False``),
while standalone batched scans — rerank-candidate scoring, benchmarks,
brute-force baselines over a quantized shard — dispatch to the compiled
Pallas kernel on TPU and to the jnp oracle (compiled XLA) everywhere
else. All implementations share one semantics:
``similarity(q, dequantize(codes))`` with the exact metric formulas of
``repro.core.metrics``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant_distance.kernel import quant_distance_pallas
from repro.kernels.quant_distance.ref import (dequantize_jnp,  # noqa: F401
                                              quant_scores_np,
                                              quant_scores_ref)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quant_impl() -> str:
    """Which implementation :func:`quant_scores` dispatches to on this
    backend (benchmark artifacts record it so the perf trajectory names
    what was actually measured)."""
    return "pallas-kernel" if _on_tpu() else "xla-oracle"


def quant_scores(q: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray,
                 zero: jnp.ndarray, *, metric: str,
                 use_kernel: bool = True, block_q: int = 128,
                 block_n: int = 512) -> jnp.ndarray:
    """Similarity of float32 queries against int8 database codes.

    Args:
      q: [B, d] f32 preprocessed queries.
      codes: [n, d] int8 codes on the ``(scale, zero)`` grid.
      scale: [d] f32 per-dimension step.
      zero: [d] f32 per-dimension zero-point.
      use_kernel: False forces the jnp oracle (required inside traced
        walks and shard_map, where a kernel launch cannot run).

    Returns [B, n] f32 similarities (larger = more similar).
    """
    if not use_kernel or not _on_tpu():
        return quant_scores_ref(q, codes, scale, zero, metric=metric)
    return quant_distance_pallas(q, codes, scale, zero, metric=metric,
                                 block_q=block_q, block_n=block_n)
