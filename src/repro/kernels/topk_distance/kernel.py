"""Pallas TPU kernel: blocked similarity scan with running top-k.

This is the compute hotspot of the paper (DESIGN.md §3): scoring a query
batch against a dense block of vectors shows up in
  * k-means assignment (Alg. 3 line 4 / Alg. 5 line 5),
  * partition assignment of every dataset item (Alg. 3 lines 7-10),
  * MIPS norm-replication top-r search (Alg. 5 line 14),
  * brute-force rerank of candidate sets during query processing.

TPU mapping: the database is streamed HBM -> VMEM in ``block_n`` row tiles;
the query tile stays VMEM-resident; the [block_q, block_n] similarity tile is
one MXU matmul; a running top-k accumulator lives in VMEM scratch across the
sequential database grid dimension. Top-k maintenance is k rounds of
masked-argmax (k is small and static), which avoids an in-kernel sort.

Grid: (q_blocks, db_blocks) with the db dimension sequential ("arbitrary")
so the scratch accumulator carries across database tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.jax_compat import CompilerParams as _CompilerParams

NEG_INF = -3.0e38  # python float so the kernel doesn't capture a traced const


def _merge_topk(acc_scores, acc_ids, new_scores, new_ids, k: int):
    """k rounds of masked argmax over the concatenation -> new (scores, ids).

    acc_*: [bq, k]; new_*: [bq, bn]. Returns sorted-descending [bq, k].
    """
    cat_s = jnp.concatenate([acc_scores, new_scores], axis=1)  # [bq, k+bn]
    cat_i = jnp.concatenate([acc_ids, new_ids], axis=1)
    out_s = []
    out_i = []
    for _ in range(k):
        j = jnp.argmax(cat_s, axis=1)                          # [bq]
        rows = jax.lax.broadcasted_iota(jnp.int32, cat_s.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, cat_s.shape, 1)
        sel = cols == j[:, None]
        out_s.append(jnp.max(jnp.where(sel, cat_s, NEG_INF), axis=1))
        out_i.append(jnp.max(jnp.where(sel, cat_i, -1), axis=1))
        cat_s = jnp.where(sel, NEG_INF, cat_s)
        del rows
    return jnp.stack(out_s, axis=1), jnp.stack(out_i, axis=1)


def _topk_kernel(q_ref, db_ref, out_s_ref, out_i_ref,
                 acc_s_ref, acc_i_ref, *, k: int, metric: str,
                 block_n: int, total_n: int):
    db_idx = pl.program_id(1)
    num_db = pl.num_programs(1)

    @pl.when(db_idx == 0)
    def _init():
        acc_s_ref[...] = jnp.full_like(acc_s_ref, NEG_INF)
        acc_i_ref[...] = jnp.full_like(acc_i_ref, -1)

    q = q_ref[...].astype(jnp.float32)          # [bq, d]
    x = db_ref[...].astype(jnp.float32)         # [bn, d]

    if metric == "angular":
        q = q * jax.lax.rsqrt(jnp.sum(q * q, -1, keepdims=True) + 1e-12)
        x = x * jax.lax.rsqrt(jnp.sum(x * x, -1, keepdims=True) + 1e-12)

    sims = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # [bq, bn] on the MXU
    if metric == "l2":
        sims = 2.0 * sims - jnp.sum(q * q, -1, keepdims=True) \
            - jnp.sum(x * x, -1)[None, :]

    # mask padded database rows (beyond total_n)
    base = db_idx * block_n
    local = jax.lax.broadcasted_iota(jnp.int32, sims.shape, 1)
    gids = base + local
    sims = jnp.where(gids < total_n, sims, NEG_INF)

    new_s, new_i = _merge_topk(
        acc_s_ref[...], acc_i_ref[...], sims, gids, k)
    acc_s_ref[...] = new_s
    acc_i_ref[...] = new_i

    @pl.when(db_idx == num_db - 1)
    def _flush():
        out_s_ref[...] = acc_s_ref[...]
        out_i_ref[...] = acc_i_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("k", "metric", "block_q", "block_n", "interpret"))
def topk_similarity_pallas(queries: jnp.ndarray, database: jnp.ndarray, *,
                           k: int, metric: str = "l2", block_q: int = 128,
                           block_n: int = 512, interpret: bool = False):
    """Blocked top-k similarity scan. Returns (scores [B,k], ids [B,k])."""
    b, d = queries.shape
    n, d2 = database.shape
    assert d == d2, (d, d2)
    assert k <= block_n, "k must fit in one database block"

    block_q = min(block_q, max(8, b))
    pb = -(-b // block_q) * block_q
    pn = -(-n // block_n) * block_n
    qp = jnp.zeros((pb, d), queries.dtype).at[:b].set(queries)
    xp = jnp.zeros((pn, d), database.dtype).at[:n].set(database)

    grid = (pb // block_q, pn // block_n)
    kernel = functools.partial(
        _topk_kernel, k=k, metric=metric, block_n=block_n, total_n=n)

    out_s, out_i = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pb, k), jnp.float32),
            jax.ShapeDtypeStruct((pb, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qp, xp)
    return out_s[:b], out_i[:b]
