from repro.kernels.topk_distance.ops import topk_similarity
from repro.kernels.topk_distance.ref import topk_similarity_ref

__all__ = ["topk_similarity", "topk_similarity_ref"]
