"""Public op: top-k similarity scan (Pallas on TPU, oracle elsewhere).

``topk_similarity`` dispatches to the Pallas kernel with interpret mode on
CPU (kernel body executed in Python for validation) and compiled mode on
TPU. Callers that only need tiny problems can use the ref directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk_distance.kernel import topk_similarity_pallas
from repro.kernels.topk_distance.ref import topk_similarity_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def topk_similarity(queries: jnp.ndarray, database: jnp.ndarray, *, k: int,
                    metric: str = "l2", block_q: int = 128,
                    block_n: int = 512, use_kernel: bool = True):
    """Top-k most-similar database rows for each query.

    Returns (scores [B, k] f32 descending, ids [B, k] i32).
    """
    n = database.shape[0]
    if not use_kernel or n < 32 or k > min(block_n, n):
        return topk_similarity_ref(queries, database, k=k, metric=metric)
    return topk_similarity_pallas(
        queries, database, k=k, metric=metric, block_q=block_q,
        block_n=block_n, interpret=not _on_tpu())
