"""Pure-jnp oracle for the blocked top-k similarity scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_similarity_ref(queries: jnp.ndarray, database: jnp.ndarray, *,
                        k: int, metric: str = "l2"):
    """Exact top-k by similarity.

    Args:
      queries:  [B, d]
      database: [n, d]
      k: neighbours to return.
      metric: 'l2' (sim = -||q-x||^2), 'ip' or 'angular'.

    Returns:
      scores [B, k] f32 descending, ids [B, k] i32.
    """
    q = queries.astype(jnp.float32)
    x = database.astype(jnp.float32)
    if metric == "l2":
        sims = 2.0 * q @ x.T - jnp.sum(q * q, -1, keepdims=True) \
            - jnp.sum(x * x, -1)[None, :]
    elif metric == "ip":
        sims = q @ x.T
    elif metric == "angular":
        qn = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
        xn = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)
        sims = qn @ xn.T
    else:
        raise ValueError(metric)
    scores, ids = jax.lax.top_k(sims, k)
    return scores.astype(jnp.float32), ids.astype(jnp.int32)
