"""Pallas TPU kernel: Mamba2 SSD chunk scan (state-space duality).

The SSD formulation is *designed* for matmul units: each chunk's output is
an intra-chunk [Q, Q] x [Q, P] matmul (MXU) plus a rank-N correction from
the running inter-chunk state. This kernel keeps the running state
[Hb, N, P] in VMEM scratch across the sequential chunk grid dimension, so
the recurrence never round-trips to HBM — the HBM traffic is exactly one
streaming read of (x, dt, B, C) and one write of y.

Grid: (B, H_blocks, n_chunks); chunks sequential ("arbitrary"), batch and
head blocks parallel. Head-major layouts keep BlockSpecs contiguous.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common.jax_compat import CompilerParams as _CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                st_scratch, *, chunk: int):
    c_idx = pl.program_id(2)
    num_c = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        st_scratch[...] = jnp.zeros_like(st_scratch)

    x = x_ref[0, 0].astype(jnp.float32)       # [Hb, Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)     # [Hb, Q]
    a = a_ref[...].astype(jnp.float32)        # [Hb]
    bm = b_ref[0, 0].astype(jnp.float32)      # [Q, N]
    cm = c_ref[0, 0].astype(jnp.float32)      # [Q, N]

    da = dt * a[:, None]                      # [Hb, Q] (negative)
    cum = jnp.cumsum(da, axis=-1)             # [Hb, Q]
    # intra-chunk decay L[h, i, j] = exp(cum[i] - cum[j]) for i >= j
    diff = cum[:, :, None] - cum[:, None, :]
    q_iota = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 1)
    k_iota = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 2)
    tri = q_iota >= k_iota
    decay_in = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)

    # scores[h, i, j] = (C_i . B_j) * L[h, i, j] * dt[h, j]
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    scores = cb[None] * decay_in * dt[:, None, :]                 # [Hb,Q,Q]
    # intra-chunk output: one [Q, Q] x [Q, P] matmul per head (MXU)
    ydt = jax.lax.dot_general(
        scores, x, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                       # [Hb,Q,P]

    # inter-chunk contribution from the carried state
    state = st_scratch[...]                                       # [Hb,N,P]
    cdec = jnp.exp(cum)                                           # [Hb, Q]
    yoff = jax.lax.dot_general(
        jnp.broadcast_to(cm[None], (state.shape[0],) + cm.shape),
        state, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                       # [Hb,Q,P]
    y = ydt + yoff * cdec[..., None]
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S <- S * exp(sum da) + B^T (x * dt * decay_to_end)
    decay_to_end = jnp.exp(cum[:, -1:] - cum)                     # [Hb, Q]
    xw = x * (dt * decay_to_end)[..., None]                       # [Hb,Q,P]
    contrib = jax.lax.dot_general(
        jnp.broadcast_to(bm[None], (state.shape[0],) + bm.shape),
        xw, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                       # [Hb,N,P]
    chunk_decay = jnp.exp(cum[:, -1])                             # [Hb]
    st_scratch[...] = state * chunk_decay[:, None, None] + contrib

    @pl.when(c_idx == num_c - 1)
    def _flush():
        state_ref[0] = st_scratch[...]


@functools.partial(
    jax.jit, static_argnames=("chunk", "block_h", "interpret"))
def ssd_pallas(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
               b_mat: jnp.ndarray, c_mat: jnp.ndarray, *, chunk: int = 128,
               block_h: int = 8, interpret: bool = False):
    """SSD chunk scan. x [B,S,H,P], dt [B,S,H], a [H], b/c [B,S,N].

    Returns (y [B,S,H,P] f32, final_state [B,H,N,P] f32). S is padded to a
    chunk multiple internally (dt=0 padding is a no-op for the scan).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 => identity
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q
    block_h = min(block_h, h)
    while h % block_h:
        block_h -= 1
    hb = h // block_h

    # head-major chunked layouts
    xh = jnp.moveaxis(x.reshape(bsz, nc, q, h, p), 3, 2)   # [B,C,H,Q,P]
    dth = jnp.moveaxis(dt.reshape(bsz, nc, q, h), 3, 2)    # [B,C,H,Q]
    bmc = b_mat.reshape(bsz, nc, q, n)
    cmc = c_mat.reshape(bsz, nc, q, n)

    kernel = functools.partial(_ssd_kernel, chunk=q)
    y, state = pl.pallas_call(
        kernel,
        grid=(bsz, hb, nc),
        in_specs=[
            pl.BlockSpec((1, 1, block_h, q, p),
                         lambda b, hh, c: (b, c, hh, 0, 0)),
            pl.BlockSpec((1, 1, block_h, q),
                         lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((block_h,), lambda b, hh, c: (hh,)),
            pl.BlockSpec((1, 1, q, n), lambda b, hh, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda b, hh, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_h, q, p),
                         lambda b, hh, c: (b, c, hh, 0, 0)),
            pl.BlockSpec((1, block_h, n, p), lambda b, hh, c: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nc, h, q, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_h, n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xh, dth, a, bmc, cmc)
    y = jnp.moveaxis(y, 2, 3).reshape(bsz, nc * q, h, p)[:, :s]
    return y, state
