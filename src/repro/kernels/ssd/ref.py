"""Pure-jnp oracle for the SSD chunk-scan kernel: re-exports the model's
chunked implementation (itself validated against recurrent decode in
tests/test_arch_smoke.py::test_decode_matches_prefill)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
            b_mat: jnp.ndarray, c_mat: jnp.ndarray, *, chunk: int,
            initial_state=None):
    """x [B,S,H,P], dt [B,S,H], a [H], b/c [B,S,N] ->
    (y [B,S,H,P], final_state [B,H,N,P])."""
    return ssd_chunked(x, dt, a, b_mat, c_mat, chunk=chunk,
                       initial_state=initial_state)
