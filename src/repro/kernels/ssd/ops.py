"""Public op: SSD chunk scan (Pallas on TPU, chunked-jnp oracle elsewhere)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_ref


def ssd_scan(x, dt, a, b_mat, c_mat, *, chunk: int = 128,
             use_kernel: bool = True):
    """Mamba2 SSD scan. Returns (y [B,S,H,P], final_state [B,H,N,P])."""
    if not use_kernel or x.shape[1] < chunk:
        return ssd_ref(x, dt, a, b_mat, c_mat, chunk=chunk)
    return ssd_pallas(x, dt, a, b_mat, c_mat, chunk=chunk,
                      interpret=jax.default_backend() != "tpu")
