from repro.kernels.ssd.ops import ssd_scan
from repro.kernels.ssd.ref import ssd_ref

__all__ = ["ssd_scan", "ssd_ref"]
