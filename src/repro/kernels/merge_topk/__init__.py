from repro.kernels.merge_topk.ops import merge_impl, merge_topk
from repro.kernels.merge_topk.ref import merge_topk_np, merge_topk_ref

__all__ = ["merge_impl", "merge_topk", "merge_topk_np", "merge_topk_ref"]
