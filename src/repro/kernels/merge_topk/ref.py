"""References for the dedup-top-k merge: pure-jnp oracle + numpy twin.

The merge is Alg. 4 line 9 (coordinator combine): given per-query partial
result lists flattened to ``[B, m]`` (scores, external ids), return the k
best entries per query with *duplicate external ids removed* — MIPS
norm-replication (Alg. 5) stores one item in several sub-datasets, so two
shards can legitimately return the same global id.

Semantics shared by every implementation (kernel / jnp / numpy):
  * ids < 0 are padding and never returned;
  * of a duplicate-id group only the best-scoring occurrence survives
    (score ties break to the lowest input position, so the merge is
    deterministic);
  * output is sorted descending, padded with (-inf, -1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dominated(scores: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """[B, m] -> [B, m] bool: entry j loses to a better same-id entry i."""
    m = ids.shape[1]
    eq = ids[:, :, None] == ids[:, None, :]                   # [B, i, j]
    ii = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    beats = jnp.logical_or(
        scores[:, :, None] > scores[:, None, :],
        jnp.logical_and(scores[:, :, None] == scores[:, None, :],
                        (ii < jj)[None]))
    valid_i = (ids >= 0)[:, :, None]
    return jnp.any(eq & beats & valid_i, axis=1)


def merge_topk_ref(scores: jnp.ndarray, ids: jnp.ndarray, *, k: int,
                   alive=None):
    """Dedup top-k merge oracle.

    Args:
      scores: [B, m] f32, -inf for empty slots.
      ids: [B, m] int external ids, -1 for empty slots.
      k: entries to keep (k <= m; ``ops.merge_topk`` pads otherwise).
      alive: optional [B, m] bool — dead entries become (-inf, -1)
        before the merge (pre-merge filtering, same as ``ops``).

    Returns:
      (scores [B, k] f32 descending, ids [B, k] i32), (-inf, -1) padded.
    """
    if alive is not None:
        ids = jnp.where(alive, ids, -1)
    s = jnp.where(ids >= 0, scores.astype(jnp.float32), -jnp.inf)
    s = jnp.where(_dominated(s, ids), -jnp.inf, s)
    top_s, sel = jax.lax.top_k(s, k)
    top_i = jnp.take_along_axis(ids.astype(jnp.int32), sel, axis=1)
    top_i = jnp.where(top_s > -jnp.inf, top_i, -1)
    return top_s, top_i


def merge_topk_np(scores: np.ndarray, ids: np.ndarray, *, k: int,
                  alive=None):
    """Numpy twin of :func:`merge_topk_ref` for host-side merging (the
    serving engine's coordinator thread merges tiny per-query partial
    lists; a jit round-trip per query would cost more than the merge).

    ``alive`` ([B, m] bool) demotes dead entries (filters, tombstones)
    to (-inf, -1) BEFORE the merge — the engine filters tombstones here
    so a deleted id can never crowd a live result out of the top k.

    Returns (scores [B, k] f32 descending, ids [B, k] int64) — the same
    tuple order as every other ``merge_topk`` implementation.
    """
    scores = np.asarray(scores, np.float32)
    ids = np.asarray(ids, np.int64)
    if alive is not None:
        ids = np.where(np.asarray(alive, bool), ids, -1)
    b, m = scores.shape
    s = np.where(ids >= 0, scores, -np.inf)
    eq = ids[:, :, None] == ids[:, None, :]
    beats = (s[:, :, None] > s[:, None, :]) | (
        (s[:, :, None] == s[:, None, :]) &
        (np.arange(m)[:, None] < np.arange(m)[None, :]))
    dominated = np.any(eq & beats & (ids >= 0)[:, :, None], axis=1)
    s = np.where(dominated, -np.inf, s)
    kk = min(k, m)
    order = np.argsort(-s, axis=1, kind="stable")[:, :kk]
    out_ids = np.full((b, k), -1, np.int64)
    out_scores = np.full((b, k), -np.inf, np.float32)
    out_scores[:, :kk] = np.take_along_axis(s, order, axis=1)
    out_ids[:, :kk] = np.take_along_axis(ids, order, axis=1)
    out_ids[:, :kk] = np.where(out_scores[:, :kk] > -np.inf,
                               out_ids[:, :kk], -1)
    return out_scores, out_ids
