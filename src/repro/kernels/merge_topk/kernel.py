"""Pallas TPU kernel: dedup-top-k merge of per-shard partial results.

The coordinator combine of Alg. 4 line 9: each query's w*k partial
(score, id) pairs collapse to the k best with duplicate external ids
removed (MIPS replication can return one global id from two shards).

TPU mapping (same style as ``topk_distance``): the [block_q, m] partial
tile lives in VMEM (m = w*k is small); selection is k rounds of masked
argmax — after each round an *id-match mask* retires every entry carrying
the selected external id, which performs the dedup for free inside the
selection loop instead of as a separate host pass. Grid is 1-D over query
blocks, fully parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common.jax_compat import CompilerParams as _CompilerParams

NEG_INF = -3.0e38  # python float so the kernel doesn't capture a traced const


def _merge_kernel(s_ref, i_ref, out_s_ref, out_i_ref, *, k: int):
    s = s_ref[...]                                     # [bq, m]
    ids = i_ref[...]                                   # [bq, m]
    s = jnp.where(ids >= 0, s, NEG_INF)
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    out_s = []
    out_i = []
    for _ in range(k):
        j = jnp.argmax(s, axis=1)                      # [bq]
        sel = cols == j[:, None]
        best_s = jnp.max(jnp.where(sel, s, NEG_INF), axis=1)
        best_i = jnp.max(jnp.where(sel, ids, -1), axis=1)
        alive = best_s > NEG_INF / 2  # rows with slots left this round
        best_i = jnp.where(alive, best_i, -1)
        out_s.append(jnp.where(alive, best_s, NEG_INF))
        out_i.append(best_i)
        # retire the selection AND every same-id duplicate (replication)
        dup = jnp.logical_and(ids == best_i[:, None], best_i[:, None] >= 0)
        s = jnp.where(jnp.logical_or(sel, dup), NEG_INF, s)
    out_s_ref[...] = jnp.stack(out_s, axis=1)
    out_i_ref[...] = jnp.stack(out_i, axis=1)


@functools.partial(jax.jit, static_argnames=("k", "block_q", "interpret"))
def merge_topk_pallas(scores: jnp.ndarray, ids: jnp.ndarray, *, k: int,
                      block_q: int = 128, interpret: bool = False):
    """Blocked dedup-top-k merge.

    Args:
      scores: [B, m] f32 partial scores (-inf empty).
      ids: [B, m] i32 external ids (-1 empty).
      k: entries to keep per query (k <= m).

    Returns (scores [B, k] f32, ids [B, k] i32); empty output slots carry
    (NEG_INF, -1) — ``ops.merge_topk`` normalises NEG_INF to -inf.
    """
    b, m = scores.shape
    assert ids.shape == (b, m), (ids.shape, scores.shape)
    assert k <= m, (k, m)

    block_q = min(block_q, max(8, b))
    pb = -(-b // block_q) * block_q
    sp = jnp.full((pb, m), NEG_INF, jnp.float32).at[:b].set(scores)
    ip = jnp.full((pb, m), -1, jnp.int32).at[:b].set(ids)

    kernel = functools.partial(_merge_kernel, k=k)
    out_s, out_i = pl.pallas_call(
        kernel,
        grid=(pb // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, m), lambda i: (i, 0)),
            pl.BlockSpec((block_q, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pb, k), jnp.float32),
            jax.ShapeDtypeStruct((pb, k), jnp.int32),
        ],
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(sp, ip)
    return out_s[:b], out_i[:b]
