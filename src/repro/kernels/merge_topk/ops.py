"""Public op: dedup-top-k merge (Pallas on TPU, jnp oracle elsewhere).

``merge_topk`` is THE coordinator merge — the fused arena pipeline, the
single-host reference path and the SPMD ``shard_map`` program all call it
(the serving engine's per-query host merge uses the numpy twin in
``ref.py``). Dispatch: compiled Pallas kernel on TPU; the jnp oracle
everywhere else — this is a production hot path, so off-TPU it should
run as compiled XLA rather than the interpret-mode kernel (which exists
for validation and is exercised directly by the kernel tests).
``use_kernel=False`` forces the oracle, which callers inside
``shard_map`` need regardless of backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.merge_topk.kernel import merge_topk_pallas
from repro.kernels.merge_topk.ref import merge_topk_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def merge_impl() -> str:
    """Which implementation :func:`merge_topk` dispatches to on this
    backend (benchmark artifacts record this so the perf trajectory
    names what was actually measured)."""
    return "pallas-kernel" if _on_tpu() else "xla-oracle"


def merge_topk(scores: jnp.ndarray, ids: jnp.ndarray, *, k: int,
               alive=None, use_kernel: bool = True, block_q: int = 128):
    """k best entries per query with duplicate ids removed.

    Args:
      scores: [B, m] f32 flattened partial scores (-inf = empty slot).
      ids: [B, m] int external ids (-1 = empty slot).
      k: entries to keep; if k > m the inputs are padded up.
      alive: optional [B, m] bool alive-mask (metadata filters,
        tombstones): dead entries are demoted to the (-inf, -1) padding
        convention BEFORE the merge, so filtering can never under-fill
        the k live winners. Applied identically ahead of every
        implementation (kernel / oracle / numpy twin).
      use_kernel: False forces the jnp oracle (required inside shard_map,
        where the interpret-mode kernel cannot run).

    Returns (scores [B, k] f32 descending, ids [B, k] i32), (-inf, -1)
    padded — best-occurrence-wins on duplicate ids, ties broken by input
    position, identically in every implementation.
    """
    ids = ids.astype(jnp.int32)
    scores = scores.astype(jnp.float32)
    if alive is not None:
        scores = jnp.where(alive, scores, -jnp.inf)
        ids = jnp.where(alive, ids, -1)
    m = scores.shape[1]
    if k > m:
        pad = k - m
        scores = jnp.pad(scores, ((0, 0), (0, pad)),
                         constant_values=-jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
    if not use_kernel or not _on_tpu():
        return merge_topk_ref(scores, ids, k=k)
    out_s, out_i = merge_topk_pallas(scores, ids, k=k, block_q=block_q)
    return jnp.where(out_i >= 0, out_s, -jnp.inf), out_i
