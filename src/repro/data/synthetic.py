"""Synthetic data pipelines.

Token streams for LM training, frontend embeddings for VLM/audio stubs,
and vector datasets (clustered / norm-spread) for the Pyramid index —
mirrors the paper's Deep/SIFT (clustered descriptors, similar norms) and
Tiny (wide norm spread, used for MIPS) datasets at configurable scale.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.common.config import ArchConfig


# ---------------------------------------------------------------------------
# LM token pipeline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TokenBatch:
    inputs: np.ndarray    # [B, S] int32 (or [B, S, F] f32 for frontends)
    targets: np.ndarray   # [B, S] int32
    # loss mask (1 where target counts)
    mask: np.ndarray      # [B, S] f32


class SyntheticLM:
    """Markov-ish synthetic token stream with learnable structure.

    Tokens follow ``x[t+1] = (a * x[t] + b + noise) % V`` per sequence so a
    model can reduce loss below uniform — enough signal for the end-to-end
    training example to show learning.
    """

    def __init__(self, cfg: ArchConfig, batch: int, seq_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)

    def __iter__(self) -> Iterator[TokenBatch]:
        return self

    def __next__(self) -> TokenBatch:
        v = self.cfg.vocab_size
        b, s = self.batch, self.seq_len
        a = self.rng.integers(1, 8, size=(b, 1))
        c = self.rng.integers(0, v, size=(b, 1))
        x0 = self.rng.integers(0, v, size=(b, 1))
        toks = np.zeros((b, s + 1), dtype=np.int64)
        toks[:, :1] = x0
        for t in range(s):
            noise = self.rng.integers(0, 3, size=(b,))
            toks[:, t + 1] = (a[:, 0] * toks[:, t] + c[:, 0] + noise) % v
        if self.cfg.frontend:
            f = self.cfg.frontend_dim
            emb = self.rng.normal(size=(b, s, f)).astype(np.float32)
            return TokenBatch(inputs=emb,
                              targets=toks[:, 1:].astype(np.int32),
                              mask=np.ones((b, s), np.float32))
        return TokenBatch(inputs=toks[:, :-1].astype(np.int32),
                          targets=toks[:, 1:].astype(np.int32),
                          mask=np.ones((b, s), np.float32))


# ---------------------------------------------------------------------------
# Vector datasets for Pyramid (paper Table I analogues)
# ---------------------------------------------------------------------------


def clustered_vectors(n: int, d: int, num_clusters: int, *, spread=0.15,
                      seed: int = 0) -> np.ndarray:
    """Deep/SIFT-like: clustered descriptors with similar norms."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(num_clusters, d))
    asg = rng.integers(0, num_clusters, size=n)
    x = centers[asg] + spread * rng.normal(size=(n, d))
    return x.astype(np.float32)


def norm_spread_vectors(n: int, d: int, num_dirs: int, *, sigma=0.8,
                        seed: int = 0) -> np.ndarray:
    """Tiny-like: wide Euclidean-norm spread (interesting for MIPS)."""
    rng = np.random.default_rng(seed)
    dirs = rng.normal(size=(num_dirs, d))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    asg = rng.integers(0, num_dirs, size=n)
    x = dirs[asg] + 0.2 * rng.normal(size=(n, d))
    norms = rng.lognormal(mean=0.0, sigma=sigma, size=(n, 1))
    return (x * norms).astype(np.float32)


def query_set(x: np.ndarray, num_queries: int, *, noise=0.02,
              seed: int = 1) -> np.ndarray:
    """Queries drawn near dataset items (paper-style query workload)."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(x.shape[0], size=num_queries, replace=True)
    return (x[idx] + noise * rng.normal(size=(num_queries, x.shape[1]))
            ).astype(np.float32)
