"""Vector-dataset IO: fvecs/bvecs/ivecs (the SIFT/Deep/GIST interchange
formats used by the paper's datasets) plus npy/npz, with memory-mapped
sharded reading for the distributed index-build workflow (each worker
reads a contiguous slice — Sec. III-A "each worker reading a part of the
dataset from the distributed file system").
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import numpy as np


def _vecs_meta(path: str, itemsize: int) -> Tuple[int, int]:
    """(n, d) of an *.fvecs/bvecs/ivecs file (d-prefixed records)."""
    with open(path, "rb") as f:
        d = int(np.frombuffer(f.read(4), dtype=np.int32)[0])
    record = 4 + d * itemsize
    size = os.path.getsize(path)
    if size % record:
        raise ValueError(f"{path}: size {size} not a multiple of {record}")
    return size // record, d


def read_fvecs(path: str, start: int = 0,
               count: Optional[int] = None) -> np.ndarray:
    """float32 vectors; returns [count, d]."""
    n, d = _vecs_meta(path, 4)
    count = n - start if count is None else min(count, n - start)
    mm = np.memmap(path, dtype=np.float32, mode="r",
                   offset=start * (4 + 4 * d),
                   shape=(count, d + 1))
    return np.ascontiguousarray(mm[:, 1:], dtype=np.float32)


def read_bvecs(path: str, start: int = 0,
               count: Optional[int] = None) -> np.ndarray:
    """uint8 vectors (SIFT1B); returns float32 [count, d]."""
    n, d = _vecs_meta(path, 1)
    count = n - start if count is None else min(count, n - start)
    mm = np.memmap(path, dtype=np.uint8, mode="r",
                   offset=start * (4 + d), shape=(count, d + 4))
    return mm[:, 4:].astype(np.float32)


def read_ivecs(path: str, start: int = 0,
               count: Optional[int] = None) -> np.ndarray:
    """int32 vectors (ground-truth files); returns [count, d] int32."""
    n, d = _vecs_meta(path, 4)
    count = n - start if count is None else min(count, n - start)
    mm = np.memmap(path, dtype=np.int32, mode="r",
                   offset=start * (4 + 4 * d), shape=(count, d + 1))
    return np.ascontiguousarray(mm[:, 1:])


def write_fvecs(path: str, x: np.ndarray) -> None:
    x = np.asarray(x, dtype=np.float32)
    n, d = x.shape
    out = np.empty((n, d + 1), dtype=np.float32)
    out[:, 0] = np.frombuffer(
        np.full((n,), d, dtype=np.int32).tobytes(), dtype=np.float32)
    out[:, 1:] = x
    out.tofile(path)


def load_dataset(path: str, start: int = 0,
                 count: Optional[int] = None) -> np.ndarray:
    """Dispatch on extension: .fvecs/.bvecs/.npy/.npz."""
    if path.endswith(".fvecs"):
        return read_fvecs(path, start, count)
    if path.endswith(".bvecs"):
        return read_bvecs(path, start, count)
    if path.endswith(".npy"):
        x = np.load(path, mmap_mode="r")
        end = x.shape[0] if count is None else start + count
        return np.asarray(x[start:end], dtype=np.float32)
    if path.endswith(".npz"):
        x = np.load(path)["x"]
        end = x.shape[0] if count is None else start + count
        return np.asarray(x[start:end], dtype=np.float32)
    raise ValueError(f"unknown dataset format: {path}")


def worker_slice(total: int, worker: int, num_workers: int) -> Tuple[int, int]:
    """Contiguous (start, count) for one worker's read."""
    per = -(-total // num_workers)
    start = min(worker * per, total)
    return start, min(per, total - start)
